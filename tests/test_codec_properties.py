"""Property-based codec hardening (hypothesis): the invariants the
dispatch layer leans on, pinned across every supported format.

Low-bit posit inference lives or dies on exact encode/decode behavior
(Deep Positron; Lu et al.), so the codec properties the execution plans
assume — round-trip identity on representable values, order preservation,
pack/unpack inverse — are pinned here as laws over the whole P(n<=16)
format space rather than point checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import posit
from repro.core.formats import P8_2, P13_2, P16_2, PositFormat

# every (n, es) corner the framework supports: p8/p16 containers, es 0..3
FORMATS = [P8_2, PositFormat(8, 0), PositFormat(8, 1), PositFormat(10, 2),
           P13_2, PositFormat(12, 3), P16_2, PositFormat(16, 0),
           PositFormat(6, 1)]

fmt_strategy = st.sampled_from(FORMATS)

_STORAGE = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


def _codes(fmt, data, size=16):
    return np.array([data.draw(st.integers(0, fmt.mask)) for _ in range(size)])


# ---------------------------------------------------------------------------
# round-trip identity on representable values
# ---------------------------------------------------------------------------


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=150, deadline=None)
def test_roundtrip_identity_on_codes(fmt, data):
    """encode(decode(c)) == c for every code: decoded posit values are
    exactly representable, so re-encoding is the identity (NaR included —
    decode gives nan, encode maps nan back to the NaR code)."""
    c = jnp.asarray(_codes(fmt, data), jnp.int32)
    v = posit.decode(c, fmt)
    back = np.asarray(posit.encode(v, fmt)) & fmt.mask
    assert (back == np.asarray(c)).all()


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=150, deadline=None)
def test_quantize_is_idempotent(fmt, data):
    """quantize(quantize(x)) == quantize(x): one rounding, then a fixpoint.
    This is what lets pack_params replace on-the-fly fake_quant."""
    x = np.array([data.draw(st.floats(-1e8, 1e8, allow_nan=False, width=32))
                  for _ in range(16)], np.float32)
    q1 = posit.quantize(jnp.asarray(x), fmt)
    q2 = posit.quantize(q1, fmt)
    assert (np.asarray(q1) == np.asarray(q2)).all()


# ---------------------------------------------------------------------------
# monotonicity
# ---------------------------------------------------------------------------


def _signed(c, fmt):
    return c - (1 << fmt.n) if c & fmt.sign_mask else c


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=150, deadline=None)
def test_decode_monotonic_jax(fmt, data):
    """The JAX codec (the one the Pallas kernels lower) orders codes-as-
    signed-ints exactly like decoded values."""
    c1 = data.draw(st.integers(0, fmt.mask))
    c2 = data.draw(st.integers(0, fmt.mask))
    if fmt.nar_code in (c1, c2):
        return
    v = np.asarray(posit.decode(jnp.asarray([c1, c2], jnp.int32), fmt))
    s1, s2 = _signed(c1, fmt), _signed(c2, fmt)
    if s1 < s2:
        assert v[0] < v[1]
    elif s1 > s2:
        assert v[0] > v[1]
    else:
        assert v[0] == v[1]


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=150, deadline=None)
def test_encode_monotonic_jax(fmt, data):
    """encode is monotone in the float value (never reorders operands —
    what keeps fake_quant and fused rankings consistent)."""
    x = data.draw(st.floats(-1e20, 1e20, allow_nan=False, width=32))
    y = data.draw(st.floats(-1e20, 1e20, allow_nan=False, width=32))
    if x > y:
        x, y = y, x
    cx, cy = (int(c) & fmt.mask for c in
              np.asarray(posit.encode(jnp.asarray([x, y], jnp.float32), fmt)))
    assert _signed(cx, fmt) <= _signed(cy, fmt)


# ---------------------------------------------------------------------------
# pack / unpack inverse (the storage layer the checkpoints rely on)
# ---------------------------------------------------------------------------


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=150, deadline=None)
def test_pack_unpack_inverse(fmt, data):
    """unpack(pack(x)) == quantize(x), pack lands in the narrowest
    container, and re-packing the unpacked values is code-identical
    (no second rounding)."""
    x = np.array([data.draw(st.floats(-1e6, 1e6, allow_nan=False, width=32))
                  for _ in range(16)], np.float32)
    codes = posit.pack(jnp.asarray(x), fmt)
    assert codes.dtype == _STORAGE[fmt.storage_bits]
    v = posit.unpack(codes, fmt)
    assert (np.asarray(v) == np.asarray(posit.quantize(jnp.asarray(x), fmt))).all()
    again = posit.pack(v, fmt)
    assert (np.asarray(again) == np.asarray(codes)).all()


@given(fmt=st.sampled_from([P8_2, PositFormat(8, 0), P13_2, P16_2,
                            PositFormat(16, 1)]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_params_posit_unpack_inverse(fmt, seed):
    """pack_params -> posit.unpack recovers exactly the quantized masters
    for every packable leaf, across formats — the checkpoint conversion
    adds no rounding beyond the one fake_quant applies."""
    from repro import configs
    from repro.core.quant import QuantPolicy
    from repro.models import api, packing

    cfg = configs.get_smoke("qwen3_moe_235b").replace(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
        n_experts=4, top_k=2, moe_d_ff=8, vocab_size=32,
        quant=QuantPolicy(weights=fmt))
    params = api.init(jax.random.key(seed), cfg)
    packed = api.pack_params(params, cfg)
    for path in packing.packable_paths(cfg):
        leaf = params
        code = packed
        for k in path:
            leaf, code = leaf[k], code[k]
        want = posit.quantize(jnp.asarray(leaf, jnp.float32), fmt)
        got = posit.unpack(code, fmt)
        assert code.dtype == _STORAGE[fmt.storage_bits], path
        assert (np.asarray(got) == np.asarray(want)).all(), path
