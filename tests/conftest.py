# NOTE: deliberately no XLA_FLAGS here — tests must see the real 1-device
# world; multi-device tests spawn subprocesses that set their own flags.
import os

import numpy as np
import pytest

try:
    from hypothesis import settings

    # "ci" is the fixed-seed profile the workflow selects via
    # HYPOTHESIS_PROFILE=ci: derandomize makes every run replay the same
    # example sequence, so a red CI is reproducible locally byte for byte.
    settings.register_profile("ci", max_examples=150, deadline=None,
                              derandomize=True, print_blob=True)
    settings.register_profile("dev", max_examples=30, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # property tests importorskip hypothesis themselves
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
