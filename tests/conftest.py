# NOTE: deliberately no XLA_FLAGS here — tests must see the real 1-device
# world; multi-device tests spawn subprocesses that set their own flags.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
