"""Pallas kernel sweeps (interpret mode on CPU) vs the pure-jnp oracles:
shapes x dtypes x formats, asserting bit identity (codec/pdpu) or
allclose (fused matmul f32 path)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import P8_2, P13_2, P16_2, PDPUConfig
from repro.kernels import ops, ref

SHAPES_ELTWISE = [(8, 128), (256, 512), (300, 700), (17, 129), (1000,),
                  (3, 5, 257), (1, 1)]
FORMATS = [P8_2, P13_2, P16_2]


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
@pytest.mark.parametrize("shape", SHAPES_ELTWISE, ids=str)
def test_decode_kernel_sweep(fmt, shape, rng):
    codes = jnp.asarray(rng.integers(0, 1 << fmt.n, shape), jnp.int32)
    got = np.asarray(ops.decode(codes, fmt))
    want = np.asarray(ref.decode_ref(codes, fmt))
    eq = (got == want) | (np.isnan(got) & np.isnan(want))
    assert eq.all()


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
@pytest.mark.parametrize("shape", SHAPES_ELTWISE, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_encode_kernel_sweep(fmt, shape, dtype, rng):
    x = jnp.asarray(rng.normal(0, 2, shape), dtype)
    got = np.asarray(ops.encode(x, fmt))
    want = np.asarray(ref.encode_ref(x, fmt))
    assert (got == want).all()
    assert got.dtype == want.dtype  # storage container dtype


MM_CASES = [
    ((64, 128, 96), P16_2, P16_2, P16_2, (32, 32, 64)),
    ((130, 260, 70), P13_2, P13_2, P16_2, (64, 64, 128)),
    ((32, 64, 32), P8_2, P8_2, None, (32, 32, 32)),
    ((257, 129, 65), P13_2, P16_2, P13_2, (64, 64, 64)),  # mixed in-formats
    ((8, 512, 8), P16_2, P16_2, None, (8, 8, 128)),
]


@pytest.mark.parametrize("case", MM_CASES, ids=lambda c: f"{c[0]}-{c[1]}-{c[3]}")
def test_fused_matmul_sweep(case, rng):
    (M, K, N), fa, fb, fo, (bm, bn, bk) = case
    a = jnp.asarray(rng.integers(0, 1 << fa.n, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << fb.n, (K, N)), jnp.int32)
    a = jnp.where(a == fa.nar_code, 0, a)
    b = jnp.where(b == fb.nar_code, 0, b)
    got = ops.fused_matmul(a, b, fa, fb, fo, bm=bm, bn=bn, bk=bk)
    want = ref.posit_matmul_ref(a, b, fa, fb, fo, bk=bk)
    if fo is None:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
    else:
        assert (np.asarray(got) == np.asarray(want)).all()


def test_fused_matmul_single_rounding_property(rng):
    """Kernel output == encode(f32 matmul of decoded inputs): exactly one
    rounding (the fused property).

    Two separately compiled f32 dots may reduce in different orders, so a
    value sitting exactly on a posit rounding boundary can land one code
    apart — allow off-by-one codes on <0.5% of outputs, nothing more."""
    fa = fo = P16_2
    a = jnp.asarray(rng.integers(0, 1 << 16, (48, 64)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 16, (64, 32)), jnp.int32)
    a = jnp.where(a == fa.nar_code, 0, a)
    b = jnp.where(b == fa.nar_code, 0, b)
    from repro.core import posit
    manual = posit.pack(
        jnp.dot(posit.decode(a, fa), posit.decode(b, fa),
                preferred_element_type=jnp.float32), fo)
    got = np.asarray(ops.fused_matmul(a, b, fa, fa, fo, bm=16, bn=16, bk=64))
    manual = np.asarray(manual)
    diff = np.abs(got.astype(np.int64) - manual.astype(np.int64))
    assert diff.max() <= 1, "more than one code apart => extra rounding"
    assert (diff != 0).mean() < 0.005


PDPU_GEMM_CASES = [
    (PDPUConfig(P13_2, P16_2, N=4, w_m=14), (24, 16, 40), (16, 32)),
    (PDPUConfig(P8_2, P8_2, N=4, w_m=10), (16, 8, 16), (8, 16)),
    (PDPUConfig(P16_2, P16_2, N=8, w_m=14), (8, 16, 8), (8, 8)),
]


@pytest.mark.parametrize("case", PDPU_GEMM_CASES,
                         ids=lambda c: f"{c[0].name}-{c[1]}")
def test_pdpu_gemm_kernel_bit_exact(case, rng):
    cfg, (M, K, N), (bm, bn) = case
    a = jnp.asarray(rng.integers(0, 1 << cfg.fmt_in.n, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << cfg.fmt_in.n, (K, N)), jnp.int32)
    got = np.asarray(ops.pdpu_matmul(a, b, cfg, bm=bm, bn=bn))
    want = np.asarray(ref.pdpu_matmul_ref(a, b, cfg))
    assert (got == want).all()


def test_matmul_posit_weights_path(rng):
    from repro.core import posit
    x = jnp.asarray(rng.normal(0, 1, (16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.1, (64, 32)).astype(np.float32))
    w_codes = posit.pack(w, P16_2)
    got = ops.matmul_posit_weights(x, w_codes, P16_2)
    want = jnp.dot(x, posit.unpack(w_codes, P16_2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
