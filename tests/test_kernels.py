"""Pallas kernel sweeps (interpret mode on CPU) vs the pure-jnp oracles:
shapes x dtypes x formats, asserting bit identity (codec/pdpu) or
allclose (fused matmul f32 path)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import P8_2, P13_2, P16_1, P16_2, PDPUConfig
from repro.kernels import ops, ref

SHAPES_ELTWISE = [(8, 128), (256, 512), (300, 700), (17, 129), (1000,),
                  (3, 5, 257), (1, 1)]
FORMATS = [P8_2, P13_2, P16_2]


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
@pytest.mark.parametrize("shape", SHAPES_ELTWISE, ids=str)
def test_decode_kernel_sweep(fmt, shape, rng):
    codes = jnp.asarray(rng.integers(0, 1 << fmt.n, shape), jnp.int32)
    got = np.asarray(ops.decode(codes, fmt))
    want = np.asarray(ref.decode_ref(codes, fmt))
    eq = (got == want) | (np.isnan(got) & np.isnan(want))
    assert eq.all()


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
@pytest.mark.parametrize("shape", SHAPES_ELTWISE, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_encode_kernel_sweep(fmt, shape, dtype, rng):
    x = jnp.asarray(rng.normal(0, 2, shape), dtype)
    got = np.asarray(ops.encode(x, fmt))
    want = np.asarray(ref.encode_ref(x, fmt))
    assert (got == want).all()
    assert got.dtype == want.dtype  # storage container dtype


MM_CASES = [
    ((64, 128, 96), P16_2, P16_2, P16_2, (32, 32, 64)),
    ((130, 260, 70), P13_2, P13_2, P16_2, (64, 64, 128)),
    ((32, 64, 32), P8_2, P8_2, None, (32, 32, 32)),
    ((257, 129, 65), P13_2, P16_2, P13_2, (64, 64, 64)),  # mixed in-formats
    ((8, 512, 8), P16_2, P16_2, None, (8, 8, 128)),
]


@pytest.mark.parametrize("case", MM_CASES, ids=lambda c: f"{c[0]}-{c[1]}-{c[3]}")
def test_fused_matmul_sweep(case, rng):
    (M, K, N), fa, fb, fo, (bm, bn, bk) = case
    a = jnp.asarray(rng.integers(0, 1 << fa.n, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << fb.n, (K, N)), jnp.int32)
    a = jnp.where(a == fa.nar_code, 0, a)
    b = jnp.where(b == fb.nar_code, 0, b)
    got = ops.fused_matmul(a, b, fa, fb, fo, bm=bm, bn=bn, bk=bk)
    want = ref.posit_matmul_ref(a, b, fa, fb, fo, bk=bk)
    if fo is None:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
    else:
        assert (np.asarray(got) == np.asarray(want)).all()


def test_fused_matmul_single_rounding_property(rng):
    """Kernel output == encode(f32 matmul of decoded inputs): exactly one
    rounding (the fused property).

    Two separately compiled f32 dots may reduce in different orders, so a
    value sitting exactly on a posit rounding boundary can land one code
    apart — allow off-by-one codes on <0.5% of outputs, nothing more."""
    fa = fo = P16_2
    a = jnp.asarray(rng.integers(0, 1 << 16, (48, 64)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 16, (64, 32)), jnp.int32)
    a = jnp.where(a == fa.nar_code, 0, a)
    b = jnp.where(b == fa.nar_code, 0, b)
    from repro.core import posit
    manual = posit.pack(
        jnp.dot(posit.decode(a, fa), posit.decode(b, fa),
                preferred_element_type=jnp.float32), fo)
    got = np.asarray(ops.fused_matmul(a, b, fa, fa, fo, bm=16, bn=16, bk=64))
    manual = np.asarray(manual)
    diff = np.abs(got.astype(np.int64) - manual.astype(np.int64))
    assert diff.max() <= 1, "more than one code apart => extra rounding"
    assert (diff != 0).mean() < 0.005


PDPU_GEMM_CASES = [
    (PDPUConfig(P13_2, P16_2, N=4, w_m=14), (24, 16, 40), (16, 32)),
    (PDPUConfig(P8_2, P8_2, N=4, w_m=10), (16, 8, 16), (8, 16)),
    (PDPUConfig(P16_2, P16_2, N=8, w_m=14), (8, 16, 8), (8, 8)),
]


@pytest.mark.parametrize("case", PDPU_GEMM_CASES,
                         ids=lambda c: f"{c[0].name}-{c[1]}")
def test_pdpu_gemm_kernel_bit_exact(case, rng):
    cfg, (M, K, N), (bm, bn) = case
    a = jnp.asarray(rng.integers(0, 1 << cfg.fmt_in.n, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << cfg.fmt_in.n, (K, N)), jnp.int32)
    got = np.asarray(ops.pdpu_matmul(a, b, cfg, bm=bm, bn=bn))
    want = np.asarray(ref.pdpu_matmul_ref(a, b, cfg))
    assert (got == want).all()


def test_matmul_posit_weights_path(rng):
    from repro.core import posit
    x = jnp.asarray(rng.normal(0, 1, (16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.1, (64, 32)).astype(np.float32))
    w_codes = posit.pack(w, P16_2)
    got = ops.matmul_posit_weights(x, w_codes, P16_2)
    want = jnp.dot(x, posit.unpack(w_codes, P16_2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# multi-query paged decode: the 4-D q [B, T, Hq, Dh] grid
# ---------------------------------------------------------------------------


def _mq_setup(seed=1, B=3, T=4, Hq=4, Hkv=2, Dh=8, ps=4, M=5, fmt=P16_1,
              lengths=(7, 15, 12)):
    """Coded page pool (valid random codes — recycled-page garbage), one
    distinct page run per slot, and a [B, T, Hq, Dh] query block.
    `lengths` count all T new tokens as already written.  A local
    generator (not the session rng fixture): these tests must not shift
    the shared stream other test files' draws come from."""
    rng = np.random.default_rng(seed)
    n_pages = 1 + B * M
    F = Hkv * Dh
    dt = {8: jnp.int8, 16: jnp.int16}[fmt.storage_bits]
    kp = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, F)), jnp.int32)
    kp = jnp.where(kp == fmt.nar_code, 0, kp).astype(dt)
    vp = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, F)), jnp.int32)
    vp = jnp.where(vp == fmt.nar_code, 0, vp).astype(dt)
    bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, T, Hq, Dh)), jnp.float32)
    return q, kp, vp, bt, jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("t_block", [1, 2, 4, None])
def test_paged_attention_mq_matches_ref(t_block):
    q, kp, vp, bt, lengths = _mq_setup()
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    got = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=P16_1,
                              softcap_val=20.0, t_block=t_block)
    want = ref.paged_attention_mq_ref(q, kp, vp, bt, lengths, win,
                                      fmt_kv=P16_1, softcap_val=20.0)
    # streaming softmax vs dense softmax over garbage-code magnitudes
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-5)


def test_paged_attention_mq_window_plus_softcap():
    q, kp, vp, bt, lengths = _mq_setup()
    win = jnp.full((1,), 5, jnp.int32)
    got = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=P16_1,
                              softcap_val=12.0)
    want = ref.paged_attention_mq_ref(q, kp, vp, bt, lengths, win,
                                      fmt_kv=P16_1, softcap_val=12.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-5)


def test_paged_attention_mq_t_block_bitwise_independent():
    """The query-tile split is the autotuned knob: any tiling must be
    bitwise identical (each query row's streaming pass is independent)."""
    q, kp, vp, bt, lengths = _mq_setup()
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    outs = [ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=P16_1,
                                softcap_val=20.0, t_block=tb)
            for tb in (1, 2, 4)]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_paged_attention_mq_t1_matches_3d_path_bitwise():
    q, kp, vp, bt, lengths = _mq_setup()
    o3 = ops.paged_attention(q[:, 0], kp, vp, bt, lengths - 3,
                             jnp.full((1,), 2 ** 30, jnp.int32), fmt_kv=P16_1)
    o4 = ops.paged_attention(q[:, :1], kp, vp, bt, lengths - 3,
                             jnp.full((1,), 2 ** 30, jnp.int32), fmt_kv=P16_1)
    np.testing.assert_array_equal(np.asarray(o3), np.asarray(o4[:, 0]))


def test_paged_attention_mq_partials_finalize_matches_direct():
    """partials=True under the 4-D grid: normalizing (o, m, l) must be
    bitwise the direct kernel output (the single-'shard' merge case)."""
    q, kp, vp, bt, lengths = _mq_setup()
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    o, m, l = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=P16_1,
                                  partials=True)
    direct = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=P16_1)
    norm = o / jnp.maximum(l, 1e-30)[..., None]
    np.testing.assert_array_equal(np.asarray(norm), np.asarray(direct))


def test_paged_attention_mq_zero_length_slot():
    """A slot with length 0 has every kv position masked: the streaming
    kernel's normalizer stays 0 and finalize yields exact finite zeros
    (NOT the dense reference's uniform softmax over -inf rows).  The
    other slots must still match the reference."""
    q, kp, vp, bt, lengths = _mq_setup(lengths=(7, 15, 0))
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    got = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=P16_1,
                              softcap_val=20.0)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_array_equal(np.asarray(got[2]),
                                  np.zeros_like(np.asarray(got[2])))
    want = ref.paged_attention_mq_ref(q, kp, vp, bt, lengths, win,
                                      fmt_kv=P16_1, softcap_val=20.0)
    np.testing.assert_allclose(np.asarray(got[:2]), np.asarray(want[:2]),
                               rtol=2e-5, atol=1e-5)
