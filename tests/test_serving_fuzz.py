"""Property/fuzz hardening for the serving scheduler (hypothesis).

The paged engine is now a real scheduler — refcounted page allocator,
prefix-sharing index with copy-on-write, batched cross-slot prefill,
interleaved chunks, eos-at-prefill retirement, oversubscribed admission —
so its correctness surface is pinned as laws over random workloads rather
than example-driven point checks:

  * PageAllocator: alloc/share/free round-trips never double-free, never
    hand out the trash page, conserve `in_use + free == capacity`, and
    keep the peak monotone.
  * Scheduler: random queues (mixed lengths, shared/duplicate prefixes,
    eos-at-prefill, single-token budgets, oversubscribed pools) decode
    token-identical to the dense reference engine, and every page, hold,
    and prefix-index entry reclaims once the queue drains.
  * Sharded pool (kv_pages over a 2-device mesh): the same allocator laws
    per-device — budgets conserve shard-wise, no shard's trash page is
    ever granted, prefer_shard affinity holds whenever the budget fits —
    and the same scheduler law: random queues on the mesh engine decode
    token-identical to the dense reference with zero page leaks on any
    shard.  The mesh runs need >= 2 devices and skip otherwise (the CI
    8-device leg forces them via XLA_FLAGS).

Runs under the fixed-seed `ci` hypothesis profile in CI (tests/conftest.py)
so a red run replays locally byte for byte.
"""
import numpy as np
import jax
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core.quant import QuantPolicy
from repro.core.formats import P16_2, P8_2
from repro.models import api
from repro.serve import PageAllocator, Request, ServingEngine


# ---------------------------------------------------------------------------
# PageAllocator properties (pure host state, no device work)
# ---------------------------------------------------------------------------


@given(n_pages=st.integers(2, 24), data=st.data())
@settings(max_examples=150, deadline=None)
def test_allocator_invariants_under_random_ops(n_pages, data):
    """Random alloc/share/free interleavings conserve the pool: the trash
    page is never granted, every live page is unique, in_use + free ==
    capacity at every step, and the peak high-watermark is monotone."""
    a = PageAllocator(n_pages)
    live = {}  # page -> refcount we believe it has
    peak_seen = 0
    for _ in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(["alloc", "share", "free"]))
        if op == "alloc":
            n = data.draw(st.integers(0, n_pages))
            got = a.alloc(n)
            if n > a.capacity - sum(1 for _ in live):
                assert got is None, "oversubscribing alloc must refuse"
            if got is None:
                continue
            assert len(got) == n and 0 not in got
            assert not (set(got) & set(live)), "granted a live page twice"
            for p in got:
                live[p] = 1
        elif op == "share" and live:
            p = data.draw(st.sampled_from(sorted(live)))
            a.share([p])
            live[p] += 1
        elif op == "free" and live:
            p = data.draw(st.sampled_from(sorted(live)))
            recycled = a.free([p])
            live[p] -= 1
            if live[p] == 0:
                assert recycled == [p]
                del live[p]
            else:
                assert recycled == []
        assert a.pages_in_use + a.pages_free == a.capacity
        assert a.pages_in_use == len(live)
        for p, rc in live.items():
            assert a.refcount(p) == rc
        assert a.peak_in_use >= peak_seen, "peak must be monotone"
        peak_seen = a.peak_in_use
    # drain completely: every page recycles exactly once
    for p, rc in list(live.items()):
        recycled = a.free([p] * rc)
        assert recycled == [p]
    assert a.pages_free == a.capacity and a.pages_in_use == 0


@given(n_pages=st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_allocator_rejects_double_free_and_free_share(n_pages):
    a = PageAllocator(n_pages)
    got = a.alloc(a.capacity)
    assert got is not None and a.alloc(1) is None
    a.free(got)
    with pytest.raises(ValueError, match="double free"):
        a.free([got[0]])
    with pytest.raises(ValueError, match="share free"):
        a.share([got[0]])


@given(pps=st.integers(2, 8), n_shards=st.integers(1, 4), data=st.data())
@settings(max_examples=100, deadline=None)
def test_sharded_allocator_invariants_under_random_ops(pps, n_shards, data):
    """The per-device budgets obey the single-pool laws shard-wise: no
    shard's trash page (global ids = 0 mod pages_per_shard) is ever
    granted, per-shard in_use + free == pages_per_shard - 1, frees recycle
    onto their own shard, and prefer_shard is honored whenever that
    budget fits the whole grant."""
    a = PageAllocator(pps * n_shards, n_shards=n_shards)
    live = {}
    for _ in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(["alloc", "share", "free"]))
        if op == "alloc":
            n = data.draw(st.integers(0, pps * n_shards))
            prefer = data.draw(st.one_of(
                st.none(), st.integers(0, n_shards - 1)))
            free_at_prefer = (a.pages_free_by_shard[prefer]
                              if prefer is not None else -1)
            got = a.alloc(n, prefer_shard=prefer)
            if n > a.capacity - len(live):
                assert got is None, "oversubscribing alloc must refuse"
            if got is None:
                continue
            assert len(got) == n == len(set(got))
            assert all(g % pps != 0 for g in got), "granted a trash page"
            assert not (set(got) & set(live)), "granted a live page twice"
            if prefer is not None and free_at_prefer >= n > 0:
                assert all(g // pps == prefer for g in got), \
                    "prefer_shard budget fit but grant left the shard"
            for p in got:
                live[p] = 1
        elif op == "share" and live:
            p = data.draw(st.sampled_from(sorted(live)))
            a.share([p])
            live[p] += 1
        elif op == "free" and live:
            p = data.draw(st.sampled_from(sorted(live)))
            recycled = a.free([p])
            live[p] -= 1
            if live[p] == 0:
                del live[p]
                assert recycled == [p]
        by_use = a.pages_in_use_by_shard
        by_free = a.pages_free_by_shard
        for s in range(n_shards):
            assert by_use[s] + by_free[s] == pps - 1
            assert by_use[s] == sum(1 for p in live if p // pps == s)
    for p, rc in list(live.items()):
        assert a.free([p] * rc) == [p]
    assert a.pages_free_by_shard == [pps - 1] * n_shards


# ---------------------------------------------------------------------------
# scheduler fuzz: random queues vs the dense reference engine
# ---------------------------------------------------------------------------

_PS = 4  # page size under fuzz


def _model():
    if not hasattr(_model, "cache"):
        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        _model.cache = (cfg, params)
    return _model.cache


# two fixed base prefixes requests may share (page-aligned and not)
_BASES = (np.arange(8, dtype=np.int32) % 61,
          (np.arange(5, dtype=np.int32) * 7 + 3) % 61)


@st.composite
def _queues(draw):
    reqs = []
    for rid in range(draw(st.integers(1, 5))):
        kind = draw(st.sampled_from(["fresh", "shared", "dup"]))
        if kind == "fresh":
            n = draw(st.integers(1, 14))
            prompt = np.array([draw(st.integers(0, 60)) for _ in range(n)],
                              np.int32)
        else:
            base = _BASES[draw(st.integers(0, 1))]
            tail = ([] if kind == "dup" else
                    [draw(st.integers(0, 60))
                     for _ in range(draw(st.integers(0, 6)))])
            prompt = np.concatenate([base, np.asarray(tail, np.int32)])
        max_new = draw(st.integers(1, 4))
        # eos drawn from the prompt sometimes fires mid-decode or right at
        # prefill (the sampled token is never masked against it)
        eos = (int(prompt[draw(st.integers(0, len(prompt) - 1))])
               if draw(st.booleans()) else None)
        reqs.append(dict(rid=rid, prompt=prompt, max_new_tokens=max_new,
                         eos_id=eos))
    slack = draw(st.integers(0, 5))
    chunks_per_step = draw(st.sampled_from([0, 1, 2]))
    return reqs, slack, chunks_per_step


@given(q=_queues())
@settings(max_examples=8, deadline=None)
def test_scheduler_fuzz_matches_dense_reference(q):
    """Any random queue — mixed lengths, shared/duplicate prefixes, eos at
    prefill, oversubscribed pools, interleaved chunking — decodes
    token-identical to the dense reference engine, and the paged engine
    reclaims every page, hold, and index entry once the queue drains."""
    reqs, slack, chunks_per_step = q
    cfg, params = _model()
    # pool: just enough for the largest request plus a little slack, so
    # queues routinely oversubscribe and wait for reclamation
    max_need = max((len(r["prompt"]) + r["max_new_tokens"] - 2) // _PS + 1
                   for r in reqs)
    kw = dict(batch_slots=2, max_seq=32, prefill_buckets=(4, 1),
              prefill_chunks_per_step=chunks_per_step)
    paged = ServingEngine(cfg, params, page_size=_PS,
                          n_pages=max_need + 1 + slack, **kw)
    dense = ServingEngine(cfg, params, paged=False, **kw)
    for eng in (paged, dense):
        for r in reqs:
            eng.submit(Request(**{**r, "prompt": r["prompt"].copy()}))
    got = {r.rid: r.out_tokens for r in paged.run()}
    want = {r.rid: r.out_tokens for r in dense.run()}
    assert got == want
    assert len(got) == len(reqs)
    assert paged.pages_in_use == 0 and paged.pages_free \
        == paged.allocator.capacity
    assert not paged.prefix_index and not paged._held
    assert not paged.allocator._refs
    assert all(not p for p in paged.slot_pages)


def _events(data, reqs):
    """A random schedule of mid-flight preemptions and queue cancels."""
    evs = data.draw(st.lists(st.tuples(
        st.integers(0, 25),                        # engine step to fire at
        st.sampled_from(["preempt", "cancel"]),
        st.integers(0, max(r["rid"] for r in reqs))),  # slot / rid source
        min_size=1, max_size=4))
    by_step = {}
    for step, kind, x in evs:
        by_step.setdefault(step, []).append((kind, x))
    return by_step


def _drive_with_events(eng, by_step):
    """step() the engine to drain, firing scheduled preempt/cancel events;
    returns the set of rids successfully cancelled while still queued."""
    cancelled = set()
    for step in range(500):
        for kind, x in by_step.get(step, ()):
            if kind == "preempt":
                eng.preempt(x % eng.B)   # no-op on a free slot
            elif eng.cancel(x):
                cancelled.add(x)
        if not eng.step() and not eng.queue:
            break
    else:
        pytest.fail("engine did not drain under preemption fuzz")
    return cancelled


@given(q=_queues(), data=st.data())
@settings(max_examples=6, deadline=None)
def test_preemption_cancel_fuzz_matches_reference(q, data):
    """Random mid-flight preemptions (requeue at head, discard + replay)
    and queue cancellations never change a surviving request's stream:
    sampling keys derive from (seed, draw index), so a replay is bitwise
    the original run regardless of when the eviction landed.  Afterwards
    the pool reclaims completely — no leaked pages and no orphaned holds
    from cancelled requests (`cancel` prunes what only they wanted)."""
    reqs, slack, chunks_per_step = q
    cfg, params = _model()
    max_need = max((len(r["prompt"]) + r["max_new_tokens"] - 2) // _PS + 1
                   for r in reqs)
    kw = dict(batch_slots=2, max_seq=32, prefill_buckets=(4, 1),
              prefill_chunks_per_step=chunks_per_step,
              page_size=_PS, n_pages=max_need + 1 + slack)
    ref = ServingEngine(cfg, params, **kw)
    eng = ServingEngine(cfg, params, **kw)
    for e in (ref, eng):
        for r in reqs:
            e.submit(Request(**{**r, "prompt": r["prompt"].copy()}))
    want = {r.rid: r.out_tokens for r in ref.run()}

    cancelled = _drive_with_events(eng, _events(data, reqs))
    got = {r.rid: r.out_tokens for r in eng.done}
    assert set(got) == {r["rid"] for r in reqs} - cancelled
    for rid, toks in got.items():
        assert toks == want[rid], rid
    assert eng.pages_in_use == 0 and eng.pages_free \
        == eng.allocator.capacity
    assert not eng.prefix_index and not eng._held
    assert not eng.allocator._refs
    assert all(not p for p in eng.slot_pages)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="sharded preemption fuzz needs >=2 devices")
@given(q=_queues(), data=st.data())
@settings(max_examples=4, deadline=None)
def test_sharded_preemption_fuzz_reclaims_per_shard(q, data):
    """The same preemption/cancel law on the 2-device mesh engine: token
    streams of survivors match the unpreempted reference and EVERY shard's
    page budget returns to full — preemption must release pages back onto
    the shard that owns them."""
    from repro.launch.mesh import make_serving_mesh

    reqs, slack, chunks_per_step = q
    cfg, params = _model()
    max_need = max((len(r["prompt"]) + r["max_new_tokens"] - 2) // _PS + 1
                   for r in reqs)
    n_pages = max_need + 2 + slack
    n_pages += n_pages % 2
    kw = dict(batch_slots=2, max_seq=32, prefill_buckets=(4, 1),
              prefill_chunks_per_step=chunks_per_step,
              page_size=_PS, n_pages=n_pages)
    ref = ServingEngine(cfg, params, mesh=make_serving_mesh(2), **kw)
    eng = ServingEngine(cfg, params, mesh=make_serving_mesh(2), **kw)
    for e in (ref, eng):
        for r in reqs:
            e.submit(Request(**{**r, "prompt": r["prompt"].copy()}))
    want = {r.rid: r.out_tokens for r in ref.run()}

    cancelled = _drive_with_events(eng, _events(data, reqs))
    got = {r.rid: r.out_tokens for r in eng.done}
    assert set(got) == {r["rid"] for r in reqs} - cancelled
    for rid, toks in got.items():
        assert toks == want[rid], rid
    a = eng.allocator
    assert a.pages_in_use_by_shard == [0, 0]
    assert a.pages_free_by_shard == [a.pages_per_shard - 1] * 2
    assert not eng.prefix_index and not eng._held and not a._refs
    assert all(not p for p in eng.slot_pages)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="sharded-pool fuzz needs >=2 devices (the CI "
                           "8-device leg forces them via XLA_FLAGS)")
@given(q=_queues())
@settings(max_examples=6, deadline=None)
def test_sharded_scheduler_fuzz_matches_dense_reference(q):
    """The 2-device mesh engine obeys the same law as the single-pool
    one: any random queue decodes token-identical to the dense reference,
    and once it drains every per-device page budget is back to full — no
    leaked pages, holds, or index entries on either shard."""
    from repro.launch.mesh import make_serving_mesh

    reqs, slack, chunks_per_step = q
    cfg, params = _model()
    max_need = max((len(r["prompt"]) + r["max_new_tokens"] - 2) // _PS + 1
                   for r in reqs)
    # smallest even pool with capacity (n_pages - 2 trash) >= max_need,
    # plus slack — queues routinely oversubscribe and spill across shards
    n_pages = max_need + 2 + slack
    n_pages += n_pages % 2
    kw = dict(batch_slots=2, max_seq=32, prefill_buckets=(4, 1),
              prefill_chunks_per_step=chunks_per_step)
    paged = ServingEngine(cfg, params, page_size=_PS, n_pages=n_pages,
                          mesh=make_serving_mesh(2), **kw)
    dense = ServingEngine(cfg, params, paged=False, **kw)
    assert paged.n_shards == 2
    for eng in (paged, dense):
        for r in reqs:
            eng.submit(Request(**{**r, "prompt": r["prompt"].copy()}))
    got = {r.rid: r.out_tokens for r in paged.run()}
    want = {r.rid: r.out_tokens for r in dense.run()}
    assert got == want
    assert len(got) == len(reqs)
    a = paged.allocator
    assert a.pages_in_use_by_shard == [0, 0]
    assert a.pages_free_by_shard == [a.pages_per_shard - 1] * 2
    assert not paged.prefix_index and not paged._held and not a._refs
    assert all(not p for p in paged.slot_pages)
