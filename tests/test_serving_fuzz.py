"""Property/fuzz hardening for the serving scheduler (hypothesis).

The paged engine is now a real scheduler — refcounted page allocator,
prefix-sharing index with copy-on-write, batched cross-slot prefill,
interleaved chunks, eos-at-prefill retirement, oversubscribed admission —
so its correctness surface is pinned as laws over random workloads rather
than example-driven point checks:

  * PageAllocator: alloc/share/free round-trips never double-free, never
    hand out the trash page, conserve `in_use + free == capacity`, and
    keep the peak monotone.
  * Scheduler: random queues (mixed lengths, shared/duplicate prefixes,
    eos-at-prefill, single-token budgets, oversubscribed pools) decode
    token-identical to the dense reference engine, and every page, hold,
    and prefix-index entry reclaims once the queue drains.

Runs under the fixed-seed `ci` hypothesis profile in CI (tests/conftest.py)
so a red run replays locally byte for byte.
"""
import numpy as np
import jax
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core.quant import QuantPolicy
from repro.core.formats import P16_2, P8_2
from repro.models import api
from repro.serve import PageAllocator, Request, ServingEngine


# ---------------------------------------------------------------------------
# PageAllocator properties (pure host state, no device work)
# ---------------------------------------------------------------------------


@given(n_pages=st.integers(2, 24), data=st.data())
@settings(max_examples=150, deadline=None)
def test_allocator_invariants_under_random_ops(n_pages, data):
    """Random alloc/share/free interleavings conserve the pool: the trash
    page is never granted, every live page is unique, in_use + free ==
    capacity at every step, and the peak high-watermark is monotone."""
    a = PageAllocator(n_pages)
    live = {}  # page -> refcount we believe it has
    peak_seen = 0
    for _ in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(["alloc", "share", "free"]))
        if op == "alloc":
            n = data.draw(st.integers(0, n_pages))
            got = a.alloc(n)
            if n > a.capacity - sum(1 for _ in live):
                assert got is None, "oversubscribing alloc must refuse"
            if got is None:
                continue
            assert len(got) == n and 0 not in got
            assert not (set(got) & set(live)), "granted a live page twice"
            for p in got:
                live[p] = 1
        elif op == "share" and live:
            p = data.draw(st.sampled_from(sorted(live)))
            a.share([p])
            live[p] += 1
        elif op == "free" and live:
            p = data.draw(st.sampled_from(sorted(live)))
            recycled = a.free([p])
            live[p] -= 1
            if live[p] == 0:
                assert recycled == [p]
                del live[p]
            else:
                assert recycled == []
        assert a.pages_in_use + a.pages_free == a.capacity
        assert a.pages_in_use == len(live)
        for p, rc in live.items():
            assert a.refcount(p) == rc
        assert a.peak_in_use >= peak_seen, "peak must be monotone"
        peak_seen = a.peak_in_use
    # drain completely: every page recycles exactly once
    for p, rc in list(live.items()):
        recycled = a.free([p] * rc)
        assert recycled == [p]
    assert a.pages_free == a.capacity and a.pages_in_use == 0


@given(n_pages=st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_allocator_rejects_double_free_and_free_share(n_pages):
    a = PageAllocator(n_pages)
    got = a.alloc(a.capacity)
    assert got is not None and a.alloc(1) is None
    a.free(got)
    with pytest.raises(ValueError, match="double free"):
        a.free([got[0]])
    with pytest.raises(ValueError, match="share free"):
        a.share([got[0]])


# ---------------------------------------------------------------------------
# scheduler fuzz: random queues vs the dense reference engine
# ---------------------------------------------------------------------------

_PS = 4  # page size under fuzz


def _model():
    if not hasattr(_model, "cache"):
        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        _model.cache = (cfg, params)
    return _model.cache


# two fixed base prefixes requests may share (page-aligned and not)
_BASES = (np.arange(8, dtype=np.int32) % 61,
          (np.arange(5, dtype=np.int32) * 7 + 3) % 61)


@st.composite
def _queues(draw):
    reqs = []
    for rid in range(draw(st.integers(1, 5))):
        kind = draw(st.sampled_from(["fresh", "shared", "dup"]))
        if kind == "fresh":
            n = draw(st.integers(1, 14))
            prompt = np.array([draw(st.integers(0, 60)) for _ in range(n)],
                              np.int32)
        else:
            base = _BASES[draw(st.integers(0, 1))]
            tail = ([] if kind == "dup" else
                    [draw(st.integers(0, 60))
                     for _ in range(draw(st.integers(0, 6)))])
            prompt = np.concatenate([base, np.asarray(tail, np.int32)])
        max_new = draw(st.integers(1, 4))
        # eos drawn from the prompt sometimes fires mid-decode or right at
        # prefill (the sampled token is never masked against it)
        eos = (int(prompt[draw(st.integers(0, len(prompt) - 1))])
               if draw(st.booleans()) else None)
        reqs.append(dict(rid=rid, prompt=prompt, max_new_tokens=max_new,
                         eos_id=eos))
    slack = draw(st.integers(0, 5))
    chunks_per_step = draw(st.sampled_from([0, 1, 2]))
    return reqs, slack, chunks_per_step


@given(q=_queues())
@settings(max_examples=8, deadline=None)
def test_scheduler_fuzz_matches_dense_reference(q):
    """Any random queue — mixed lengths, shared/duplicate prefixes, eos at
    prefill, oversubscribed pools, interleaved chunking — decodes
    token-identical to the dense reference engine, and the paged engine
    reclaims every page, hold, and index entry once the queue drains."""
    reqs, slack, chunks_per_step = q
    cfg, params = _model()
    # pool: just enough for the largest request plus a little slack, so
    # queues routinely oversubscribe and wait for reclamation
    max_need = max((len(r["prompt"]) + r["max_new_tokens"] - 2) // _PS + 1
                   for r in reqs)
    kw = dict(batch_slots=2, max_seq=32, prefill_buckets=(4, 1),
              prefill_chunks_per_step=chunks_per_step)
    paged = ServingEngine(cfg, params, page_size=_PS,
                          n_pages=max_need + 1 + slack, **kw)
    dense = ServingEngine(cfg, params, paged=False, **kw)
    for eng in (paged, dense):
        for r in reqs:
            eng.submit(Request(**{**r, "prompt": r["prompt"].copy()}))
    got = {r.rid: r.out_tokens for r in paged.run()}
    want = {r.rid: r.out_tokens for r in dense.run()}
    assert got == want
    assert len(got) == len(reqs)
    assert paged.pages_in_use == 0 and paged.pages_free \
        == paged.allocator.capacity
    assert not paged.prefix_index and not paged._held
    assert not paged.allocator._refs
    assert all(not p for p in paged.slot_pages)
