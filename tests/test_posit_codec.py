"""Posit codec: exhaustive bit-exactness + hypothesis invariants.

Three implementations (exact Fraction oracle / numpy int64 / JAX int32)
must agree everywhere; the JAX codec is the one the kernels lower.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import posit as pj
from repro.core import posit_np as pnp
from repro.core import posit_py as ppy
from repro.core.formats import P8_2, P13_2, P16_2, PositFormat

FORMATS = [P8_2, PositFormat(8, 0), PositFormat(8, 1), P13_2, P16_2,
           PositFormat(10, 2), PositFormat(12, 3), PositFormat(6, 1)]


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_decode_exhaustive_np_vs_jax(fmt):
    codes = np.arange(1 << fmt.n)
    vn = pnp.decode_np(codes, fmt).astype(np.float32)
    vj = np.asarray(pj.decode(jnp.asarray(codes, jnp.int32), fmt))
    eq = (vn == vj) | (np.isnan(vn) & np.isnan(vj))
    assert eq.all(), np.where(~eq)


@pytest.mark.parametrize("fmt", [P8_2, PositFormat(8, 0), PositFormat(6, 1)], ids=str)
def test_decode_exhaustive_vs_oracle(fmt):
    codes = np.arange(1 << fmt.n)
    vn = pnp.decode_np(codes, fmt)
    for c in codes:
        ve = ppy.decode_exact(int(c), fmt)
        if ve is None:
            assert np.isnan(vn[c])
        else:
            assert float(ve) == vn[c], hex(c)


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_roundtrip_exhaustive(fmt):
    codes = np.arange(1 << fmt.n)
    v = pnp.decode_np(codes, fmt)
    assert (pnp.encode_np(v, fmt) == codes).all()
    vj = pj.decode(jnp.asarray(codes, jnp.int32), fmt)
    assert (np.asarray(pj.encode(vj, fmt)) == codes).all()


@pytest.mark.parametrize("fmt", [P16_2, P13_2, P8_2], ids=str)
def test_encode_jax_matches_numpy_random(fmt, rng):
    xs = np.concatenate([
        rng.normal(0, 1, 4000), rng.normal(0, 1e-7, 1000),
        rng.normal(0, 1e7, 1000),
        [0.0, -0.0, np.inf, -np.inf, np.nan, 1e-42, -1e-44],
    ]).astype(np.float32)
    cn = pnp.encode_np(xs.astype(np.float64), fmt)
    cj = np.asarray(pj.encode(jnp.asarray(xs), fmt))
    assert (cn == cj).all()


@pytest.mark.parametrize("fmt", [P8_2, PositFormat(6, 1)], ids=str)
def test_encode_matches_oracle_random(fmt, rng):
    xs = np.concatenate([rng.normal(0, 1, 300), rng.normal(0, 1e-6, 150),
                         rng.normal(0, 1e6, 150)])
    cn = pnp.encode_np(xs, fmt)
    for x, c in zip(xs, cn):
        assert ppy.from_float(float(x), fmt) == c, x


def test_pattern_rounding_regime_gap():
    """Regression: posit RNE is pattern-space, not linear nearest-value.

    In P(8,2), between code 1 (2^-24) and code 2 (2^-20) the pattern
    midpoint is 2^-22; a value just above it must round UP even though it
    is linearly closer to 2^-24."""
    x = 4.19e-7  # > 2^-22 = 2.38e-7, but linearly nearer to 5.96e-8
    assert ppy.from_float(x, P8_2) == 0x2
    assert int(pnp.encode_np(np.array([x]), P8_2)[0]) == 0x2


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

fmt_strategy = st.sampled_from([P8_2, P13_2, P16_2, PositFormat(10, 2),
                                PositFormat(8, 0)])


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=200, deadline=None)
def test_negation_symmetry(fmt, data):
    c = data.draw(st.integers(0, fmt.mask))
    if c in (0, fmt.nar_code):
        return
    neg = (-c) & fmt.mask
    v = pnp.decode_np(np.array([c, neg]), fmt)
    assert v[0] == -v[1]


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=200, deadline=None)
def test_monotonic_codes(fmt, data):
    """Posit codes, read as signed n-bit ints, order exactly like values."""
    c1 = data.draw(st.integers(0, fmt.mask))
    c2 = data.draw(st.integers(0, fmt.mask))
    if fmt.nar_code in (c1, c2):
        return
    def signed(c):
        return c - (1 << fmt.n) if c & fmt.sign_mask else c
    v = pnp.decode_np(np.array([c1, c2]), fmt)
    if signed(c1) < signed(c2):
        assert v[0] < v[1]
    elif signed(c1) > signed(c2):
        assert v[0] > v[1]


@given(fmt=fmt_strategy,
       x=st.floats(min_value=-1e30, max_value=1e30,
                   allow_nan=False, allow_infinity=False))
@settings(max_examples=300, deadline=None)
def test_encode_is_clamping_total(fmt, x):
    """Every finite float encodes to a finite posit (never NaR), and a
    non-zero float never encodes to zero (posit has no underflow)."""
    c = int(pnp.encode_np(np.array([x]), fmt)[0])
    assert c != fmt.nar_code
    if x != 0:
        assert c != 0
    v = float(pnp.decode_np(np.array([c]), fmt)[0])
    maxpos = float(pnp.decode_np(np.array([fmt.maxpos_code]), fmt)[0])
    assert abs(v) <= maxpos


@given(fmt=fmt_strategy, data=st.data())
@settings(max_examples=200, deadline=None)
def test_encode_monotonic_in_value(fmt, data):
    x = data.draw(st.floats(-1e20, 1e20, allow_nan=False))
    y = data.draw(st.floats(-1e20, 1e20, allow_nan=False))
    if x > y:
        x, y = y, x
    cx, cy = (int(v) for v in pnp.encode_np(np.array([x, y]), fmt))
    def signed(c):
        return c - (1 << fmt.n) if c & fmt.sign_mask else c
    assert signed(cx) <= signed(cy)


def test_pack_unpack_storage_dtypes():
    x = jnp.asarray(np.linspace(-3, 3, 64, dtype=np.float32))
    for fmt, dt in [(P8_2, jnp.int8), (P16_2, jnp.int16), (P13_2, jnp.int16)]:
        codes = pj.pack(x, fmt)
        assert codes.dtype == dt
        y = pj.unpack(codes, fmt)
        assert np.allclose(np.asarray(y), np.asarray(pj.quantize(x, fmt)))


def test_quantize_ste_gradient_is_identity():
    import jax
    x = jnp.asarray(np.linspace(-2, 2, 32, dtype=np.float32))
    g = jax.grad(lambda t: jnp.sum(pj.quantize_ste(t, P13_2) ** 2))(x)
    # STE: d/dx sum(q(x)^2) == 2*q(x) (identity through the quantizer)
    assert np.allclose(np.asarray(g), 2 * np.asarray(pj.quantize(x, P13_2)))
