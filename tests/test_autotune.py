"""Kernel autotune cache: keys, persistence, dispatch resolution, sweep.

The cache (kernels/autotune.py) maps (kernel, bucketed shape, posit
formats, backend) -> launch params; ops.py resolves unspecified launch
params through it at dispatch time.  Every tuned parameter is
value-neutral by construction (tile sizes / query-tile splits that never
change the math), so these tests assert that resolution through any
cache contents — committed, injected, or absent — leaves kernel outputs
bitwise unchanged while the hit/miss accounting observes the lookups.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import posit
from repro.core.formats import P8_2, P16_1, P16_2
from repro.kernels import autotune, ops, posit_codec


@pytest.fixture
def scratch_cache():
    """Restore the process-wide cache after a test swaps it out."""
    yield
    autotune.reset_cache(None)


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------


def test_shape_bucket_rounds_up_pow2_min8():
    assert autotune.shape_bucket((1, 8, 9, 1000)) == (8, 8, 16, 1024)
    assert autotune.shape_bucket((256,)) == (256,)


def test_make_key_canonical():
    assert autotune.make_key((200, 300, 100), (P16_2, None)) == \
        {"shape": [256, 512, 128], "fmts": ["P16_2", "f32"]}


def test_key_digest_stable_and_discriminating():
    key = autotune.make_key((200, 300, 100), (P16_2, P16_2))
    d = autotune.key_digest("posit_matmul", "cpu", key)
    # same bucket -> same digest
    same = autotune.make_key((129, 257, 65), (P16_2, P16_2))
    assert autotune.key_digest("posit_matmul", "cpu", same) == d
    # kernel, backend, format, and bucket each discriminate
    assert autotune.key_digest("posit_matmul_grouped", "cpu", key) != d
    assert autotune.key_digest("posit_matmul", "tpu", key) != d
    other_fmt = autotune.make_key((200, 300, 100), (P16_1, P16_2))
    assert autotune.key_digest("posit_matmul", "cpu", other_fmt) != d
    other_shape = autotune.make_key((300, 300, 100), (P16_2, P16_2))
    assert autotune.key_digest("posit_matmul", "cpu", other_shape) != d


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def test_cache_roundtrip_and_hit_accounting(tmp_path):
    c = autotune.AutotuneCache()
    c.put("paged_attention", (4, 8, 8, 16, 128), {"t_block": 2},
          fmts=(P16_1,), ms=1.0, oracle_ms=0.5)
    path = c.save(str(tmp_path / "cache.json"))
    loaded = autotune.AutotuneCache.load(path)
    # any shape in the same bucket resolves to the stored params
    assert loaded.lookup("paged_attention", (3, 5, 7, 9, 100),
                         (P16_1,)) == {"t_block": 2}
    assert loaded.lookup("paged_attention", (3, 5, 7, 9, 100),
                         (P8_2,)) is None
    assert loaded.report() == {"paged_attention": {"hits": 1, "misses": 1}}


def test_cache_version_bump_invalidates_wholesale(tmp_path):
    c = autotune.AutotuneCache()
    c.put("posit_matmul", (256, 256, 256), {"bm": 128, "bn": 128, "bk": 256},
          fmts=(P16_2, P16_2))
    path = c.save(str(tmp_path / "cache.json"))
    with open(path) as f:
        raw = json.load(f)
    raw["version"] = autotune.CACHE_VERSION + 1
    with open(path, "w") as f:
        json.dump(raw, f)
    assert autotune.AutotuneCache.load(path).entries == {}


def test_missing_file_loads_empty(tmp_path):
    assert autotune.AutotuneCache.load(str(tmp_path / "nope.json")).entries \
        == {}


def test_env_var_cache_path_and_off(tmp_path, monkeypatch, scratch_cache):
    c = autotune.AutotuneCache()
    c.put("posit_codec.decode", (64, 128), {"block_r": 64, "block_c": 128},
          fmts=(P16_2,))
    path = c.save(str(tmp_path / "cache.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset_cache(None)  # force a reload from the env path
    assert autotune.lookup("posit_codec.decode", (64, 128), (P16_2,)) == \
        {"block_r": 64, "block_c": 128}
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert autotune.lookup("posit_codec.decode", (64, 128), (P16_2,)) is None


def test_committed_cache_is_well_formed():
    """The committed CI-host cache must load under the current schema and
    only carry params from each kernel's declared tunable space."""
    cache = autotune.AutotuneCache.load(autotune.DEFAULT_CACHE_PATH)
    with open(autotune.DEFAULT_CACHE_PATH) as f:
        raw = json.load(f)
    assert raw["version"] == autotune.CACHE_VERSION
    assert len(raw["entries"]) > 0
    for digest, ent in raw["entries"].items():
        space = autotune.TUNABLES[ent["kernel"]]
        assert set(ent["params"]) == set(space)
        for name, val in ent["params"].items():
            assert val in space[name]
        # the stored digest must reproduce from the stored key contents
        assert digest == autotune.key_digest(ent["kernel"], raw["backend"],
                                             ent["key"])
    del cache


# ---------------------------------------------------------------------------
# dispatch-time resolution through ops.py
# ---------------------------------------------------------------------------


def test_ops_resolution_uses_injected_cache(scratch_cache):
    rng = np.random.default_rng(11)
    vals = jnp.asarray(rng.normal(0, 1, (32, 48)), jnp.float32)
    codes = posit.pack(vals, P16_2)
    autotune.reset_cache(autotune.AutotuneCache())  # empty: all misses
    want = ops.decode(codes, P16_2)
    c = autotune.AutotuneCache()
    c.put("posit_codec.decode", codes.shape, {"block_r": 256, "block_c": 512},
          fmts=(P16_2,))
    autotune.reset_cache(c)
    got = ops.decode(codes, P16_2)
    # tuned tiling resolved (a recorded hit) and value-neutral
    assert c.hits.get("posit_codec.decode", 0) >= 1
    assert bool(jnp.all(got == want))


def test_ops_explicit_params_win_over_cache(scratch_cache):
    rng = np.random.default_rng(12)
    vals = jnp.asarray(rng.normal(0, 1, (32, 48)), jnp.float32)
    codes = posit.pack(vals, P16_2)
    c = autotune.AutotuneCache()
    c.put("posit_codec.decode", codes.shape, {"block_r": 256, "block_c": 512},
          fmts=(P16_2,))
    autotune.reset_cache(c)
    got = ops.decode(codes, P16_2, block_r=8, block_c=16)
    assert bool(jnp.all(got == posit.unpack(codes, P16_2)))
    assert bool(jnp.all(got == ops.decode(codes, P16_2)))


def test_largest_divisor_fallback():
    """Dispatch-time degrade rule for cached tiles that don't divide the
    live launch dim: largest divisor at or below the cached value."""
    assert ops._largest_divisor(6, 4) == 3
    assert ops._largest_divisor(7, 4) == 1
    assert ops._largest_divisor(8, 8) == 8
    assert ops._largest_divisor(8, 16) == 8
    assert ops._largest_divisor(48, 32) == 24


def test_degrade_tile_prime_dim_drops_cached_value():
    """A cached tile facing a prime live dim must NOT collapse to a
    1-element-per-program grid; the resolver drops it (None) so the
    kernel's untuned default applies instead."""
    assert ops._degrade_tile(6, 4) == 3        # degrades to a divisor
    assert ops._degrade_tile(8, 4) == 4        # divides: kept as-is
    assert ops._degrade_tile(7, 4) is None     # prime: dropped, not 1
    assert ops._degrade_tile(13, 8) is None    # prime: dropped, not 1
    assert ops._degrade_tile(1, 4) == 1        # dim 1: trivially exact
    assert ops._degrade_tile(7, None) is None  # no cached value at all


def test_ops_paged_prime_t_falls_back_to_untuned_default(scratch_cache):
    """Regression: a cached t_block over a prime multi-query span used to
    collapse to t_block=1 via _largest_divisor; it must instead drop to
    the kernel's untuned default — and stay value-neutral."""
    rng = np.random.default_rng(31)
    B, T, Hq, Hkv, Dh, ps, M = 2, 5, 4, 2, 8, 4, 4  # T=5 prime
    fmt = P16_1
    n_pages = 1 + B * M
    kp = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, Hkv * Dh)),
                     jnp.int32)
    kp = jnp.where(kp == fmt.nar_code, 0, kp).astype(jnp.int16)
    vp = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, Hkv * Dh)),
                     jnp.int32)
    vp = jnp.where(vp == fmt.nar_code, 0, vp).astype(jnp.int16)
    bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
    lengths = jnp.asarray([6, 9], jnp.int32)
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, T, Hq, Dh)), jnp.float32)
    autotune.reset_cache(autotune.AutotuneCache())  # untuned default
    want = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=fmt)
    c = autotune.AutotuneCache()
    c.put("paged_attention", (B, T, M, ps, Hkv * Dh), {"t_block": 4},
          fmts=(fmt,))
    autotune.reset_cache(c)
    got = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=fmt)
    assert c.hits.get("paged_attention", 0) >= 1
    assert bool(jnp.all(got == want))


def test_ops_decode_sample_prime_vocab_falls_back(scratch_cache):
    """Regression companion for the fused decode epilogue: a cached
    v_block over a prime vocab drops to the whole-vocab untuned default
    instead of a 1-column grid — sampled tokens bitwise unchanged."""
    rng = np.random.default_rng(32)
    B, D, V = 3, 16, 47  # V=47 prime
    x = jnp.asarray(rng.normal(0, 1, (B, D)), jnp.float32)
    w = posit.pack(jnp.asarray(rng.normal(0, 1, (D, V)), jnp.float32), P16_2)
    noise = jnp.asarray(rng.gumbel(size=(B, V)), jnp.float32)
    temp = jnp.float32(0.7)
    autotune.reset_cache(autotune.AutotuneCache())
    want = ops.decode_sample(x, w, noise, temp, plan="fused", fmt_w=P16_2,
                             top_k=5)
    c = autotune.AutotuneCache()
    c.put("decode_sample", (B, D, V), {"v_block": 32}, fmts=(P16_2,))
    autotune.reset_cache(c)
    got = ops.decode_sample(x, w, noise, temp, plan="fused", fmt_w=P16_2,
                            top_k=5)
    assert c.hits.get("decode_sample", 0) >= 1
    assert bool(jnp.all(got == want))


def test_ops_paged_rejects_nondividing_t_block(scratch_cache):
    """A cached t_block that doesn't divide this launch's T must degrade
    to the largest divisor of T below it, not crash the kernel — and the
    degraded tiling stays value-neutral."""
    rng = np.random.default_rng(13)
    B, T, Hq, Hkv, Dh, ps, M = 2, 3, 4, 2, 8, 4, 4
    fmt = P16_1
    n_pages = 1 + B * M
    kp = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, Hkv * Dh)),
                     jnp.int32)
    kp = jnp.where(kp == fmt.nar_code, 0, kp).astype(jnp.int16)
    vp = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, Hkv * Dh)),
                     jnp.int32)
    vp = jnp.where(vp == fmt.nar_code, 0, vp).astype(jnp.int16)
    bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
    lengths = jnp.asarray([7, 11], jnp.int32)
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, T, Hq, Dh)), jnp.float32)
    default = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=fmt)
    c = autotune.AutotuneCache()
    c.put("paged_attention", (B, T, M, ps, Hkv * Dh), {"t_block": 2},
          fmts=(fmt,))
    autotune.reset_cache(c)
    got = ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=fmt)
    assert bool(jnp.all(got == default))


def test_ops_decode_sample_resolves_v_block(scratch_cache):
    """Cached vocab tiles for the fused decode epilogue resolve at
    dispatch and never change the sampled token: the 0 sentinel collapses
    the vocab grid, and a non-dividing tile degrades to the largest
    divisor below it."""
    rng = np.random.default_rng(21)
    B, D, V = 3, 16, 48
    x = jnp.asarray(rng.normal(0, 1, (B, D)), jnp.float32)
    w = posit.pack(jnp.asarray(rng.normal(0, 1, (D, V)), jnp.float32), P16_2)
    noise = jnp.asarray(rng.gumbel(size=(B, V)), jnp.float32)
    temp = jnp.float32(0.7)
    autotune.reset_cache(autotune.AutotuneCache())  # empty: all misses
    want = ops.decode_sample(x, w, noise, temp, plan="fused", fmt_w=P16_2,
                             top_k=5)
    for vb in (0, 32):  # whole-vocab sentinel; 32 degrades to 24
        c = autotune.AutotuneCache()
        c.put("decode_sample", (B, D, V), {"v_block": vb}, fmts=(P16_2,))
        autotune.reset_cache(c)
        got = ops.decode_sample(x, w, noise, temp, plan="fused",
                                fmt_w=P16_2, top_k=5)
        assert c.hits.get("decode_sample", 0) >= 1
        assert bool(jnp.all(got == want))


def test_ops_prefill_resolves_launch_knobs(scratch_cache):
    """Cached TPU launch knobs (dimension_semantics / VMEM budget) for the
    fused prefill kernel resolve at dispatch and are value-neutral."""
    rng = np.random.default_rng(22)
    B, C, Hq, Hkv, Dh, ps, M = 2, 4, 4, 2, 8, 4, 2
    fmt = P16_1
    F = Hkv * Dh
    n_pages = 1 + B * M
    q = jnp.asarray(rng.normal(0, 1, (B, C, Hq, Dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
    kp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                jnp.float32), fmt)
    vp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                jnp.float32), fmt)
    bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
    starts = jnp.full((B,), ps, jnp.int32)
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    autotune.reset_cache(autotune.AutotuneCache())
    want = ops.prefill_attention_paged(q, kc, vc, kp, vp, bt, starts, win,
                                       fmt_kv=fmt)
    c = autotune.AutotuneCache()
    c.put("prefill_attention", (B, C, M, ps, F),
          {"dimension_semantics": "arbitrary", "vmem_limit_mb": 64},
          fmts=(fmt,))
    autotune.reset_cache(c)
    got = ops.prefill_attention_paged(q, kc, vc, kp, vp, bt, starts, win,
                                      fmt_kv=fmt)
    assert c.hits.get("prefill_attention", 0) >= 1
    for a, b in zip(got, want):
        assert bool(jnp.all(a == b))


# ---------------------------------------------------------------------------
# the sweep itself
# ---------------------------------------------------------------------------


def test_sweep_smoke_codec():
    rng = np.random.default_rng(14)
    vals = jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)
    codes = posit.pack(vals, P16_2)

    def run(params):
        return lambda: posit_codec.decode(codes, P16_2, interpret=True,
                                          **params)

    params, ms, table = autotune.sweep("posit_codec.decode", (64, 128), run,
                                       fmts=(P16_2,), reps=1)
    assert params in list(autotune.candidates("posit_codec.decode"))
    assert ms > 0
    assert len(table) == 16  # full 4x4 codec grid, pruned or timed
    timed = [t for t in table if t["ms"] is not None]
    assert timed and all(not t["pruned"] for t in timed)
    # the winner must be bitwise the default tiling's output
    got = posit_codec.decode(codes, P16_2, interpret=True, **params)
    assert bool(jnp.all(got == posit_codec.decode(codes, P16_2,
                                                  interpret=True)))


def test_oracle_cost_positive_finite():
    import math
    for kernel in autotune.TUNABLES:
        shape = {"posit_codec.decode": (512, 512),
                 "posit_codec.encode": (512, 512),
                 "posit_matmul": (256, 256, 256),
                 "posit_matmul_grouped": (4, 128, 128, 128),
                 "paged_attention": (4, 8, 8, 16, 128),
                 "prefill_attention": (2, 64, 8, 16, 128),
                 "decode_sample": (4, 256, 4096)}[kernel]
        fmts = {"posit_matmul": (P16_2, P16_2),
                "posit_matmul_grouped": (None, P16_2)}.get(kernel, (P16_2,))
        for params in autotune.candidates(kernel):
            cost = autotune.oracle_cost(kernel, shape, params, fmts)
            assert math.isfinite(cost) and cost > 0
