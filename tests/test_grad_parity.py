"""Kernel-in-the-loop QAT: the fused plan's custom_vjp STE gradients.

The fused execution plan's forward runs the packed Pallas kernel (encode ->
in-kernel decode -> wide f32 MXU accumulate); its backward is straight-
through w.r.t. the float activations and weight masters, computed on the
decoded quantized operands.  That is exactly what the fake_quant STE plan
back-propagates, so gradients must agree — at qdot level bit-for-bit, at
model level up to the reduction-order noise of the differing forwards.

bit_exact stays forward-only: `jax.grad` through it must raise a clear
error (dispatch grad barrier) and the train-step factories must reject it
up front (QuantPolicy.require_trainable).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.formats import P8_2, P13_2, P16_2
from repro.core.quant import (PLAN_TABLE, TRAINABLE_PLANS, QuantPolicy,
                              policy_by_name)
from repro.kernels import dispatch


@pytest.fixture
def xw(rng):
    x = jnp.asarray(rng.normal(0, 1, (3, 5, 40)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (40, 24)).astype(np.float32))
    return x, w


@pytest.fixture
def exw(rng):
    x = jnp.asarray(rng.normal(0, 1, (4, 6, 40)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (4, 40, 24)).astype(np.float32))
    return x, w


def _grads(fn, *args):
    return jax.grad(lambda *a: fn(*a).sum(), argnums=(0, 1))(*args)


# ---------------------------------------------------------------------------
# qdot-level gradient parity: fused STE == fake_quant STE
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("acts", [None, P13_2],
                         ids=["float_act", "act_coded"])
def test_dense_fused_grads_match_fake_quant(xw, acts):
    """Both STE backwards are g @ wq^T / xq^T @ g on the same decoded
    quantized operands — identical cotangents."""
    x, w = xw
    policy = QuantPolicy(weights=P16_2, activations=acts)
    gx_f, gw_f = _grads(lambda a, b: dispatch.qdot(a, b, policy), x, w)
    fused = policy.with_execution("fused")
    gx_k, gw_k = _grads(lambda a, b: dispatch.qdot(a, b, fused), x, w)
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_k),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_k),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("acts", [None, P13_2],
                         ids=["float_act", "act_coded"])
def test_grouped_fused_grads_match_fake_quant(exw, acts):
    x, w = exw
    policy = QuantPolicy(weights=P16_2, activations=acts)
    gx_f, gw_f = _grads(lambda a, b: dispatch.qdot_grouped(a, b, policy),
                        x, w)
    fused = policy.with_execution("fused")
    gx_k, gw_k = _grads(lambda a, b: dispatch.qdot_grouped(a, b, fused),
                        x, w)
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_k),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_k),
                               rtol=1e-6, atol=1e-7)


def test_grouped_4d_fused_grads_match_fake_quant(rng):
    """GShard-grouped [B, E, Cg, K] activations: the batch-dim fold/unfold
    around the STE kernel is linear, so gradients still match."""
    x = jnp.asarray(rng.normal(0, 1, (2, 3, 4, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (3, 16, 8)).astype(np.float32))
    policy = QuantPolicy(weights=P16_2, activations=P13_2)
    gx_f, gw_f = _grads(lambda a, b: dispatch.qdot_grouped(a, b, policy),
                        x, w)
    fused = policy.with_execution("fused")
    gx_k, gw_k = _grads(lambda a, b: dispatch.qdot_grouped(a, b, fused),
                        x, w)
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_k),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_k),
                               rtol=1e-6, atol=1e-7)


def test_fused_grads_flow_through_bf16_casts(xw):
    """Model activations arrive in the compute dtype; the dispatch-level
    casts around the f32-only STE kernel must carry cotangents back."""
    x, w = xw
    x = x.astype(jnp.bfloat16)
    policy = QuantPolicy(weights=P16_2, activations=P13_2, execution="fused")
    gx, gw = _grads(lambda a, b: dispatch.qdot(a, b, policy)
                    .astype(jnp.float32), x, w)
    assert gx.dtype == jnp.bfloat16 and gw.dtype == jnp.float32
    assert np.isfinite(np.asarray(gx, np.float32)).all()
    assert np.isfinite(np.asarray(gw)).all()


# ---------------------------------------------------------------------------
# model-level QAT: jax.grad through a fused-plan train step
# ---------------------------------------------------------------------------


def _dense_cfg(quant):
    from repro import configs
    return configs.get_smoke("command_r_35b").replace(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
        d_ff=32, vocab_size=64, quant=quant)


def _moe_cfg(quant):
    from repro import configs
    return configs.get_smoke("qwen3_moe_235b").replace(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
        vocab_size=64, n_experts=4, top_k=2, moe_d_ff=8, quant=quant)


def _loss_grads(cfg, batch):
    from repro.models import api
    from repro.train import step as step_lib

    params = api.init(jax.random.key(0), cfg)
    loss, grads = jax.value_and_grad(
        lambda p: step_lib.loss_fn(p, batch, cfg)[0])(params)
    return float(loss), grads


@pytest.mark.parametrize("make_cfg", [_dense_cfg, _moe_cfg],
                         ids=["dense", "moe_grouped"])
def test_model_qat_grads_fused_vs_fake_quant(rng, make_cfg):
    """jax.grad through the whole LM loss succeeds on the fused plan and
    matches fake_quant within reduction-order tolerance (the two forwards
    differ only in f32 association order, the backwards are identical)."""
    policy = QuantPolicy(weights=P16_2, activations=P13_2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32),
    }
    loss_f, g_fake = _loss_grads(make_cfg(policy), batch)
    loss_k, g_fused = _loss_grads(make_cfg(policy.with_execution("fused")),
                                  batch)
    assert np.isfinite(loss_k)
    assert abs(loss_f - loss_k) < 1e-4 * max(1.0, abs(loss_f))
    for a, b in zip(jax.tree.leaves(g_fake), jax.tree.leaves(g_fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)


def test_train_step_runs_on_fused_plan(rng):
    """make_train_step under execution='fused': one full optimizer step —
    the QAT loop trains on the packed-kernel forward end to end."""
    from repro.optim import adamw, cosine_schedule
    from repro.train import step as step_lib

    cfg = _dense_cfg(QuantPolicy(weights=P16_2, activations=P13_2,
                                 execution="fused"))
    opt = adamw(cosine_schedule(1e-3, warmup=1, total=4))
    train_step = jax.jit(step_lib.make_train_step(cfg, opt, accum=2))
    state = step_lib.init_state(jax.random.key(0), cfg, opt)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)),
                              jnp.int32),
    }
    state1, metrics = train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, state1.params)
    assert max(jax.tree.leaves(moved)) > 0.0


# ---------------------------------------------------------------------------
# bit_exact is forward-only: clear errors, not silent zeros
# ---------------------------------------------------------------------------


def _bit_exact_policy():
    return QuantPolicy(weights=P13_2, activations=P13_2,
                       execution="bit_exact", pdpu_n=4)


def test_bit_exact_grad_raises_dense(xw):
    x, w = xw
    policy = _bit_exact_policy()
    with pytest.raises(ValueError, match="not differentiable"):
        jax.grad(lambda a: dispatch.qdot(a, w, policy).sum())(x)
    with pytest.raises(ValueError, match="trainable plans"):
        jax.grad(lambda b: dispatch.qdot(x, b, policy).sum())(w)
    # the forward itself stays usable (validation plan)
    assert dispatch.qdot(x, w, policy).shape == x.shape[:-1] + (w.shape[-1],)


def test_bit_exact_grad_raises_grouped(exw):
    x, w = exw
    policy = _bit_exact_policy()
    with pytest.raises(ValueError, match="not differentiable"):
        jax.grad(lambda a: dispatch.qdot_grouped(a, w, policy).sum())(x)
    assert dispatch.qdot_grouped(x, w, policy).shape == (4, 6, 24)


def test_packed_act_coded_grad_raises(xw, exw):
    """Activation-coded fused over packed int weights has no activation
    backward (the encode drops tangents): a clear error, not silent
    zeros.  The float-activation packed path keeps its exact gradient."""
    from repro.core import posit

    x, w = xw
    policy = policy_by_name("serve_fused_p16_a13")
    w_codes = posit.pack(w, P16_2)
    with pytest.raises(ValueError, match="packed int weights"):
        jax.grad(lambda a: dispatch.qdot(a, w_codes, policy).sum())(x)
    xg, wg = exw
    with pytest.raises(ValueError, match="packed int weights"):
        jax.grad(lambda a: dispatch.qdot_grouped(
            a, posit.pack(wg, P16_2), policy).sum())(xg)
    # float activations over packed weights stay differentiable (plain
    # decode + dot), and forward-only act-coded serving stays usable
    float_pol = policy_by_name("serve_fused_p16")
    gx = jax.grad(lambda a: dispatch.qdot(a, w_codes, float_pol).sum())(x)
    assert np.isfinite(np.asarray(gx)).all()
    assert dispatch.qdot(x, w_codes, policy).shape == x.shape[:-1] + (24,)


def test_train_step_rejects_bit_exact():
    """The factories fail fast — before any tracing — with the same
    trainability rule the dispatch barrier enforces lazily."""
    from repro.optim import adamw, cosine_schedule
    from repro.train import step as step_lib

    cfg = _dense_cfg(_bit_exact_policy())
    opt = adamw(cosine_schedule(1e-3, warmup=1, total=4))
    with pytest.raises(ValueError, match="not differentiable"):
        step_lib.make_train_step(cfg, opt)


def test_plan_table_and_trainability_knobs():
    assert set(PLAN_TABLE) == {"fake_quant", "fused", "bit_exact"}
    assert TRAINABLE_PLANS == ("fake_quant", "fused")
    assert QuantPolicy(weights=P16_2, execution="fused").trainable
    assert not _bit_exact_policy().trainable
    with pytest.raises(ValueError, match="trainable plans"):
        _bit_exact_policy().require_trainable()
    # require_trainable chains for the policy-construction idiom
    p = QuantPolicy(weights=P16_2).require_trainable()
    assert p.execution == "fake_quant"


def test_with_serving_activations_knob():
    p = policy_by_name("serve_fused_p16").with_serving_activations(P13_2)
    assert p.execution == "fused" and p.activations == P13_2
    assert p.weights == P16_2 and p.kv_cache == P8_2
    assert p == policy_by_name("serve_fused_p16_a13")
