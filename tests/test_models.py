"""Per-architecture smoke tests (reduced configs, CPU): one forward and one
train step with shape + finiteness asserts; decode-vs-full-forward
consistency per family; posit-quantized variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import PAPER_MIXED, SERVE_P16_KV8
from repro.models import api
from repro.models.config import ShapeConfig
from repro.optim import adamw, constant_schedule
from repro.train import step as step_lib

B, S = 2, 16


def _batch(cfg, rng, seq=S, batch=B):
    out = {}
    if cfg.frontend == "audio_stub":
        out["frames"] = jnp.asarray(rng.normal(0, 1, (batch, seq, cfg.frontend_dim)),
                                    jnp.float32)
    elif cfg.frontend == "vision_stub":
        out["patches"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq - cfg.frontend_tokens)),
            jnp.int32)
    else:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                                    jnp.int32)
    out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                                jnp.int32)
    return out


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_forward_shapes_and_finite(arch, rng):
    cfg = configs.get_smoke(arch).replace(ssm_chunk=8)
    params = api.init(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)
    kw = {"with_aux": True} if cfg.family in ("moe", "hybrid") else {}
    out = api.apply(params, batch, cfg, **kw)
    logits = out[0] if isinstance(out, tuple) else out
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_train_step(arch, rng):
    cfg = configs.get_smoke(arch).replace(ssm_chunk=8)
    opt = adamw(constant_schedule(1e-3))
    state = step_lib.init_state(jax.random.key(0), cfg, opt)
    ts = jax.jit(step_lib.make_train_step(cfg, opt, accum=2))
    batch = _batch(cfg, rng)
    state2, metrics = ts(state, batch)
    assert int(state2.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, state2.params))
    assert delta > 0


DECODE_ARCHS = [a for a in configs.ARCH_NAMES
                if not configs.get(a).is_encoder]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch, rng):
    cfg = configs.get_smoke(arch).replace(ssm_chunk=8, dtype="float32")
    params = api.init(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)
    logits, cache = api.prefill(params, {k: v for k, v in batch.items()
                                         if k != "labels"}, cfg, max_seq=S + 4)
    nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    lg1, cache = api.decode_step(params, nt, cache, cfg)
    if cfg.frontend == "vision_stub":
        ext = {"patches": batch["patches"],
               "tokens": jnp.concatenate([batch["tokens"], nt[:, None]], 1)}
    else:
        ext = {"tokens": jnp.concatenate([batch["tokens"], nt[:, None]], 1)}
    full = api.apply(params, ext, cfg.replace(ssm_chunk=17))
    err = float(jnp.max(jnp.abs(lg1 - full[:, -1])))
    assert err < 5e-2, err


def test_posit_quantized_forward_close_to_float(rng):
    cfg = configs.get_smoke("minitron_8b").replace(dtype="float32")
    params = api.init(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)
    base = api.apply(params, batch, cfg)
    quant = api.apply(params, batch, cfg.replace(quant=PAPER_MIXED))
    # mixed-precision posit matmuls stay close to the float forward
    rel = jnp.abs(quant - base) / (jnp.abs(base) + 1e-3)
    assert float(jnp.median(rel)) < 0.05


def test_posit_kv_cache_decode(rng):
    cfg = configs.get_smoke("command_r_35b").replace(
        dtype="float32", quant=SERVE_P16_KV8)
    params = api.init(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)
    logits, cache = api.prefill(params, {"tokens": batch["tokens"]}, cfg,
                                max_seq=S + 2)
    assert cache["k"].dtype == jnp.int8  # posit-coded storage
    nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    lg1, _ = api.decode_step(params, nt, cache, cfg)
    assert bool(jnp.isfinite(lg1).all())


def test_gemma3_local_global_pattern():
    cfg = configs.get("gemma3_4b")
    flags = [cfg.layer_is_global(i) for i in range(cfg.n_layers)]
    assert sum(flags) == cfg.n_layers // 6 + (1 if cfg.n_layers % 6 >= 6 else 0)
    assert flags[5] and not flags[0]  # every 6th layer is global


def test_jamba_pattern():
    cfg = configs.get("jamba_1_5_large")
    attn = [cfg.layer_is_attn(i) for i in range(cfg.n_layers)]
    moe = [cfg.layer_is_moe(i) for i in range(cfg.n_layers)]
    assert sum(attn) == cfg.n_layers // 8     # 1:7 attention:mamba
    assert sum(moe) == cfg.n_layers // 2      # MoE every other layer


def test_sliding_window_masks_differ(rng):
    """Local layers must actually restrict attention: perturbing a token
    outside the window must not change a local-layer-only model's output."""
    cfg = configs.get_smoke("gemma3_4b").replace(
        n_layers=2, global_interval=1000, sliding_window=4, dtype="float32")
    # global_interval > n_layers => every layer is local
    params = api.init(jax.random.key(0), cfg)
    t1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab_size)
    l1 = api.apply(params, {"tokens": t1}, cfg)
    l2 = api.apply(params, {"tokens": t2}, cfg)
    # position 15 attends only to >= 12 in both layers; token 0 is invisible
    assert float(jnp.max(jnp.abs(l1[0, -1] - l2[0, -1]))) < 1e-5
    # but an in-window perturbation does change it
    t3 = t1.at[0, 14].set((t1[0, 14] + 7) % cfg.vocab_size)
    l3 = api.apply(params, {"tokens": t3}, cfg)
    assert float(jnp.max(jnp.abs(l1[0, -1] - l3[0, -1]))) > 1e-6
