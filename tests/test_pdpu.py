"""PDPU fused dot-product: bit-exactness across all three implementations,
quire equivalence, and hardware-semantics properties."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import pdpu as pdj
from repro.core import posit_np as pnp
from repro.core import posit_py as ppy
from repro.core.formats import P8_2, P13_2, P16_2, PDPUConfig, PositFormat

CFGS = [
    PDPUConfig(P16_2, P16_2, N=4, w_m=14),   # Table I row
    PDPUConfig(P13_2, P16_2, N=4, w_m=14),   # paper's mixed headline
    PDPUConfig(P13_2, P16_2, N=8, w_m=14),
    PDPUConfig(P13_2, P16_2, N=8, w_m=10),
    PDPUConfig(P8_2, P8_2, N=4, w_m=10),
    PDPUConfig(P8_2, PositFormat(12, 2), N=2, w_m=20),
]


def rand_codes(rng, fmt, shape):
    return rng.integers(0, 1 << fmt.n, size=shape)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_jax_vs_numpy_bit_exact(cfg, rng):
    M = 500
    va = rand_codes(rng, cfg.fmt_in, (M, cfg.N))
    vb = rand_codes(rng, cfg.fmt_in, (M, cfg.N))
    acc = rand_codes(rng, cfg.fmt_out, (M,))
    out_np = pnp.pdpu_dot_np(va, vb, acc, cfg)
    out_j = np.asarray(pdj.pdpu_dot(jnp.asarray(va), jnp.asarray(vb),
                                    jnp.asarray(acc), cfg))
    assert (out_np == out_j).all()


@pytest.mark.parametrize("cfg", CFGS[:4], ids=lambda c: c.name)
def test_numpy_vs_staged_python_model(cfg, rng):
    M = 60
    va = rand_codes(rng, cfg.fmt_in, (M, cfg.N))
    vb = rand_codes(rng, cfg.fmt_in, (M, cfg.N))
    acc = rand_codes(rng, cfg.fmt_out, (M,))
    out = pnp.pdpu_dot_np(va, vb, acc, cfg)
    for i in range(M):
        ref = ppy.pdpu_dot_model(
            [int(x) for x in va[i]], [int(x) for x in vb[i]], int(acc[i]),
            cfg.fmt_in, cfg.fmt_out, cfg.w_m, cfg.guard_bits, cfg.sticky)
        assert ref == out[i], i


def test_wide_wm_equals_quire_oracle(rng):
    cfg = PDPUConfig(P13_2, P16_2, N=4, w_m=256)
    M = 80
    va = rand_codes(rng, cfg.fmt_in, (M, 4))
    vb = rand_codes(rng, cfg.fmt_in, (M, 4))
    acc = rand_codes(rng, cfg.fmt_out, (M,))
    out = pnp.pdpu_dot_np(va, vb, acc, cfg)
    for i in range(M):
        ref = ppy.quire_dot_exact(
            [int(x) for x in va[i]], [int(x) for x in vb[i]], int(acc[i]),
            cfg.fmt_in, cfg.fmt_out)
        assert ref == out[i]


def test_wm_error_monotone(rng):
    """Wider alignment width w_m == closer to quire-exact (paper §III-C)."""
    fmt_i, fmt_o = P13_2, P16_2
    M, N = 800, 4
    # values near 1.0 so alignment truncation is exercised
    va = pnp.encode_np(rng.normal(0, 1, (M, N)), fmt_i)
    vb = pnp.encode_np(rng.normal(0, 1, (M, N)), fmt_i)
    acc = pnp.encode_np(rng.normal(0, 1, (M,)), fmt_o)
    exact = pnp.decode_np(pnp.pdpu_dot_np(
        va, vb, acc, PDPUConfig(fmt_i, fmt_o, N=N, w_m=256)), fmt_o)
    errs = []
    for w_m in (8, 10, 14, 20):
        got = pnp.decode_np(pnp.pdpu_dot_np(
            va, vb, acc, PDPUConfig(fmt_i, fmt_o, N=N, w_m=w_m)), fmt_o)
        errs.append(np.nanmean(np.abs(got - exact)))
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]
    assert errs[3] <= 5e-6  # w_m=20 is effectively exact at these scales


def test_fused_fewer_roundings_than_discrete(rng):
    """PDPU (one rounding per chunk) beats the discrete DPU (rounding per
    op) against the exact reference — the paper's precision claim."""
    from repro.core import discrete
    fmt = P16_2
    K = 32
    a = rng.normal(0, 1, (400, K))
    b = rng.normal(0, 1, (400, K))
    aq = pnp.decode_np(pnp.encode_np(a, fmt), fmt)
    bq = pnp.decode_np(pnp.encode_np(b, fmt), fmt)
    exact = (aq * bq).sum(-1)
    fused = discrete.dpu_pdpu_fused(a, b, PDPUConfig(fmt, fmt, N=4, w_m=20))
    disc = discrete.dpu_discrete(a, b, 4, discrete.make_round_posit(fmt))
    err_f = np.abs(fused - exact).mean()
    err_d = np.abs(disc - exact).mean()
    assert err_f < err_d


# -- properties -------------------------------------------------------------

@given(data=st.data())
@settings(max_examples=60, deadline=None)
def test_zero_vb_returns_acc(data):
    cfg = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    acc = rand_codes(rng, cfg.fmt_out, (8,))
    acc[acc == cfg.fmt_out.nar_code] = 0
    va = rand_codes(rng, cfg.fmt_in, (8, 4))
    va[va == cfg.fmt_in.nar_code] = 0
    vb = np.zeros((8, 4), np.int64)
    out = pnp.pdpu_dot_np(va, vb, acc, cfg)
    assert (out == acc).all()


@given(data=st.data())
@settings(max_examples=60, deadline=None)
def test_permutation_invariance(data):
    cfg = PDPUConfig(P13_2, P16_2, N=8, w_m=14)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    va = rand_codes(rng, cfg.fmt_in, (4, 8))
    vb = rand_codes(rng, cfg.fmt_in, (4, 8))
    acc = rand_codes(rng, cfg.fmt_out, (4,))
    perm = rng.permutation(8)
    out1 = pnp.pdpu_dot_np(va, vb, acc, cfg)
    out2 = pnp.pdpu_dot_np(va[:, perm], vb[:, perm], acc, cfg)
    assert (out1 == out2).all()


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_nar_poisons(data):
    cfg = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    va = rand_codes(rng, cfg.fmt_in, (4, 4))
    vb = rand_codes(rng, cfg.fmt_in, (4, 4))
    acc = rand_codes(rng, cfg.fmt_out, (4,))
    va[2, 1] = cfg.fmt_in.nar_code
    out = pnp.pdpu_dot_np(va, vb, acc, cfg)
    assert out[2] == cfg.fmt_out.nar_code


def test_chunked_matches_stepwise(rng):
    cfg = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
    K = 24
    a = rand_codes(rng, cfg.fmt_in, (16, K))
    b = rand_codes(rng, cfg.fmt_in, (16, K))
    chunked = pnp.pdpu_chunked_dot_np(a, b, cfg)
    acc = np.zeros(16, np.int64)
    for j in range(K // 4):
        acc = pnp.pdpu_dot_np(a[:, 4*j:4*j+4], b[:, 4*j:4*j+4], acc, cfg)
    assert (chunked == acc).all()
    jx = np.asarray(pdj.pdpu_chunked_dot(jnp.asarray(a), jnp.asarray(b), cfg))
    assert (jx == chunked).all()
