"""Paged posit-KV serving runtime: kernel-vs-reference parity, paged-vs-
dense token parity across model families and KV formats, page reclamation
(no stale-key leakage), prefix sharing (refcounted pages, copy-on-write,
bit-identical to unshared serving), batched cross-slot prefill,
bucketed-prefill compile counts, and the sampler.

All Pallas kernels run in interpret mode on CPU."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import posit
from repro.core.formats import P8_2, P16_1, P16_2
from repro.core.quant import QuantPolicy, policy_by_name
from repro.kernels import ops, ref
from repro.models import api
from repro.models.paged import PagedLayout
from repro.serve import PageAllocator, Request, ServingEngine


# ---------------------------------------------------------------------------
# kernel vs reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt_kv", [None, P8_2, P16_1])
def test_paged_attention_kernel_matches_ref(rng, fmt_kv):
    B, Hq, Hkv, Dh, ps, M, P = 3, 4, 2, 8, 4, 3, 12
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, Dh)).astype(np.float32))
    kf = jnp.asarray(rng.normal(0, 1, (P, ps, Hkv * Dh)).astype(np.float32))
    vf = jnp.asarray(rng.normal(0, 1, (P, ps, Hkv * Dh)).astype(np.float32))
    if fmt_kv is not None:
        kf, vf = posit.pack(kf, fmt_kv), posit.pack(vf, fmt_kv)
    bt = jnp.asarray(rng.permutation(P)[:B * M].reshape(B, M).astype(np.int32))
    lengths = jnp.array([5, 12, 1], jnp.int32)
    window = jnp.array([1 << 30], jnp.int32)
    got = ops.paged_attention(q, kf, vf, bt, lengths, window, fmt_kv=fmt_kv)
    want = ref.paged_attention_ref(q, kf, vf, bt, lengths, window,
                                   fmt_kv=fmt_kv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_paged_attention_window_and_softcap(rng):
    B, Hq, Hkv, Dh, ps, M, P = 2, 4, 2, 8, 4, 4, 9
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, Dh)).astype(np.float32))
    kf = posit.pack(jnp.asarray(
        rng.normal(0, 1, (P, ps, Hkv * Dh)).astype(np.float32)), P8_2)
    vf = posit.pack(jnp.asarray(
        rng.normal(0, 1, (P, ps, Hkv * Dh)).astype(np.float32)), P8_2)
    bt = jnp.asarray(rng.permutation(P - 1)[:B * M].reshape(B, M) + 1,
                     dtype=jnp.int32)
    lengths = jnp.array([13, 7], jnp.int32)
    window = jnp.array([3], jnp.int32)
    got = ops.paged_attention(q, kf, vf, bt, lengths, window,
                              fmt_kv=P8_2, softcap_val=4.0)
    want = ref.paged_attention_ref(q, kf, vf, bt, lengths, window,
                                   fmt_kv=P8_2, softcap_val=4.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_paged_attention_ignores_unallocated_and_stale_pages(rng):
    """Positions >= length are masked, so block-table entries past the
    written prefix (trash page 0, reclaimed garbage) cannot contribute."""
    B, Hq, Hkv, Dh, ps, M, P = 1, 2, 1, 8, 4, 3, 6
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, Dh)).astype(np.float32))
    kf = jnp.asarray(rng.normal(0, 1, (P, ps, Hkv * Dh)).astype(np.float32))
    vf = jnp.asarray(rng.normal(0, 1, (P, ps, Hkv * Dh)).astype(np.float32))
    lengths = jnp.array([3], jnp.int32)  # only page bt[0] partially valid
    window = jnp.array([1 << 30], jnp.int32)
    out1 = ops.paged_attention(q, kf, vf, jnp.array([[2, 4, 5]], jnp.int32),
                               lengths, window)
    # same first page, wildly different (stale) tail pages -> same output
    kf2 = kf.at[4].set(999.0).at[5].set(-999.0)
    vf2 = vf.at[4].set(999.0).at[5].set(-999.0)
    out2 = ops.paged_attention(q, kf2, vf2, jnp.array([[2, 0, 0]], jnp.int32),
                               lengths, window)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# paged cache representation
# ---------------------------------------------------------------------------


def test_paged_cache_specs_shapes():
    cfg = configs.get_smoke("command_r_35b")
    layout = PagedLayout.for_slots(3, 40, 8)
    assert layout.n_pages == 3 * 5 + 1 and layout.pages_per_slot(40) == 5
    specs = api.cache_specs(cfg, 3, 40, layout)
    F = cfg.n_kv_heads * cfg.head_dim
    assert specs["k"].shape == (cfg.n_layers, 16, 8, F)
    assert specs["k"].logical_axes == ("layers", "kv_pages", None, "kv_heads")
    assert specs["block_table"].shape == (3, 5)
    cache = api.init_cache(cfg, 3, 40, layout)
    assert cache["k"].shape == (cfg.n_layers, 16, 8, F)
    # kv_pages participates in the sharding rule table
    from repro.parallel.sharding import DEFAULT_RULES
    assert "kv_pages" in DEFAULT_RULES


def test_page_allocator_free_list():
    a = PageAllocator(6)  # pages 1..5 allocatable, 0 reserved
    assert a.capacity == 5 and a.pages_free == 5
    got = a.alloc(3)
    assert got is not None and 0 not in got and len(set(got)) == 3
    assert a.pages_in_use == 3 and a.peak_in_use == 3
    assert a.alloc(3) is None  # only 2 left
    a.free(got)
    assert a.pages_free == 5 and a.peak_in_use == 3
    assert a.alloc(5) is not None and a.pages_free == 0


# ---------------------------------------------------------------------------
# engine: paged-vs-dense token parity across families and KV formats
# ---------------------------------------------------------------------------


def _tiny(arch, quant):
    return configs.get_tiny_serving(arch, quant)


def _serve(cfg, params, prompts, max_new=3, **kw):
    engine = ServingEngine(cfg, params, batch_slots=2, max_seq=32, **kw)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    done = engine.run()
    return {r.rid: r.out_tokens for r in done}, engine


@pytest.mark.parametrize("arch", ["command_r_35b", "mamba2_1_3b",
                                  "jamba_1_5_large", "qwen3_moe_235b"])
@pytest.mark.parametrize("kv", ["f32", "coded"])
def test_paged_vs_dense_token_parity(rng, arch, kv):
    """Same requests, same seeds -> identical output tokens across
    {dense, paged} x {f32, posit-coded} KV, per family."""
    quant = QuantPolicy() if kv == "f32" else \
        QuantPolicy(weights=P16_2, kv_cache=P8_2)
    cfg = _tiny(arch, quant)
    params = api.init(jax.random.key(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3)]
    out_paged, ep = _serve(cfg, params, prompts, page_size=4)
    out_dense, _ = _serve(cfg, params, prompts, paged=False)
    assert out_paged == out_dense
    assert set(out_paged) == {0, 1, 2}
    assert all(len(t) == 3 for t in out_paged.values())
    if cfg.family != "ssm":
        assert ep.paged and ep.pages_in_use == 0  # all reclaimed
        if kv == "coded":
            assert ep.cache["k"].dtype == jnp.int8  # pages at code width


def test_slot_reuse_after_retirement_no_stale_keys(rng):
    """Page reclamation: a request served through recycled pages must see
    exactly what it would see on a fresh engine (stale keys from retired
    requests never enter its attention)."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(1), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7, 9, 4)]
    # one slot, minimal pool: every request recycles its predecessor's pages
    engine = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=4, n_pages=6)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    recycled = {r.rid: r.out_tokens for r in engine.run()}
    assert engine.pages_in_use == 0
    for i, p in enumerate(prompts):
        fresh = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                              page_size=4, n_pages=6)
        fresh.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        want = fresh.run()[0].out_tokens
        assert recycled[i] == want, i


def test_oversubscribed_pool_waits_for_reclamation(rng):
    """A pool smaller than the queue's worst case admits lazily (requests
    wait for reclaimed pages) but still serves everything, identically."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 8, 7, 6)]
    full, _ = _serve(cfg, params, prompts, page_size=4)
    # 4 pages: exactly one in-flight request's worth ((9+3-1)//4 + 1 = 3)
    tight, eng = _serve(cfg, params, prompts, page_size=4, n_pages=5)
    assert tight == full
    assert eng.allocator.peak_in_use <= 4


def test_submit_rejects_requests_exceeding_max_seq(rng):
    """Writes past max_seq would wrap into the slot's last page (paged) or
    be silently dropped (dense) — submission must reject them up front."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=16)
    with pytest.raises(ValueError, match="max_seq"):
        engine.submit(Request(rid=0, prompt=np.arange(30, dtype=np.int32),
                              max_new_tokens=8))
    with pytest.raises(ValueError, match="empty prompt"):
        engine.submit(Request(rid=1, prompt=np.array([], np.int32)))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(Request(rid=2, prompt=np.arange(4, dtype=np.int32),
                              max_new_tokens=0))
    # the boundary case fits exactly: 25 + 8 - 1 == 32 positions
    engine.submit(Request(rid=3, prompt=np.arange(25, dtype=np.int32),
                          max_new_tokens=8))
    done = engine.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 8


def test_request_larger_than_pool_raises(rng):
    """A request that can never fit the pool fails fast at submit — not
    mid-run after other requests were already served."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=4, n_pages=3)
    with pytest.raises(ValueError, match="pages"):
        engine.submit(Request(rid=0, prompt=np.arange(20, dtype=np.int32),
                              max_new_tokens=8))
    assert engine.queue == []


def test_interleaved_chunked_prefill_matches_admission_prefill(rng):
    """prefill_chunks_per_step=1 interleaves prompt chunks with ongoing
    decode; mid-prefill slots must be fully isolated from the decode step
    (recurrent SSM/conv state and pages untouched) — outputs identical to
    completing prefill at admission."""
    for arch in ("command_r_35b", "mamba2_1_3b", "jamba_1_5_large"):
        cfg = _tiny(arch, QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (11, 6, 9)]
        at_admission, _ = _serve(cfg, params, prompts, max_new=4,
                                 page_size=4)
        interleaved, _ = _serve(cfg, params, prompts, max_new=4,
                                page_size=4, prefill_chunks_per_step=1)
        assert interleaved == at_admission, arch


# ---------------------------------------------------------------------------
# prefix sharing: refcounted pages, copy-on-write, token parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["command_r_35b", "qwen3_moe_235b",
                                  "jamba_1_5_large"])
@pytest.mark.parametrize("kv", ["f32", "coded"])
def test_prefix_sharing_token_parity(rng, arch, kv):
    """Requests sharing a prompt prefix map the donor's pages (refcounted)
    and produce bit-identical tokens to unshared serving, across attention
    families and KV formats.  Chain: sharing stops at boundaries of the
    request's own chunk decomposition, so the tail's chunking — and every
    logit — matches an unshared run exactly."""
    quant = QuantPolicy(weights=P16_2) if kv == "f32" else \
        QuantPolicy(weights=P16_2, kv_cache=P8_2)
    cfg = _tiny(arch, quant)
    params = api.init(jax.random.key(0), cfg)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([base, rng.integers(0, cfg.vocab_size, t)
                               .astype(np.int32)]) for t in (3, 5)]
    prompts.append(prompts[0].copy())  # exact duplicate
    kw = dict(max_new=4, page_size=4, prefill_buckets=(4, 1))
    shared, es = _serve(cfg, params, prompts, **kw)
    unshared, eu = _serve(cfg, params, prompts, prefix_sharing=False, **kw)
    assert shared == unshared
    assert es.stats["shared_admissions"] >= 2
    assert es.stats["pages_shared"] >= 4
    # the whole point: fewer fresh page grants than unshared serving
    assert es.allocator.total_allocs < eu.allocator.total_allocs
    # everything reclaims: refcounts, holds, and index all drain
    assert es.pages_in_use == 0 and not es.prefix_index and not es._held


def test_cow_fork_never_mutates_shared_page(rng):
    """An exact-duplicate request maps the donor's partially-filled tail
    page; its divergent write (last prompt token, then decode) must fork a
    private copy and leave the donor's page bit-identical — pinned with a
    direct page-pool readback, not just token parity."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)

    engine = ServingEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=4, prefill_buckets=(4, 1))
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=12))
    engine.step()  # donor prefilled + decoding; index holds its pages
    donor_pages = list(engine.slot_pages[0])
    tail_page = donor_pages[2]  # positions 8..11: prompt tail + decode
    snap_k = np.asarray(engine.cache["k"][:, tail_page])
    snap_v = np.asarray(engine.cache["v"][:, tail_page])

    engine.submit(Request(rid=1, prompt=prompt, max_new_tokens=3))
    engine.step()  # sharer admitted, COW-forks the tail page, decodes
    assert engine.stats["cow_forks"] == 1
    # the sharer's block table diverged from the donor's on the tail page
    assert engine.block_tables[1, 2] != tail_page
    assert engine.block_tables[1, 0] == donor_pages[0]
    assert engine.block_tables[1, 1] == donor_pages[1]
    # direct pool readback: the shared page holds exactly the donor's KV
    # below the sharer's trusted range (positions 8..9 of the prompt);
    # the donor keeps appending its own decode KV in place past it
    ps = engine.layout.page_size
    tail_lo = 2 * ps
    valid = min(int(engine.lengths[0]), 11) - tail_lo  # prompt rows only
    np.testing.assert_array_equal(
        np.asarray(engine.cache["k"][:, tail_page, :2]), snap_k[:, :2])
    np.testing.assert_array_equal(
        np.asarray(engine.cache["v"][:, tail_page, :2]), snap_v[:, :2])
    assert valid >= 2

    out = {r.rid: r.out_tokens for r in engine.run()}
    for rid, mn in ((0, 12), (1, 3)):
        fresh = ServingEngine(cfg, params, batch_slots=2, max_seq=32,
                              page_size=4, prefill_buckets=(4, 1))
        fresh.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mn))
        assert out[rid] == fresh.run()[0].out_tokens, rid
    assert engine.pages_in_use == 0


def test_shared_prefix_pages_allocated_once(rng):
    """N requests with the same prompt allocate the shared-prefix pages
    once: total fresh grants stay near a single request's demand (the
    acceptance bar the bench gate also checks)."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    # 46-token prompt over 4-token pages: 11 full prefix pages share, the
    # tail page is COW-forked per sharer, decode stays inside it
    prompt = rng.integers(0, cfg.vocab_size, 46).astype(np.int32)

    def allocs(n_req, sharing):
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=48,
                            page_size=4, prefill_buckets=(16, 4, 1),
                            prefix_sharing=sharing)
        for i in range(n_req):
            eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=2))
        done = eng.run()
        assert len(done) == n_req and eng.pages_in_use == 0
        return eng.allocator.total_allocs

    single = allocs(1, True)
    assert allocs(4, True) < 1.5 * single < allocs(4, False)


def test_held_prefix_pages_yield_to_blocked_admission(rng):
    """Pages held for a queued sharer must not starve a non-sharing
    request that needs the whole pool: when admission stalls with nothing
    in flight, holds yield (liveness over sharing) and every request still
    serves, token-identical to fresh runs."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    donor = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    sharer = np.concatenate([donor[:8],
                             rng.integers(0, cfg.vocab_size, 2)
                             .astype(np.int32)])
    big = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)  # 6 pages

    engine = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=4, n_pages=7, prefill_buckets=(4, 1))
    engine.submit(Request(rid=0, prompt=donor, max_new_tokens=2))
    # donor retires first; its prefix pages are held for rid=2's benefit
    # while rid=1 (queued ahead) needs the entire pool
    engine.submit(Request(rid=1, prompt=big, max_new_tokens=4))
    engine.submit(Request(rid=2, prompt=sharer, max_new_tokens=2))
    out = {r.rid: r.out_tokens for r in engine.run()}
    assert set(out) == {0, 1, 2}
    for rid, prompt, mn in ((0, donor, 2), (1, big, 4), (2, sharer, 2)):
        fresh = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                              page_size=4, n_pages=7,
                              prefill_buckets=(4, 1))
        fresh.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mn))
        assert out[rid] == fresh.run()[0].out_tokens, rid
    assert engine.pages_in_use == 0 and not engine._held


def test_page_allocator_refcounts():
    """Sharing takes references, free drops them, recycle only at zero;
    double frees and shares of free pages raise instead of corrupting."""
    a = PageAllocator(6)
    got = a.alloc(2)
    assert a.total_allocs == 2 and all(a.refcount(p) == 1 for p in got)
    a.share(got)
    assert all(a.refcount(p) == 2 for p in got)
    assert a.free(got) == []          # refs survive: nothing recycled
    assert a.pages_in_use == 2
    assert sorted(a.free(got)) == sorted(got)  # last ref: recycled
    assert a.pages_in_use == 0
    with pytest.raises(ValueError, match="double free"):
        a.free([got[0]])
    with pytest.raises(ValueError, match="share free"):
        a.share([got[0]])


def test_joint_oversubscription_with_sharing(rng):
    """Two requests that individually fit but jointly oversubscribe the
    pool: admission accounts the full private demand (including the
    copy-on-write fork reserve) up front instead of checking each request
    in isolation, so the sharer never allocates mid-flight — both serve to
    completion, token-identical to fresh runs, and the pool drains."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    # donor needs 4 pages (11 + 6 - 2 -> positions 0..15), duplicate needs
    # 4 alone: jointly 8 > capacity 6, individually 4 <= 6
    engine = ServingEngine(cfg, params, batch_slots=2, max_seq=16,
                           page_size=4, n_pages=7, prefill_buckets=(4, 1))
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    engine.submit(Request(rid=1, prompt=prompt, max_new_tokens=6))
    assert engine.pages_promised == 8 > engine.allocator.capacity
    out = {r.rid: r.out_tokens for r in engine.run()}
    assert len(out) == 2
    fresh = ServingEngine(cfg, params, batch_slots=1, max_seq=16,
                          page_size=4, n_pages=7, prefill_buckets=(4, 1))
    fresh.submit(Request(rid=9, prompt=prompt, max_new_tokens=6))
    want = fresh.run()[0].out_tokens
    assert out[0] == want and out[1] == want
    assert engine.pages_in_use == 0 and not engine._held


# ---------------------------------------------------------------------------
# bucketed prefill: compile count O(#buckets), not O(#lengths)
# ---------------------------------------------------------------------------


def test_prefill_compiles_per_bucket_not_per_length(rng):
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=4, prefill_buckets=(16, 4, 1),
                           batched_prefill=False)
    lengths = [3, 5, 7, 9, 11, 13, 6, 10, 14, 8]  # 10 distinct lengths
    for i, n in enumerate(lengths):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=2))
    done = engine.run()
    assert len(done) == len(lengths)
    assert engine._chunk._cache_size() <= len(engine.prefill_buckets)


def test_batched_prefill_compiles_per_bucket_not_per_slot_count(rng):
    """Cross-slot batched prefill keeps the compile count O(#buckets): the
    [batch_slots, chunk] program shape is fixed however many slots fill
    per step (non-group rows are masked), so a mixed-length queue over 4
    slots with variable group sizes traces at most one program per
    bucket — and the fleet actually batches (multi-slot groups occur)."""
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=4, max_seq=32,
                           page_size=4, prefill_buckets=(16, 4, 1),
                           prefill_chunks_per_step=1)
    lengths = [3, 5, 7, 9, 11, 13, 6, 10, 14, 8, 12, 4]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=2))
    done = engine.run()
    assert len(done) == len(lengths)
    assert engine._chunk_batched._cache_size() <= len(engine.prefill_buckets)
    assert engine._chunk._cache_size() == 0  # per-slot path never used
    assert max(engine.stats["prefill_batch_sizes"]) > 1  # real batching
    # parity: the batched fleet decodes exactly what per-slot serving does
    per_slot = ServingEngine(cfg, params, batch_slots=4, max_seq=32,
                             page_size=4, prefill_buckets=(16, 4, 1),
                             prefill_chunks_per_step=1,
                             batched_prefill=False)
    for i, p in enumerate(prompts):
        per_slot.submit(Request(rid=i, prompt=p, max_new_tokens=2))
    got = {r.rid: r.out_tokens for r in per_slot.run()}
    assert got == {r.rid: r.out_tokens for r in done}


def test_batched_prefill_auto_disabled_for_droppy_moe_capacity():
    """Routed-MoE capacity is computed over the whole batched chunk, so
    batch composition could displace active tokens when the capacity
    factor is not drop-proof — the engine falls back to per-slot prefill
    there unless explicitly overridden; drop-proof configs keep batching."""
    droppy = configs.get_smoke("qwen3_moe_235b").replace(
        quant=QuantPolicy(weights=P16_2, kv_cache=P8_2))
    assert droppy.capacity_factor * droppy.top_k < droppy.n_experts
    params = api.init(jax.random.key(0), droppy)
    eng = ServingEngine(droppy, params, batch_slots=2, max_seq=32,
                        page_size=4)
    assert eng.batched_prefill is False
    forced = ServingEngine(droppy, params, batch_slots=2, max_seq=32,
                           page_size=4, batched_prefill=True)
    assert forced.batched_prefill is True
    proof = _tiny("qwen3_moe_235b", QuantPolicy(weights=P16_2,
                                                kv_cache=P8_2))
    assert proof.capacity_factor * proof.top_k >= proof.n_experts
    eng2 = ServingEngine(proof, api.init(jax.random.key(0), proof),
                         batch_slots=2, max_seq=32, page_size=4)
    assert eng2.batched_prefill is True


def test_ssm_buckets_respect_ssd_chunk():
    cfg = _tiny("mamba2_1_3b", QuantPolicy())  # ssm_chunk == 8
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                           prefill_buckets=(48, 12, 4))
    # 48 = 6*8 kept, 12 dropped (not <= 8, not divisible), 4 kept, 1 added
    assert engine.prefill_buckets == (48, 4, 1)


# ---------------------------------------------------------------------------
# sampling: the greedy knob is honored, non-greedy is reproducible
# ---------------------------------------------------------------------------


def test_sampling_reproducible_and_seeded(rng):
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9)]
    kw = dict(greedy=False, temperature=30.0, top_k=16, max_new=6)
    s1, _ = _serve(cfg, params, prompts, **kw)
    s2, _ = _serve(cfg, params, prompts, **kw)
    assert s1 == s2  # fixed per-request seed -> byte-identical streams
    g, _ = _serve(cfg, params, prompts, max_new=6)
    assert s1 != g  # at temperature 30 sampling actually explores
    s3, _ = _serve(cfg, params, prompts, base_seed=1234, **kw)
    assert s1 != s3  # a different engine seed moves the streams
    # sampling is layout-independent: paged and dense draw the same tokens
    s_dense, _ = _serve(cfg, params, prompts, paged=False, **kw)
    assert s1 == s_dense


def test_request_seed_overrides_rid(rng):
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)

    def one(rid, seed):
        e = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                          greedy=False, temperature=30.0, top_k=16)
        e.submit(Request(rid=rid, prompt=prompt, max_new_tokens=6, seed=seed))
        return e.run()[0].out_tokens

    assert one(0, seed=42) == one(99, seed=42)  # seed pins the stream


# ---------------------------------------------------------------------------
# storage accounting
# ---------------------------------------------------------------------------


def test_kv_cache_summary_splits_metadata(rng):
    cfg = _tiny("command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=4)
    s = engine.kv_cache_summary()
    assert s["kv_bytes"] == int(engine.cache["k"].nbytes
                                + engine.cache["v"].nbytes)
    assert s["metadata_bytes"] == int(engine.cache["length"].nbytes
                                      + engine.cache["block_table"].nbytes)
    assert s["total_bytes"] == s["kv_bytes"] + s["metadata_bytes"]
    assert s["kv_bytes_in_use"] == 0  # nothing admitted yet
    assert engine.kv_cache_bytes() == s["total_bytes"]
    engine.submit(Request(rid=0, prompt=rng.integers(0, 64, 6).astype(np.int32),
                          max_new_tokens=8))
    engine.step()
    used = engine.kv_cache_summary()["kv_bytes_in_use"]
    page_bytes = s["kv_bytes"] // engine.layout.n_pages
    assert used == engine.pages_in_use * page_bytes > 0
    summary = engine.execution_summary()
    assert summary["paged"] is True and summary["page_size"] == 4
    assert summary["pages_in_use"] == engine.pages_in_use
    assert summary["kv_bytes"] + summary["metadata_bytes"] \
        == summary["kv_cache_bytes"]
