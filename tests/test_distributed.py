"""Distribution tests: sharding-rule unit tests on synthetic meshes, and
multi-device integration via subprocesses (the only way to get >1 device
in a CPU test without polluting the session's device count)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.parallel import sharding

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV8 = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(REPO, "src")}


def _run(code: str, env=ENV8, timeout=600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# sharding rules (pure logic — works on 1 device via Mesh abstract use)
# ---------------------------------------------------------------------------

def test_spec_for_divisibility_and_fallback():
    import jax
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1),
                             ("data", "model"))
    # synthetic mesh shape checks go through mesh.shape; fabricate via Mesh
    # of 1x1 (all rules drop to None because axis size 1)
    spec = sharding.spec_for((64, 128), ("embed", "heads"), mesh)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_spec_for_on_8dev():
    out = _run("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel import sharding
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # divisible: shard both
        s = sharding.spec_for((64, 128), ("embed", "heads"), mesh)
        assert s == P("data", "model"), s
        # non-divisible heads dim (129 % 4 != 0) -> replicated
        s = sharding.spec_for((64, 129), ("embed", "heads"), mesh)
        assert s == P("data", None), s
        # tuple axis with shrink: batch=2 on (pod,data) mesh missing pod
        s = sharding.spec_for((2, 16), ("batch", None), mesh)
        assert s == P("data", None), s
        # axis used once only
        s = sharding.spec_for((8, 8), ("heads", "mlp"), mesh)
        assert s == P("model", None), s
        print("SPECS-OK")
    """)
    assert "SPECS-OK" in out


def test_train_step_multidevice_matches_single():
    """Loss trajectory on a 2x2 mesh must match the 1-device run."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.models.config import ShapeConfig
        from repro.models.module import ParamSpec, abstract_params
        from repro.optim import adamw, constant_schedule
        from repro.train import step as step_lib
        from repro.parallel import sharding as sh
        from repro.data import Pipeline, DataConfig

        cfg = configs.get_smoke("minitron_8b").replace(dtype="float32")
        shape = ShapeConfig("t", 32, 8, "train")
        opt = adamw(constant_schedule(1e-3))
        pipe = Pipeline(cfg, shape)
        batches = [jax.tree.map(jnp.asarray, pipe.batch_at(s)) for s in range(3)]

        def run(mesh):
            state = step_lib.init_state(jax.random.key(0), cfg, opt)
            ts = jax.jit(step_lib.make_train_step(cfg, opt, accum=2))
            losses = []
            if mesh is None:
                for b in batches:
                    state, m = ts(state, b)
                    losses.append(float(m["loss"]))
            else:
                with mesh:
                    for b in batches:
                        state, m = ts(state, b)
                        losses.append(float(m["loss"]))
            return losses

        l1 = run(None)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        l2 = run(mesh)
        print("L1", l1)
        print("L2", l2)
        assert np.allclose(l1, l2, rtol=2e-4, atol=2e-4), (l1, l2)
        print("MULTIDEV-OK")
    """
    assert "MULTIDEV-OK" in _run(code)


def test_compressed_grad_allreduce_2pods():
    """shard_map posit-compressed cross-pod training step runs and learns."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.models.config import ShapeConfig
        from repro.optim import adamw, constant_schedule
        from repro.train import step as step_lib
        from repro.data import Pipeline

        cfg = configs.get_smoke("minitron_8b").replace(dtype="float32")
        shape = ShapeConfig("t", 16, 8, "train")
        opt = adamw(constant_schedule(2e-3))
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ts = step_lib.make_train_step_compressed(cfg, opt, mesh)
        state = step_lib.init_state(jax.random.key(0), cfg, opt)
        err = ts.init_err(state.params)
        pipe = Pipeline(cfg, shape)
        losses = []
        carry = (state, err)
        with mesh:
            tsj = jax.jit(ts)
            for s in range(8):
                carry, m = tsj(carry, jax.tree.map(jnp.asarray, pipe.batch_at(s)))
                losses.append(float(m["loss"]))
        print("losses", losses)
        assert losses[-1] < losses[0]
        # HLO really ships int8 over the pod axis
        lowered = jax.jit(ts).lower(carry, jax.tree.map(jnp.asarray, pipe.batch_at(0)))
        txt = lowered.compile().as_text()
        assert ("s8[" in txt and ("all-to-all" in txt or "all-gather" in txt))
        print("COMPRESS-OK")
    """
    assert "COMPRESS-OK" in _run(code)


def test_dryrun_cell_small_mesh():
    """The dry-run builder compiles a smoke arch on an 8-device 3-axis mesh
    (mini multi-pod) for all three step kinds."""
    code = """
        import jax
        from repro import configs
        from repro.launch import dryrun
        from repro.models.config import ShapeConfig
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = configs.get_smoke("qwen3_moe_235b")
        for kind, shape in [("train", ShapeConfig("t", 64, 8, "train")),
                            ("prefill", ShapeConfig("p", 64, 8, "prefill")),
                            ("decode", ShapeConfig("d", 64, 8, "decode"))]:
            lowered = dryrun.build_lowered(cfg, shape, mesh)
            compiled = lowered.compile()
            rec = dryrun.analyze(lowered, compiled, cfg, shape, mesh, 0.0)
            assert rec["roofline"]["hlo_flops_per_dev"] > 0
            print(kind, "ok", rec["roofline"]["dominant"])
        print("DRYRUN-OK")
    """
    assert "DRYRUN-OK" in _run(code)


def test_elastic_restore_across_meshes():
    """Checkpoint written on a (2,4) mesh restores onto (4,2) and 1-dev."""
    code = """
        import tempfile, jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mesh1 = jax.make_mesh((2, 4), ("data", "model"))
            w1 = jax.device_put(tree["w"], NamedSharding(mesh1, P("data", "model")))
            mgr.save(1, {"w": w1})
            mesh2 = jax.make_mesh((4, 2), ("data", "model"))
            sh2 = {"w": NamedSharding(mesh2, P("model", "data"))}
            got = mgr.restore(1, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}, sh2)
            assert (np.asarray(got["w"]) == np.asarray(tree["w"])).all()
            assert got["w"].sharding == sh2["w"]
        print("ELASTIC-OK")
    """
    assert "ELASTIC-OK" in _run(code)
