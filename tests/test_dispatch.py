"""Execution-plan dispatch layer: cross-path parity (interpret mode on CPU),
packed-weight transparency, and the pack -> checkpoint -> load -> serve
round trip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pdpu as pdpu_core
from repro.core import posit
from repro.core.formats import P8_2, P13_2, P16_2
from repro.core.quant import QuantPolicy, policy_by_name
from repro.kernels import dispatch


@pytest.fixture
def xw(rng):
    x = jnp.asarray(rng.normal(0, 1, (3, 5, 40)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (40, 24)).astype(np.float32))
    return x, w


# ---------------------------------------------------------------------------
# plan parity
# ---------------------------------------------------------------------------

def test_fake_quant_matches_fused(xw):
    """Both plans compute on the same decoded posit values with f32
    accumulation — only the tiling order can differ."""
    x, w = xw
    policy = QuantPolicy(weights=P16_2, activations=P13_2)
    a = dispatch.qdot(x, w, policy)
    b = dispatch.qdot(x, w, policy.with_execution("fused"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_fused_packed_weights_bitwise_equal_float_weights(xw):
    """Packing is the same single rounding the fused path applies on the
    fly, so packed vs float weights are indistinguishable downstream."""
    x, w = xw
    policy = QuantPolicy(weights=P16_2, activations=P13_2, execution="fused")
    got_f = dispatch.qdot(x, w, policy)
    got_p = dispatch.qdot(x, posit.pack(w, P16_2), policy)
    assert (np.asarray(got_f) == np.asarray(got_p)).all()


def test_fused_float_activations_fast_path(xw):
    """activations=None: float x times in-kernel-decoded posit weights."""
    x, w = xw
    policy = QuantPolicy(weights=P16_2, execution="fused")
    w_codes = posit.pack(w, P16_2)
    got = dispatch.qdot(x, w_codes, policy)
    want = jnp.dot(x, posit.unpack(w_codes, P16_2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_fake_quant_accepts_packed_weights(xw):
    """A packed checkpoint served with the default plan decodes once and
    matches on-the-fly fake quantization of float masters exactly."""
    x, w = xw
    policy = QuantPolicy(weights=P16_2)
    got = dispatch.qdot(x, posit.pack(w, P16_2), policy)
    want = dispatch.qdot(x, w, policy)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_bit_exact_matches_pdpu_matmul_exact(rng):
    """Dispatch bit_exact == the chunked-PDPU oracle, code for code."""
    x = jnp.asarray(rng.normal(0, 1, (4, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (8, 6)).astype(np.float32))
    policy = QuantPolicy(weights=P13_2, activations=P13_2,
                         execution="bit_exact", pdpu_n=4)
    got = dispatch.qdot(x, w, policy, out_dtype=jnp.float32)
    cfg = policy.pdpu_config()
    want_codes = pdpu_core.pdpu_matmul_exact(
        posit.encode(x, cfg.fmt_in), posit.encode(w, cfg.fmt_in), cfg)
    want = posit.decode(want_codes, cfg.fmt_out)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_bit_exact_pads_ragged_contraction(rng):
    """K not divisible by the chunk size N pads with exact posit zeros."""
    x = jnp.asarray(rng.normal(0, 1, (2, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (10, 3)).astype(np.float32))
    policy = QuantPolicy(weights=P13_2, activations=P13_2,
                         execution="bit_exact", pdpu_n=4)
    got = dispatch.qdot(x, w, policy, out_dtype=jnp.float32)
    cfg = policy.pdpu_config()
    a = jnp.pad(posit.encode(x, cfg.fmt_in), ((0, 0), (0, 2)))
    b = jnp.pad(posit.encode(w, cfg.fmt_in), ((0, 2), (0, 0)))
    want = posit.decode(pdpu_core.pdpu_matmul_exact(a, b, cfg), cfg.fmt_out)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_execution_plan_validation():
    with pytest.raises(ValueError):
        QuantPolicy(execution="warp_speed")
    with pytest.raises(ValueError):
        QuantPolicy(execution="fused")  # no weights format
    with pytest.raises(ValueError):
        # packed codes under a policy without a weights format
        dispatch.qdot(jnp.ones((2, 4)), jnp.zeros((4, 3), jnp.int16),
                      QuantPolicy())


# ---------------------------------------------------------------------------
# grouped dispatch edge cases
# ---------------------------------------------------------------------------


def test_grouped_ragged_tiles_pad_with_posit_zero(rng):
    """Tile sizes that divide neither M, N nor K: the kernel pads blocks
    internally and posit code 0 decodes to exact 0.0, so ragged shapes
    match the un-tiled reference exactly (up to f32 association order)."""
    from repro.core.formats import PositFormat
    from repro.kernels import posit_matmul as pm

    x = jnp.asarray(rng.normal(0, 1, (3, 7, 41)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (3, 41, 21)).astype(np.float32))
    a_codes = posit.pack(x, P13_2)
    w_codes = posit.pack(w, P16_2)
    got = pm.posit_matmul_grouped(a_codes, w_codes, P13_2, P16_2, None,
                                  bm=4, bn=16, bk=16, interpret=True)
    want = jnp.einsum("ecd,edf->ecf", posit.unpack(a_codes, P13_2),
                      posit.unpack(w_codes, P16_2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # fmt_out set: the single output encode runs per expert tile
    enc = pm.posit_matmul_grouped(a_codes, w_codes, P13_2, P16_2, P16_2,
                                  bm=4, bn=16, bk=16, interpret=True)
    assert enc.dtype == jnp.int16
    assert (np.asarray(enc) ==
            np.asarray(posit.encode(want, P16_2)).astype(np.int16)).all()


def test_grouped_packed_without_weights_format_raises():
    policy = QuantPolicy()  # no formats set
    with pytest.raises(ValueError, match="weights"):
        dispatch.qdot_grouped(jnp.ones((2, 3, 4)),
                              jnp.zeros((2, 4, 5), jnp.int16), policy)


def test_grouped_rank_validation():
    policy = QuantPolicy(weights=P16_2)
    x3, w3 = jnp.ones((2, 3, 4)), jnp.ones((2, 4, 5))
    with pytest.raises(ValueError, match="3-D"):
        dispatch.qdot_grouped(x3, jnp.ones((4, 5)), policy)  # 2-D weights
    with pytest.raises(ValueError, match=r"\[E, C, K\]"):
        dispatch.qdot_grouped(jnp.ones((3, 4)), w3, policy)  # 2-D acts
    with pytest.raises(ValueError, match="mismatch"):
        dispatch.qdot_grouped(jnp.ones((2, 3, 6)), w3, policy)  # bad K
    with pytest.raises(ValueError, match="mismatch"):
        dispatch.qdot_grouped(jnp.ones((3, 3, 4)), w3, policy)  # bad E
    # qdot itself still rejects stacked weights
    with pytest.raises(ValueError, match="2-D"):
        dispatch.qdot(jnp.ones((3, 4)), w3, policy)


@pytest.mark.parametrize("plan", ["fake_quant", "fused", "bit_exact"])
def test_grouped_out_dtype_casting(rng, plan):
    """out_dtype is honored by every plan; default returns x.dtype."""
    x = jnp.asarray(rng.normal(0, 1, (2, 3, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (2, 8, 4)).astype(np.float32))
    policy = QuantPolicy(weights=P13_2, activations=P13_2,
                         execution=plan, pdpu_n=4)
    out = dispatch.qdot_grouped(x, w, policy)
    assert out.dtype == x.dtype
    out_bf = dispatch.qdot_grouped(x.astype(jnp.bfloat16), w, policy)
    assert out_bf.dtype == jnp.bfloat16
    out_cast = dispatch.qdot_grouped(x, w, policy, out_dtype=jnp.bfloat16)
    assert out_cast.dtype == jnp.bfloat16


def test_grouped_fake_quant_matches_per_expert_qdot(rng):
    """qdot_grouped(fake_quant) is exactly E independent qdots."""
    x = jnp.asarray(rng.normal(0, 1, (3, 4, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (3, 10, 6)).astype(np.float32))
    policy = QuantPolicy(weights=P16_2, activations=P13_2)
    got = dispatch.qdot_grouped(x, w, policy)
    for e in range(3):
        want = dispatch.qdot(x[e], w[e], policy)
        assert (np.asarray(got[e]) == np.asarray(want)).all(), e


# ---------------------------------------------------------------------------
# model-level parity + pack -> checkpoint -> load -> serve round trip
# ---------------------------------------------------------------------------

def _tiny_cfg(quant):
    from repro import configs
    return configs.get_smoke("command_r_35b").replace(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
        d_ff=32, vocab_size=64, quant=quant)


def test_model_fake_vs_fused_logits_parity(rng):
    """Whole-model forward: fused over packed codes ~= fake_quant on float
    masters (same quantized function; only reduction order differs)."""
    from repro.models import api

    cfg_fake = _tiny_cfg(QuantPolicy(weights=P16_2))
    cfg_fused = _tiny_cfg(QuantPolicy(weights=P16_2, execution="fused"))
    params = api.init(jax.random.key(1), cfg_fake)
    packed = api.pack_params(params, cfg_fused)
    tokens = jnp.asarray(rng.integers(0, 64, (2, 6)), jnp.int32)
    logits_fake = api.apply(params, {"tokens": tokens}, cfg_fake)
    logits_fused = api.apply(packed, {"tokens": tokens}, cfg_fused)
    np.testing.assert_allclose(np.asarray(logits_fake),
                               np.asarray(logits_fused),
                               rtol=1e-4, atol=1e-5)


def test_pack_checkpoint_load_serve_roundtrip(rng, tmp_path):
    """pack_params -> CheckpointManager.save(extra=pack_manifest) ->
    ServingEngine.from_checkpoint -> fused continuous batching on CPU."""
    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.models import api
    from repro.serve import Request, ServingEngine

    cfg = configs.get_smoke("command_r_35b").replace(
        quant=policy_by_name("serve_fused_p16"))
    params = api.init(jax.random.key(0), cfg)
    packed = api.pack_params(params, cfg)
    assert api.weight_bytes(packed) < api.weight_bytes(params)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, packed, extra=api.pack_manifest(cfg))
    assert mgr.read_manifest(3)["extra"]["packed_weights"] is True

    engine = ServingEngine.from_checkpoint(cfg, str(tmp_path),
                                           batch_slots=2, max_seq=32)
    # the restored tree is the packed tree, bit for bit
    for a, b in zip(jax.tree.leaves(engine.params), jax.tree.leaves(packed)):
        assert a.dtype == b.dtype
        assert (np.asarray(a) == np.asarray(b)).all()
    assert engine.weight_bytes() == api.weight_bytes(packed)

    for i in range(3):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=3))
    done = engine.run()
    assert len(done) == 3
    for req in done:
        assert len(req.out_tokens) == 3
        assert all(0 <= t < cfg.vocab_size for t in req.out_tokens)
    # serving state really is posit-coded
    assert engine.cache["k"].dtype == jnp.int8
    assert engine.params["layers"]["wq"].dtype == jnp.int16


def test_from_checkpoint_rejects_format_mismatch(tmp_path):
    """A checkpoint packed in one format must not silently decode with a
    different serving policy."""
    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.models import api
    from repro.serve import ServingEngine

    cfg8 = configs.get_smoke("command_r_35b").replace(
        quant=QuantPolicy(weights=P8_2, execution="fused"))
    params = api.init(jax.random.key(0), cfg8)
    CheckpointManager(str(tmp_path)).save(
        0, api.pack_params(params, cfg8), extra=api.pack_manifest(cfg8))
    cfg16 = cfg8.replace(quant=QuantPolicy(weights=P16_2, execution="fused"))
    with pytest.raises(ValueError, match="packed as"):
        ServingEngine.from_checkpoint(cfg16, str(tmp_path),
                                      batch_slots=1, max_seq=16)


def test_packed_serve_matches_in_memory_packed(rng, tmp_path):
    """from_checkpoint serving == serving the in-memory packed tree."""
    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.models import api
    from repro.serve import Request, ServingEngine

    cfg = _tiny_cfg(policy_by_name("serve_fused_p16"))
    params = api.init(jax.random.key(2), cfg)
    packed = api.pack_params(params, cfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, packed, extra=api.pack_manifest(cfg))

    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(2)]

    def run(engine):
        for i, p in enumerate(prompts):
            engine.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        return {r.rid: r.out_tokens for r in engine.run()}

    out_mem = run(ServingEngine(cfg, packed, batch_slots=2, max_seq=24))
    out_ckpt = run(ServingEngine.from_checkpoint(cfg, str(tmp_path),
                                                 batch_slots=2, max_seq=24))
    assert out_mem == out_ckpt


def test_engine_activation_coded_serving(rng, tmp_path):
    """A serving policy with activations=posit(n,es) runs the both-operands
    fused kernel at engine level: finite logits, parity with the qdot-level
    path (api.apply routes every matmul through dispatch -> fused_matmul),
    and end-to-end continuous batching."""
    from repro.checkpoint import CheckpointManager
    from repro.models import api
    from repro.serve import Request, ServingEngine

    cfg = _tiny_cfg(policy_by_name("serve_fused_p16_a13"))
    params = api.init(jax.random.key(4), cfg)
    packed = api.pack_params(params, cfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, packed, extra=api.pack_manifest(cfg))
    engine = ServingEngine.from_checkpoint(cfg, str(tmp_path),
                                           batch_slots=2, max_seq=24)
    summary = engine.execution_summary()
    assert summary["execution"] == "fused"
    assert summary["activation_coded"] is True
    assert summary["activations"] == str(P13_2)

    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    logits, _ = engine._prefill(engine.params, {"tokens": tokens})
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    want = api.apply(engine.params, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-5)

    for i in range(3):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
            max_new_tokens=4))
    done = engine.run()
    assert len(done) == 3
    assert all(0 <= t < cfg.vocab_size
               for r in done for t in r.out_tokens)


def test_qdot_act_coded_matches_fused_matmul_kernel(rng):
    """Dispatch under an activation-coded serving policy is exactly the
    both-operands fused kernel, code for code."""
    from repro.kernels import ops

    x = jnp.asarray(rng.normal(0, 1, (5, 24)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (24, 12)).astype(np.float32))
    policy = policy_by_name("serve_fused_p16_a13")
    w_codes = posit.pack(w, P16_2)
    got = dispatch.qdot(x, w_codes, policy, out_dtype=jnp.float32)
    want = ops.fused_matmul(ops.encode(x, P13_2), w_codes, P13_2, P16_2,
                            fmt_out=None)
    assert (np.asarray(got) == np.asarray(want)).all()


def _serve_engine(cfg, params, slots=1):
    from repro.serve import ServingEngine
    return ServingEngine(cfg, params, batch_slots=slots, max_seq=32)


def test_prefill_eos_retires_slot_immediately(rng):
    """A request whose prefill-produced first token is already eos must
    retire at fill time — not burn decode steps until slot_remaining
    drains — and its slot must refill from the queue in the same pass."""
    from repro.models import api
    from repro.serve import Request

    cfg = _tiny_cfg(policy_by_name("serve_fused_p16"))
    params = api.pack_params(api.init(jax.random.key(0), cfg), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    # learn the deterministic greedy first token for this prompt
    probe = _serve_engine(cfg, params)
    probe.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    first_tok = probe.run()[0].out_tokens[0]

    # two requests through ONE slot, both ending at prefill: a single
    # engine.step() must finish both without any decode step
    engine = _serve_engine(cfg, params, slots=1)
    for i in range(2):
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=8,
                              eos_id=int(first_tok)))
    assert engine.step() is False  # fill retired everything; no decode ran
    assert len(engine.done) == 2
    assert all(r.out_tokens == [first_tok] for r in engine.done)
    assert engine.queue == [] and all(engine.slot_free)


def test_prefill_max_new_tokens_one_retires_at_fill(rng):
    """max_new_tokens=1 is satisfied by the prefill token alone; the slot
    must not run a decode step (which would append a second token)."""
    from repro.models import api
    from repro.serve import Request

    cfg = _tiny_cfg(policy_by_name("serve_fused_p16"))
    params = api.pack_params(api.init(jax.random.key(0), cfg), cfg)
    engine = _serve_engine(cfg, params)
    engine.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        max_new_tokens=1))
    done = engine.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 1


def test_retire_at_prefill_refill_same_pass_key_stream_parity(rng):
    """RNG regression guard: when a SAMPLED slot retires at prefill (one-
    token budget, or eos on the prefill token) and the queue refills that
    slot in the same _fill_slots pass, the refilled request's per-draw
    fold_in stream must start at count 0 exactly as in a fresh engine —
    identically on the fused decode epilogue and the decomposed one."""
    from repro.models import api
    from repro.serve import Request, ServingEngine

    cfg = _tiny_cfg(policy_by_name("serve_fused_p16"))
    params = api.pack_params(api.init(jax.random.key(0), cfg), cfg)
    p0 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)

    def run(fused_decode, reqs):
        eng = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                            greedy=False, temperature=0.8, top_k=4,
                            fused_decode=fused_decode)
        for r in reqs:
            eng.submit(Request(**{**r, "prompt": r["prompt"].copy()}))
        return {r.rid: r.out_tokens for r in eng.run()}

    tail = dict(rid=1, prompt=p1, max_new_tokens=4, seed=22)
    ref = run(True, [tail])
    assert run(False, [tail]) == ref  # fused epilogue: same fold_in keys

    # (a) head retires via its one-token budget
    head = dict(rid=0, prompt=p0, max_new_tokens=1, seed=11)
    for fd in (True, False):
        got = run(fd, [head, tail])
        assert got[1] == ref[1], (fd, "budget retire skewed the refill")
    # (b) head retires via eos ON the sampled prefill token
    eos = run(True, [head])[0][0]
    head_eos = dict(rid=0, prompt=p0, max_new_tokens=8, seed=11,
                    eos_id=int(eos))
    for fd in (True, False):
        got = run(fd, [head_eos, tail])
        assert got[0] == [eos]
        assert got[1] == ref[1], (fd, "eos-at-prefill retire skewed keys")


def test_unpack_params_inverts_to_quantized_masters(rng):
    """unpack(pack(w)) == quantize(w): the packed checkpoint holds exactly
    the quantized weights, no second rounding."""
    from repro.models import api

    cfg = _tiny_cfg(QuantPolicy(weights=P16_2))
    params = api.init(jax.random.key(3), cfg)
    restored = api.unpack_params(api.pack_params(params, cfg), cfg)
    w = params["layers"]["wq"]
    want = posit.quantize(jnp.asarray(w, jnp.float32), P16_2)
    assert (np.asarray(restored["layers"]["wq"]) == np.asarray(want)).all()
