"""The trip-count-aware HLO analyzer is the roofline's measurement tool —
validate it against hand-countable programs (XLA's own cost_analysis counts
loop bodies once, which is why this exists)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo

X = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def _flops(f, *args):
    txt = jax.jit(f).lower(*args).compile().as_text()
    return analyze_hlo(txt)["flops"]


def test_single_dot():
    got = _flops(lambda x, w: jnp.dot(x, w), X, X)
    assert got == 2 * 128 ** 3


def test_scan_multiplies_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.dot(c, w).astype(jnp.float32), None
        return jax.lax.scan(body, x, None, length=17)[0]
    assert _flops(f, X, X) == 17 * 2 * 128 ** 3


def test_nested_scan():
    def f(x, w):
        def inner(c, _):
            return jnp.dot(c, w).astype(jnp.float32), None
        def outer(c, _):
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]
    assert _flops(f, X, X) == 15 * 2 * 128 ** 3


def test_rectangular_dot_contraction():
    a = jax.ShapeDtypeStruct((32, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 8), jnp.float32)
    got = _flops(lambda x, w: jnp.dot(x, w), a, b)
    assert got == 2 * 32 * 512 * 8


def test_hbm_bytes_scale_with_scan():
    def f1(x, w):
        return jnp.dot(x, w)
    def f17(x, w):
        def body(c, _):
            return jnp.dot(c, w).astype(jnp.float32), None
        return jax.lax.scan(body, x, None, length=17)[0]
    t1 = jax.jit(f1).lower(X, X).compile().as_text()
    t17 = jax.jit(f17).lower(X, X).compile().as_text()
    b1 = analyze_hlo(t1)["hbm_bytes"]
    b17 = analyze_hlo(t17)["hbm_bytes"]
    assert b17 > 10 * b1
