"""Asyncio continuous-batching front end (serve/frontend.py).

The front end wraps the synchronous slot scheduler in an event loop:
submit/stream/await semantics, SLO classes with priorities and queueing
deadlines, admission control that preempts a lower-priority slot for a
stuck higher-priority arrival, and TTFT/ITL accounting.  The laws pinned
here:

  * token streams delivered through `on_token` equal the engine's final
    streams, even across a preemption replay (dedup by emitted count);
  * a non-preemptible high-priority request evicts exactly one lowest-
    priority preemptible slot, and the evicted request still finishes
    with its original (bit-identical) stream;
  * deadline-expired queued requests cancel cleanly: Ticket.wait raises
    DeadlineExceeded and the pool keeps no orphaned holds or pages;
  * execution_summary surfaces the frontend terms next to the engine's.
"""
import asyncio

import numpy as np
import jax
import pytest

from repro import configs
from repro.core.formats import P8_2, P16_2
from repro.core.quant import QuantPolicy
from repro.models import api
from repro.serve import (AsyncServingFrontend, DeadlineExceeded, Request,
                         ServingEngine, SLOClass)

_PS = 4


def _model():
    if not hasattr(_model, "cache"):
        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        _model.cache = (cfg, params)
    return _model.cache


def _engine(**kw):
    cfg, params = _model()
    args = dict(batch_slots=2, max_seq=32, page_size=_PS, n_pages=24,
                prefill_buckets=(4, 1))
    args.update(kw)
    return ServingEngine(cfg, params, **args)


def _prompts(ns, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 60, n).astype(np.int32) for n in ns]


def test_streaming_matches_final_tokens_and_plain_engine():
    prompts = _prompts((5, 9, 7))
    ref = _engine()
    for i, p in enumerate(prompts):
        ref.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
    want = {r.rid: list(r.out_tokens) for r in ref.run()}

    frontend = AsyncServingFrontend(_engine())
    streams: dict = {}

    def on_token(rid, idx, tok):
        out = streams.setdefault(rid, [])
        assert idx == len(out)
        out.append(tok)

    async def main():
        ts = [frontend.submit(p, max_new_tokens=4, on_token=on_token, rid=i)
              for i, p in enumerate(prompts)]
        done, _ = await asyncio.gather(
            asyncio.gather(*(t.wait() for t in ts)), frontend.run())
        return {t.rid: toks for t, toks in zip(ts, done)}

    got = asyncio.run(main())
    assert got == want == streams
    s = frontend.execution_summary()
    assert s["requests_done"] == 3 and s["expired_requests"] == 0
    assert s["ttft_ms"]["count"] == 3
    assert s["itl_ms"]["count"] == sum(len(t) for t in want.values()) - 3
    assert frontend.engine.pages_in_use == 0


def test_interactive_preempts_lowest_priority_batch_slot():
    """With every slot busy on batch work, an interactive arrival must
    evict exactly one preemptible batch slot; the victim requeues, runs
    again, and both finish with engine-identical streams."""
    prompts = _prompts((6, 8, 5, 7), seed=1)
    ref = _engine()
    for i, p in enumerate(prompts):
        ref.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=6))
    ref.submit(Request(rid=99, prompt=_prompts((5,), 2)[0],
                       max_new_tokens=6))
    want = {r.rid: list(r.out_tokens) for r in ref.run()}

    eng = _engine()
    frontend = AsyncServingFrontend(eng)

    async def main():
        ts = [frontend.submit(p, max_new_tokens=6, slo="batch", rid=i)
              for i, p in enumerate(prompts)]
        runner = asyncio.ensure_future(frontend.run())
        # wait until every slot is mid-batch-work with more still queued,
        # so the interactive arrival can only run by evicting someone
        while not ((eng.slot_phase != 0).all() and eng.queue):
            if all(t.state != "pending" for t in ts):
                break
            await asyncio.sleep(0)
        ti = frontend.submit(_prompts((5,), 2)[0], max_new_tokens=6,
                             slo="interactive", rid=99)
        out = {t.rid: await t.wait() for t in ts + [ti]}
        await runner
        return out

    got = asyncio.run(main())
    assert got == want
    assert frontend.preemptions >= 1
    assert frontend.engine.stats["preemptions"] == frontend.preemptions
    assert eng.pages_in_use == 0 and not eng._held


def test_interactive_class_is_never_preempted():
    """Two interactive requests hold both slots; queued batch work can
    not displace them (equal-or-lower priority, and the class is marked
    non-preemptible anyway)."""
    eng = _engine()
    frontend = AsyncServingFrontend(eng)

    async def main():
        ti = [frontend.submit(p, max_new_tokens=6, slo="interactive",
                              rid=10 + i)
              for i, p in enumerate(_prompts((6, 7), 3))]
        tb = [frontend.submit(p, max_new_tokens=2, slo="batch", rid=i)
              for i, p in enumerate(_prompts((5, 5), 4))]
        await asyncio.gather(frontend.run(),
                             *(t.wait() for t in ti + tb))

    asyncio.run(main())
    assert frontend.preemptions == 0
    assert eng.stats["preemptions"] == 0


def test_deadline_expiry_cancels_and_keeps_pool_clean():
    """A queued request past its deadline cancels: wait() raises, no
    tokens ever stream, and the engine keeps no pages or holds for it."""
    eng = _engine(batch_slots=1)
    # a fake clock the test advances manually: deterministic expiry
    now = [0.0]
    frontend = AsyncServingFrontend(eng, clock=lambda: now[0])
    fired = []

    async def main():
        t0 = frontend.submit(_prompts((6,), 5)[0], max_new_tokens=8,
                             rid=0)
        t1 = frontend.submit(_prompts((14,), 6)[0], max_new_tokens=8,
                             rid=1, deadline_ms=1.0,
                             on_token=lambda *a: fired.append(a))
        now[0] = 1.0  # 1000ms later: rid=1 still queued behind rid=0
        runner = asyncio.ensure_future(frontend.run())
        toks = await t0.wait()
        with pytest.raises(DeadlineExceeded):
            await t1.wait()
        await runner
        return toks, t1

    toks, t1 = asyncio.run(main())
    assert len(toks) == 8 and not fired and t1.state == "expired"
    s = frontend.execution_summary()
    assert s["expired_requests"] == 1 and s["requests_done"] == 1
    assert eng.pages_in_use == 0 and not eng._held
    assert not eng.queue


def test_custom_slo_class_and_duplicate_rid_rejected():
    eng = _engine()
    hi = SLOClass("gold", priority=50, deadline_ms=None, preemptible=False)
    frontend = AsyncServingFrontend(eng, slo_classes=[hi])

    async def main():
        t0 = frontend.submit(_prompts((5,), 7)[0], max_new_tokens=2,
                             slo="gold", rid=7)
        with pytest.raises(ValueError, match="duplicate rid"):
            frontend.submit(_prompts((5,), 7)[0], rid=7)
        await asyncio.gather(frontend.run(), t0.wait())
        return t0

    t0 = asyncio.run(main())
    assert t0.slo.name == "gold" and t0.state == "done"
