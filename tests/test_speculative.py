"""Posit-native speculative decoding: draft-propose / batched-verify.

The engine's speculative path (serve/engine.py `_spec_round`) drafts k
tokens with a cheap policy and verifies them in ONE batched multi-query
`ops.paged_attention` dispatch (models `decode_verify`).  Draft and
target decode the SAME posit-coded KV pages, and the verify step samples
each position with exactly the fold_in key stream plain decode would
have used — so acceptance is exact and every token stream is bitwise
identical to the non-speculative engine on the same seeds.  These tests
pin that law (greedy, sampled, narrow-weight drafts, eos mid-round,
budget caps, interleaved chunked prefill) plus the constructor's
validation surface.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro import configs
from repro.core.formats import P8_0, P8_2, P16_2
from repro.core.quant import QuantPolicy
from repro.models import api
from repro.serve import Request, ServingEngine

_PS = 4


def _model():
    if not hasattr(_model, "cache"):
        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        _model.cache = (cfg, params)
    return _model.cache


def _reqs(max_new=6, eos=None, seeds=False):
    rng = np.random.default_rng(7)
    out = []
    for rid, n in enumerate((5, 9, 12)):
        prompt = rng.integers(0, 60, n).astype(np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                           eos_id=eos, seed=100 + rid if seeds else None))
    return out


def _run_pair(spec_kw, plain_kw=None, reqs=None, **shared):
    """Run the same queue through a speculative and a plain engine;
    return (spec_engine, spec_tokens, plain_tokens)."""
    cfg, params = _model()
    kw = dict(batch_slots=2, max_seq=32, page_size=_PS, n_pages=24,
              prefill_buckets=(4, 1))
    kw.update(shared)
    spec = ServingEngine(cfg, params, **kw, **spec_kw)
    plain = ServingEngine(cfg, params, **kw, **(plain_kw or {}))
    reqs = reqs if reqs is not None else _reqs()
    for eng in (spec, plain):
        for r in reqs:
            eng.submit(Request(rid=r.rid, prompt=r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens,
                               eos_id=r.eos_id, seed=r.seed))
    got = {r.rid: r.out_tokens for r in spec.run()}
    want = {r.rid: r.out_tokens for r in plain.run()}
    return spec, got, want


def _assert_clean(eng):
    assert eng.pages_in_use == 0
    assert not eng.prefix_index and not eng._held
    assert not eng.allocator._refs


def test_speculative_greedy_bitwise_matches_plain():
    spec, got, want = _run_pair({"speculate_k": 4})
    assert got == want
    s = spec.execution_summary()
    assert s["speculative"] and s["speculate_k"] == 4
    assert s["speculation_rounds"] > 0
    assert s["speculation_committed_tokens"] > 0
    # identical draft/target policy: every drafted token verifies
    assert s["speculation_accept_rate"] == 1.0
    _assert_clean(spec)


def test_speculative_sampled_bitwise_matches_plain():
    """Non-greedy: the verify step must consume exactly the per-request
    fold_in key stream plain decode would, draw for draw."""
    kw = dict(greedy=False, temperature=0.9, top_k=5)
    spec, got, want = _run_pair({"speculate_k": 3}, reqs=_reqs(seeds=True),
                                **kw)
    assert got == want
    s = spec.execution_summary()
    assert s["speculation_rounds"] > 0
    assert s["speculation_accept_rate"] == 1.0
    _assert_clean(spec)


def test_speculative_narrow_draft_weights_still_exact():
    """A genuinely different draft (P(8,0) weights) may get rejected —
    but rejection only costs speed, never tokens: streams stay bitwise
    identical to plain decode because the verify step IS plain decode's
    math over the same posit-coded pages."""
    cfg, _ = _model()
    dq = cfg.quant.with_draft(weights=P8_0)
    assert dq.kv_cache == cfg.quant.kv_cache
    assert dq.kv_page_size == cfg.quant.kv_page_size
    assert dq.weights == P8_0
    spec, got, want = _run_pair({"speculate_k": 4, "draft_quant": dq})
    assert got == want
    s = spec.execution_summary()
    assert s["speculation_rounds"] > 0
    assert 0.0 <= s["speculation_accept_rate"] <= 1.0
    _assert_clean(spec)


def test_speculative_eos_mid_round_truncates_like_plain():
    """eos landing inside a drafted span must cap the commit at the eos
    token, exactly where plain decode stops."""
    cfg, params = _model()
    # find the token greedy decode emits first, then make it the eos for
    # a fresh queue — guaranteed to fire inside the first verify span
    probe = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                          page_size=_PS, n_pages=24)
    prompt = np.arange(6, dtype=np.int32)
    probe.submit(Request(rid=0, prompt=prompt.copy(), max_new_tokens=1))
    eos = probe.run()[0].out_tokens[0]
    reqs = [Request(rid=0, prompt=prompt.copy(), max_new_tokens=8,
                    eos_id=eos),
            Request(rid=1, prompt=prompt[::-1].copy(), max_new_tokens=8)]
    spec, got, want = _run_pair({"speculate_k": 4}, reqs=reqs)
    assert got == want
    assert got[0][-1] == eos and len(got[0]) < 8
    _assert_clean(spec)


def test_speculative_budget_shorter_than_span():
    """max_new_tokens below k: the span clamps to the remaining budget
    (k=4 but only 2 tokens wanted) and the commit never overruns."""
    spec, got, want = _run_pair({"speculate_k": 4}, reqs=_reqs(max_new=2))
    assert got == want
    assert all(len(t) == 2 for t in got.values())
    _assert_clean(spec)


def test_speculative_with_interleaved_chunked_prefill():
    """Speculative decode rounds interleave with chunked prefill of the
    still-queued requests without perturbing either stream."""
    spec, got, want = _run_pair({"speculate_k": 3},
                                batch_slots=2, prefill_chunks_per_step=1)
    assert got == want
    assert spec.execution_summary()["speculation_rounds"] > 0
    _assert_clean(spec)


def test_speculation_ctor_validation():
    cfg, params = _model()
    kw = dict(batch_slots=1, max_seq=32, page_size=_PS, n_pages=12)
    with pytest.raises(ValueError, match="speculate_k must be >= 2"):
        ServingEngine(cfg, params, speculate_k=1, **kw)
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, speculate_k=2, paged=False,
                      batch_slots=1, max_seq=32)
    bad = dataclasses.replace(cfg.quant.with_draft(), kv_cache=P16_2)
    with pytest.raises(ValueError, match="kv_cache format"):
        ServingEngine(cfg, params, speculate_k=2, draft_quant=bad, **kw)


def test_with_draft_preserves_kv_contract():
    cfg, _ = _model()
    dq = cfg.quant.with_draft()
    assert dq.kv_cache == cfg.quant.kv_cache
    assert dq.kv_page_size == cfg.quant.kv_page_size
    assert dq.execution == "fake_quant"
    assert dq.weights == cfg.quant.weights
