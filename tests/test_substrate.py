"""Substrate tests: optimizers, data determinism, checkpoint atomicity +
elastic restore, fault-tolerance control plane, hw cost model."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.formats import PDPUConfig, P13_2, P16_2
from repro.core import hwmodel
from repro.data import DataConfig, Pipeline
from repro.models.config import ShapeConfig
from repro.optim import adamw, adafactor, sgdm, cosine_schedule, constant_schedule
from repro.runtime import (HeartbeatConfig, HeartbeatMonitor, NaNGuard,
                           StragglerDetector, plan_rescale)
from repro import configs


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [
    lambda: adamw(constant_schedule(0.05)),
    lambda: adafactor(constant_schedule(0.5)),
    lambda: sgdm(constant_schedule(0.05)),
], ids=["adamw", "adafactor", "sgdm"])
def test_optimizer_minimizes_quadratic(maker):
    opt = maker()
    params = {"w": jnp.asarray(np.linspace(-2, 2, 12).reshape(3, 4),
                               jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    l0 = float(loss(params))
    for _ in range(120):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(jnp.add, params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, final_frac=0.1)
    assert float(lr(jnp.asarray(0))) < 0.2
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 0.15
    assert float(lr(jnp.asarray(99))) < 0.2


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = configs.get_smoke("minitron_8b")
    shape = ShapeConfig("t", 32, 8, "train")
    p = Pipeline(cfg, shape, DataConfig(seed=3))
    b1, b2 = p.batch_at(7), p.batch_at(7)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (p.batch_at(8)["tokens"] != b1["tokens"]).any()
    # two hosts each produce their slice; contents differ but shapes halve
    pa = Pipeline(cfg, shape, DataConfig(seed=3, host_index=0, host_count=2))
    pb = Pipeline(cfg, shape, DataConfig(seed=3, host_index=1, host_count=2))
    assert pa.batch_at(0)["tokens"].shape[0] == 4
    assert (pa.batch_at(0)["tokens"] != pb.batch_at(0)["tokens"]).any()


def test_data_prefetch_iterator():
    cfg = configs.get_smoke("minitron_8b")
    p = Pipeline(cfg, ShapeConfig("t", 16, 4, "train"))
    it = p.iterator(start_step=5)
    first = next(it)
    assert (first["tokens"] == p.batch_at(5)["tokens"]).all()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(4, 8)), jnp.float32),
            "nested": {"b": jnp.asarray(r.integers(0, 9, (3,)), jnp.int32)}}


def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)
        trees = {}
        for s in (1, 2, 3, 4):
            trees[s] = _tree(s)
            mgr.save(s, trees[s])
        assert mgr.all_steps() == [3, 4]  # retention
        got = mgr.restore(4, jax.tree.map(lambda x: x, trees[4]))
        assert all((np.asarray(a) == np.asarray(b)).all()
                   for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(trees[4])))


def test_checkpoint_async_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=5)
        mgr.save_async(1, _tree(1))
        mgr.wait()
        # a torn write (tmp dir) must be invisible to readers
        os.makedirs(os.path.join(d, "step_000000009.tmp-dead"), exist_ok=True)
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1


def test_checkpoint_structure_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree(1))
        bad = {"a": jnp.zeros((4, 8)), "nested": {"c": jnp.zeros(3)}}
        with pytest.raises(ValueError):
            mgr.restore(1, bad)


# ---------------------------------------------------------------------------
# fault tolerance control plane
# ---------------------------------------------------------------------------

def test_heartbeat_death_detection():
    cfg = HeartbeatConfig(interval_s=1.0, miss_budget=2)
    mon = HeartbeatMonitor(["h0", "h1"], cfg)
    now = 100.0
    mon.beat("h0", now)
    mon.beat("h1", now)
    assert mon.dead_hosts(now + 1.0) == []
    mon.beat("h0", now + 5.0)
    assert mon.dead_hosts(now + 5.5) == ["h1"]


def test_straggler_detection():
    det = StragglerDetector(HeartbeatConfig(min_steps_for_stats=5))
    for _ in range(20):
        assert not det.observe(1.0 + np.random.default_rng(0).normal(0, 0.01))
    assert det.observe(3.0)  # 3x median


def test_nan_guard_policy():
    g = NaNGuard(max_consecutive=2)
    assert g.observe(1.0) == "ok"
    assert g.observe(float("nan")) == "skip"
    assert g.observe(float("inf")) == "restore"
    assert g.observe(0.5) == "ok"


def test_elastic_rescale_plan():
    plan = plan_rescale(available_hosts=120, chips_per_host=4,
                        restore_step=1000, model_axis=16)
    assert plan.new_mesh_shape == (30, 16)
    assert plan.restore_step == 1000
    with pytest.raises(RuntimeError):
        plan_rescale(available_hosts=1, chips_per_host=4,
                     restore_step=0, model_axis=16)


# ---------------------------------------------------------------------------
# hardware cost model (Table I calibration)
# ---------------------------------------------------------------------------

def test_hwmodel_matches_table1():
    from repro.core.formats import (
        PDPU_P16_16_N4_W14, PDPU_P13_16_N4_W14, PDPU_P13_16_N8_W14,
        PDPU_P10_16_N8_W14, PDPU_P13_16_N8_W10)
    rows = {
        PDPU_P16_16_N4_W14: (9579.15, 1.62, 4.49),
        PDPU_P13_16_N4_W14: (7694.82, 1.60, 3.66),
        PDPU_P13_16_N8_W14: (13560.37, 1.69, 5.80),
        PDPU_P10_16_N8_W14: (10006.42, 1.70, 4.24),
        PDPU_P13_16_N8_W10: (12157.11, 1.66, 5.06),
    }
    for cfg, (area, delay, power) in rows.items():
        r = hwmodel.report(cfg)
        assert abs(r.area_um2 / area - 1) < 0.12, cfg.name
        assert abs(r.delay_ns / delay - 1) < 0.05, cfg.name
        assert abs(r.power_mw / power - 1) < 0.20, cfg.name


def test_hwmodel_trends():
    """Generator monotonicity: bigger N / wider w_m cost more area."""
    base = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
    assert hwmodel.area_um2(PDPUConfig(P13_2, P16_2, N=8, w_m=14)) > \
        hwmodel.area_um2(base)
    assert hwmodel.area_um2(PDPUConfig(P13_2, P16_2, N=4, w_m=24)) > \
        hwmodel.area_um2(base)
    r = hwmodel.report(base)
    # 6-stage pipeline: balanced-ish stages, >3x throughput vs combinational
    assert r.delay_ns / max(r.stage_delay_ns) > 3.0
    # decoders (S1) dominate area (paper §IV-B)
    assert r.stage_area_um2[0] == max(r.stage_area_um2)
