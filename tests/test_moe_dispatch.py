"""Grouped posit GEMM path: cross-plan parity at qdot_grouped level, MoE
model-level parity over packed expert stacks, and packed-expert serving
through ServingEngine.from_checkpoint (all Pallas in interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pdpu as pdpu_core
from repro.core import posit
from repro.core.formats import P8_2, P13_2, P16_2
from repro.core.quant import QuantPolicy, policy_by_name
from repro.kernels import dispatch


@pytest.fixture
def exw(rng):
    x = jnp.asarray(rng.normal(0, 1, (4, 6, 40)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (4, 40, 24)).astype(np.float32))
    return x, w


# ---------------------------------------------------------------------------
# qdot_grouped plan parity
# ---------------------------------------------------------------------------


def test_grouped_fake_quant_matches_fused(exw):
    """Both plans compute on the same decoded posit values per expert with
    f32 accumulation — only tiling order can differ."""
    x, w = exw
    policy = QuantPolicy(weights=P16_2, activations=P13_2)
    a = dispatch.qdot_grouped(x, w, policy)
    b = dispatch.qdot_grouped(x, w, policy.with_execution("fused"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_grouped_fused_packed_equals_float_weights(exw):
    """Packing the expert stack is the same single rounding the fused path
    applies on the fly — packed vs float experts are indistinguishable."""
    x, w = exw
    policy = QuantPolicy(weights=P16_2, activations=P13_2, execution="fused")
    got_f = dispatch.qdot_grouped(x, w, policy)
    got_p = dispatch.qdot_grouped(x, posit.pack(w, P16_2), policy)
    assert (np.asarray(got_f) == np.asarray(got_p)).all()


def test_grouped_fake_quant_vs_fused_on_decoded_packed_experts(exw):
    """Value parity on a *packed* expert stack: serving a packed checkpoint
    with the fake_quant plan (decode once per use) and with the fused plan
    (in-kernel decode) computes the same quantized function."""
    x, w = exw
    w_codes = posit.pack(w, P16_2)
    policy = QuantPolicy(weights=P16_2)
    a = dispatch.qdot_grouped(x, w_codes, policy)
    b = dispatch.qdot_grouped(x, w_codes, policy.with_execution("fused"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_grouped_float_activation_fast_path(exw):
    """activations=None: float activations x in-kernel-decoded expert
    stacks (the serving default) equals the decode-then-einsum reference."""
    x, w = exw
    policy = QuantPolicy(weights=P16_2, execution="fused")
    w_codes = posit.pack(w, P16_2)
    got = dispatch.qdot_grouped(x, w_codes, policy)
    want = jnp.einsum("ecd,edf->ecf", x, posit.unpack(w_codes, P16_2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_grouped_batched_activations_match_per_expert_qdot(exw, rng):
    """[B, E, Cg, K] activations fold onto per-expert rows and back; every
    (b, e) slab must equal the 2-D qdot of that slab."""
    _, w = exw
    x = jnp.asarray(rng.normal(0, 1, (2, 4, 5, 40)).astype(np.float32))
    policy = QuantPolicy(weights=P16_2, execution="fused")
    w_codes = posit.pack(w, P16_2)
    got = dispatch.qdot_grouped(x, w_codes, policy)
    assert got.shape == (2, 4, 5, 24)
    for b in range(2):
        for e in range(4):
            want = dispatch.qdot(x[b, e], w_codes[e], policy)
            np.testing.assert_allclose(np.asarray(got[b, e]),
                                       np.asarray(want),
                                       rtol=1e-6, atol=1e-7)


def test_grouped_bit_exact_matches_chunked_pdpu_reference(rng):
    """bit_exact grouped == the core chunked-PDPU oracle run expert by
    expert, code for code (the hardware-model reference datapath)."""
    E = 3
    x = jnp.asarray(rng.normal(0, 1, (E, 4, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (E, 8, 6)).astype(np.float32))
    policy = QuantPolicy(weights=P13_2, activations=P13_2,
                         execution="bit_exact", pdpu_n=4)
    got = dispatch.qdot_grouped(x, w, policy, out_dtype=jnp.float32)
    cfg = policy.pdpu_config()
    for e in range(E):
        want_codes = pdpu_core.pdpu_matmul_exact(
            posit.encode(x[e], cfg.fmt_in), posit.encode(w[e], cfg.fmt_in),
            cfg)
        want = posit.decode(want_codes, cfg.fmt_out)
        assert (np.asarray(got[e]) == np.asarray(want)).all(), e


def test_grouped_bit_exact_pads_ragged_contraction(rng):
    """K not divisible by the PDPU chunk size pads with exact posit zeros."""
    x = jnp.asarray(rng.normal(0, 1, (2, 2, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (2, 10, 3)).astype(np.float32))
    policy = QuantPolicy(weights=P13_2, activations=P13_2,
                         execution="bit_exact", pdpu_n=4)
    got = dispatch.qdot_grouped(x, w, policy, out_dtype=jnp.float32)
    cfg = policy.pdpu_config()
    for e in range(2):
        a = jnp.pad(posit.encode(x[e], cfg.fmt_in), ((0, 0), (0, 2)))
        b = jnp.pad(posit.encode(w[e], cfg.fmt_in), ((0, 2), (0, 0)))
        want = posit.decode(pdpu_core.pdpu_matmul_exact(a, b, cfg),
                            cfg.fmt_out)
        assert (np.asarray(got[e]) == np.asarray(want)).all(), e


# ---------------------------------------------------------------------------
# MoE model-level parity + packed-expert serving
# ---------------------------------------------------------------------------


def _moe_cfg(name="qwen3_moe_235b", **kw):
    from repro import configs
    return configs.get_smoke(name).replace(n_layers=1, **kw)


@pytest.mark.parametrize("grouped_dispatch", [False, True],
                         ids=["sorted", "gshard"])
def test_moe_model_fake_vs_fused_logits_parity(rng, grouped_dispatch):
    """Whole-MoE forward: the fused grouped kernel over packed expert
    stacks ~= fake_quant on float masters, for both dispatch flavors
    (covers the [E, C, D] and [B, E, Cg, D] activation layouts)."""
    from repro.models import api

    cfg_fake = _moe_cfg(quant=QuantPolicy(weights=P16_2),
                        moe_grouped_dispatch=grouped_dispatch)
    cfg_fused = cfg_fake.replace(
        quant=QuantPolicy(weights=P16_2, execution="fused"))
    params = api.init(jax.random.key(1), cfg_fake)
    packed = api.pack_params(params, cfg_fused)
    assert packed["layers"]["we_gate"].dtype == jnp.int16
    tokens = jnp.asarray(rng.integers(0, cfg_fake.vocab_size, (2, 6)),
                         jnp.int32)
    logits_fake = api.apply(params, {"tokens": tokens}, cfg_fake)
    logits_fused = api.apply(packed, {"tokens": tokens}, cfg_fused)
    np.testing.assert_allclose(np.asarray(logits_fake),
                               np.asarray(logits_fused),
                               rtol=1e-4, atol=1e-5)


def test_moe_shared_experts_pack_and_fuse(rng):
    """deepseek-style shared experts pack alongside the routed stacks and
    the fused forward still matches fake_quant."""
    from repro.models import api

    cfg_fake = _moe_cfg("deepseek_moe_16b", quant=QuantPolicy(weights=P16_2))
    cfg_fused = cfg_fake.replace(
        quant=QuantPolicy(weights=P16_2, execution="fused"))
    params = api.init(jax.random.key(2), cfg_fake)
    packed = api.pack_params(params, cfg_fused)
    for n in ("we_gate", "we_up", "we_down", "ws_gate", "ws_up", "ws_down"):
        assert packed["layers"][n].dtype == jnp.int16, n
    tokens = jnp.asarray(rng.integers(0, cfg_fake.vocab_size, (2, 5)),
                         jnp.int32)
    np.testing.assert_allclose(
        np.asarray(api.apply(params, {"tokens": tokens}, cfg_fake)),
        np.asarray(api.apply(packed, {"tokens": tokens}, cfg_fused)),
        rtol=1e-4, atol=1e-5)


def test_moe_pack_checkpoint_serve_roundtrip(rng, tmp_path):
    """Packed expert stacks through the checkpoint manifest and
    ServingEngine.from_checkpoint: EP serving consumes int16 expert codes
    end to end (prefill + continuous-batching decode)."""
    from repro.checkpoint import CheckpointManager
    from repro.models import api
    from repro.serve import Request, ServingEngine

    cfg = _moe_cfg(quant=policy_by_name("serve_fused_p16"))
    params = api.init(jax.random.key(0), cfg)
    packed = api.pack_params(params, cfg)
    assert api.weight_bytes(packed) < api.weight_bytes(params)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, packed, extra=api.pack_manifest(cfg))
    assert mgr.read_manifest(7)["extra"]["packed_weights"] is True

    engine = ServingEngine.from_checkpoint(cfg, str(tmp_path),
                                           batch_slots=2, max_seq=24)
    # the restored expert stacks are the packed codes, bit for bit
    for n in ("we_gate", "we_up", "we_down"):
        assert engine.params["layers"][n].dtype == jnp.int16, n
        assert (np.asarray(engine.params["layers"][n]) ==
                np.asarray(packed["layers"][n])).all(), n

    for i in range(3):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
            max_new_tokens=3))
    done = engine.run()
    assert len(done) == 3
    for req in done:
        assert len(req.out_tokens) == 3
        assert all(0 <= t < cfg.vocab_size for t in req.out_tokens)


def test_moe_packed_serve_matches_in_memory_packed(rng, tmp_path):
    """from_checkpoint MoE serving == serving the in-memory packed tree."""
    from repro.checkpoint import CheckpointManager
    from repro.models import api
    from repro.serve import Request, ServingEngine

    cfg = _moe_cfg(quant=policy_by_name("serve_fused_p16"))
    params = api.init(jax.random.key(3), cfg)
    packed = api.pack_params(params, cfg)
    CheckpointManager(str(tmp_path)).save(0, packed,
                                          extra=api.pack_manifest(cfg))
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(2)]

    def run(engine):
        for i, p in enumerate(prompts):
            engine.submit(Request(rid=i, prompt=p, max_new_tokens=3))
        return {r.rid: r.out_tokens for r in engine.run()}

    out_mem = run(ServingEngine(cfg, packed, batch_slots=2, max_seq=16))
    out_ckpt = run(ServingEngine.from_checkpoint(cfg, str(tmp_path),
                                                 batch_slots=2, max_seq=16))
    assert out_mem == out_ckpt
