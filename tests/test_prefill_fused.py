"""Fused prefill-attention-that-writes-pages: kernel parity + engine wiring.

The fused kernel (kernels/prefill_attention.py, behind
ops.prefill_attention_paged) collapses the prefill chunk's three device
programs — flash attention over history+chunk, posit-encode of the chunk
KV, scatter into pool pages via the block table — into one.  These tests
pin the contract that makes it a pure perf move:

  * bit-identical attention output AND bit-identical written pages vs the
    decomposed gather -> decode -> flash -> encode -> insert composite,
    across KV formats (f32 pool, P(16,1), P(8,2)), compute dtypes,
    mid-page starts, window+softcap, per-slot vs batched launches, and
    the sharded global-pool variant (hist_pool_k/v + hist_bt global page
    ids + page_ok write-ownership masks);
  * the static applicability gate (paged.fused_prefill_span_ok) stays in
    sync with the flash kernel's chunk size, so fusion never changes the
    chunking the legacy path would have used — spans past one flash chunk
    stream history page-by-page inside the kernel and stay admitted
    whenever the page size tiles paged.FLASH_CHUNK;
  * ServingEngine(fused_prefill=...) emits token-identical streams either
    way while the prefill_device_programs counter drops 3x -> 1x,
    including needle-style long prompts spanning >= 3 flash chunks.
"""
import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import posit
from repro.core.formats import P8_2, P16_1, P16_2
from repro.core.quant import QuantPolicy
from repro.kernels import ops
from repro.models import api, common, paged
from repro.serve import Request, ServingEngine


# ---------------------------------------------------------------------------
# kernel parity vs the decomposed three-program path
# ---------------------------------------------------------------------------


def _legacy(q, k, v, k_pool, v_pool, bt, starts, win, fmt, compute_dtype,
            softcap_val):
    """Replay _chunk_attn_batched's decomposed attention+encode+insert
    stages op-for-op (the exact programs the fused kernel replaces)."""
    B, C, Hq, Dh = q.shape
    Hkv = k.shape[2]

    def kv_encode(x):
        return x.astype(compute_dtype) if fmt is None else posit.pack(x, fmt)

    def kv_decode(x):
        return x if fmt is None else posit.unpack(x, fmt, dtype=compute_dtype)

    k_codes = kv_encode(k.reshape(B, C, -1))
    v_codes = kv_encode(v.reshape(B, C, -1))
    hist_k = paged.gather_slots(k_pool, bt)
    hist_v = paged.gather_slots(v_pool, bt)
    k_new = paged.insert_chunk_batched(k_pool, bt, starts, k_codes)
    v_new = paged.insert_chunk_batched(v_pool, bt, starts, v_codes)
    S_h = hist_k.shape[1]
    hist_pos = jnp.broadcast_to(jnp.arange(S_h, dtype=jnp.int32)[None],
                                (B, S_h))
    hist_pos = jnp.where(hist_pos < starts[:, None], hist_pos, -1)
    pos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    kd = kv_decode(hist_k).reshape(B, S_h, Hkv, Dh).astype(k.dtype)
    vd = kv_decode(hist_v).reshape(B, S_h, Hkv, Dh).astype(v.dtype)
    k_all = jnp.concatenate([kd, k], axis=1)
    v_all = jnp.concatenate([vd, v], axis=1)
    kv_pos = jnp.concatenate([hist_pos, pos], axis=1)
    window = None if win is None else jnp.int32(win)
    attn = common.flash_attention(q, k_all, v_all, pos, kv_pos, causal=True,
                                  window=window, softcap_val=softcap_val)
    return attn, k_new, v_new


def _pool(rng, fmt, n_pages, ps, F, compute_dtype):
    """A recycled page pool: valid posit codes (or floats) as garbage."""
    if fmt is None:
        return jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)), compute_dtype)
    dt = {8: jnp.int8, 16: jnp.int16}[fmt.storage_bits]
    raw = jnp.asarray(rng.integers(0, 1 << fmt.n, (n_pages, ps, F)),
                      jnp.int32)
    return jnp.where(raw == fmt.nar_code, 0, raw).astype(dt)


# (fmt, compute_dtype, B, C, window, softcap, starts, per_slot, dense_hist)
_CASES = {
    "coded_start0": (P16_1, jnp.float32, 1, 8, None, 0.0, [0], False, False),
    "coded_mixed_midpage_starts":
        (P16_1, jnp.float32, 3, 8, None, 0.0, [0, 5, 13], False, False),
    "window_plus_softcap":
        (P16_1, jnp.float32, 2, 8, 7, 30.0, [4, 9], False, False),
    "f32_pool": (None, jnp.float32, 2, 8, None, 0.0, [3, 0], False, False),
    "bf16_compute":
        (P16_1, jnp.bfloat16, 2, 8, None, 0.0, [2, 7], False, False),
    "p8_kv": (P8_2, jnp.float32, 2, 8, None, 0.0, [1, 6], False, False),
    "per_slot_eq_batched":
        (P16_1, jnp.float32, 2, 8, None, 0.0, [0, 5], True, False),
    "dense_hist_sharded_variant":
        (P16_1, jnp.float32, 2, 8, 5, 10.0, [4, 9], False, True),
    "single_token_chunk":
        (P16_1, jnp.float32, 2, 1, None, 0.0, [7, 0], False, False),
}


@pytest.mark.parametrize("name", sorted(_CASES))
def test_fused_prefill_bitwise_vs_decomposed(name):
    rng = np.random.default_rng(0)
    fmt, compute_dtype, B, C, win, softcap, starts_l, per_slot, dense = \
        _CASES[name]
    Hq, Hkv, Dh, ps, M = 4, 2, 8, 4, 6
    F = Hkv * Dh
    n_pages = 1 + B * M
    pool_k = _pool(rng, fmt, n_pages, ps, F, compute_dtype)
    pool_v = _pool(rng, fmt, n_pages, ps, F, compute_dtype)
    bt = np.zeros((B, M), np.int32)
    for b in range(B):
        alloc = -(-(int(starts_l[b]) + C) // ps)
        bt[b, :alloc] = 1 + b * M + np.arange(alloc)
    bt = jnp.asarray(bt)
    starts = jnp.asarray(starts_l, jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, C, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)

    ref_attn, ref_k, ref_v = _legacy(q, k, v, pool_k, pool_v, bt, starts,
                                     win, fmt, compute_dtype, softcap)
    win_arr = jnp.full((1,), 2 ** 30 if win is None else win, jnp.int32)
    kw = {}
    if dense:
        # single-pool stand-in for the sharded path: the "all-gathered"
        # global pool is the pool itself and hist_bt carries global ids
        kw = dict(hist_pool_k=pool_k, hist_pool_v=pool_v, hist_bt=bt)
    if per_slot:
        attn = jnp.zeros_like(ref_attn)
        k_new, v_new = pool_k, pool_v
        for b in range(B):
            a1, k_new, v_new = ops.prefill_attention_paged(
                q[b:b + 1], k[b:b + 1], v[b:b + 1], k_new, v_new,
                bt[b:b + 1], starts[b:b + 1], win_arr, fmt_kv=fmt,
                compute_dtype=compute_dtype, softcap_val=softcap)
            attn = attn.at[b].set(a1[0])
    else:
        attn, k_new, v_new = ops.prefill_attention_paged(
            q, k, v, pool_k, pool_v, bt, starts, win_arr, fmt_kv=fmt,
            compute_dtype=compute_dtype, softcap_val=softcap, **kw)

    np.testing.assert_array_equal(np.asarray(attn), np.asarray(ref_attn))
    # page 0 is the trash page (unowned writes land there) — exclude it
    np.testing.assert_array_equal(np.asarray(k_new[1:]), np.asarray(ref_k[1:]))
    np.testing.assert_array_equal(np.asarray(v_new[1:]), np.asarray(ref_v[1:]))


def test_fused_prefill_page_ok_masks_writes():
    """With page_ok masking out a slot's pages (the not-my-shard case),
    the fused kernel must leave those pool pages untouched and still
    produce the full attention output from the dense history."""
    rng = np.random.default_rng(1)
    B, C, Hq, Hkv, Dh, ps, M = 2, 8, 4, 2, 8, 4, 6
    F = Hkv * Dh
    fmt = P16_1
    pool_k = _pool(rng, fmt, 1 + B * M, ps, F, jnp.float32)
    pool_v = _pool(rng, fmt, 1 + B * M, ps, F, jnp.float32)
    bt = np.zeros((B, M), np.int32)
    starts_l = [4, 9]
    for b in range(B):
        alloc = -(-(starts_l[b] + C) // ps)
        bt[b, :alloc] = 1 + b * M + np.arange(alloc)
    bt = jnp.asarray(bt)
    starts = jnp.asarray(starts_l, jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, C, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
    win_arr = jnp.full((1,), 2 ** 30, jnp.int32)
    owned = jnp.zeros_like(bt).at[0].set(1)  # shard owns slot 0's pages only

    full_attn, full_k, full_v = ops.prefill_attention_paged(
        q, k, v, pool_k, pool_v, bt, starts, win_arr, fmt_kv=fmt,
        hist_pool_k=pool_k, hist_pool_v=pool_v, hist_bt=bt)
    attn, k_new, v_new = ops.prefill_attention_paged(
        q, k, v, pool_k, pool_v, bt, starts, win_arr, fmt_kv=fmt,
        hist_pool_k=pool_k, hist_pool_v=pool_v, hist_bt=bt, page_ok=owned)

    np.testing.assert_array_equal(np.asarray(attn), np.asarray(full_attn))
    own = np.asarray(bt[0])[np.asarray(bt[0]) > 0]
    other = np.asarray(bt[1])[np.asarray(bt[1]) > 0]
    np.testing.assert_array_equal(np.asarray(k_new[own]),
                                  np.asarray(full_k[own]))
    np.testing.assert_array_equal(np.asarray(k_new[other]),
                                  np.asarray(pool_k[other]))
    np.testing.assert_array_equal(np.asarray(v_new[other]),
                                  np.asarray(pool_v[other]))


# ---------------------------------------------------------------------------
# the static applicability gate
# ---------------------------------------------------------------------------


def test_span_gate_matches_flash_chunk():
    """fused_prefill_span_ok is only sound while paged.FLASH_CHUNK equals
    the flash kernel's default chunk_k: the fused kernel replays the
    single-chunk flash pass, so a chunk_k change must bump FLASH_CHUNK."""
    sig = inspect.signature(common.flash_attention)
    assert sig.parameters["chunk_k"].default == paged.FLASH_CHUNK == 1024


def test_span_gate_boundaries():
    assert paged.fused_prefill_span_ok(6, 4, 8)          # 24 + 8 <= 1024
    assert paged.fused_prefill_span_ok(63, 16, 16)       # 1008 + 16 == 1024
    # spans past one flash chunk stream history page-by-page in the
    # kernel — admitted whenever the page size tiles FLASH_CHUNK exactly
    assert paged.fused_prefill_span_ok(63, 16, 17)
    assert paged.fused_prefill_span_ok(128, 16, 64)
    assert paged.fused_prefill_span_ok(4096, 4, 128)
    # a non-dividing page size only passes while the whole span still
    # fits a single flash pass
    assert paged.fused_prefill_span_ok(3, 48, 16)        # 144 + 16 <= 1024
    assert not paged.fused_prefill_span_ok(30, 48, 17)   # 48 doesn't tile


# ---------------------------------------------------------------------------
# engine: fused on/off token parity + the 3x -> 1x program counter
# ---------------------------------------------------------------------------

_ARCHS = {"transformer": "command_r_35b",
          "moe": "qwen3_moe_235b",
          "hybrid": "jamba_1_5_large"}
_QUANTS = {"f32": QuantPolicy(),
           "coded": QuantPolicy(weights=P16_2, kv_cache=P8_2)}


def _serve(cfg, params, prompts, fused, max_seq=32):
    engine = ServingEngine(cfg, params, batch_slots=2, max_seq=max_seq,
                           fused_prefill=fused)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    done = engine.run()
    return {r.rid: r.out_tokens for r in done}, engine


@pytest.mark.parametrize("family", sorted(_ARCHS))
@pytest.mark.parametrize("qname", sorted(_QUANTS))
def test_engine_token_parity_fused_vs_decomposed(family, qname):
    rng = np.random.default_rng(2)
    cfg = configs.get_tiny_serving(_ARCHS[family], _QUANTS[qname])
    params = api.init(jax.random.key(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 6)]
    out_f, eng_f = _serve(cfg, params, prompts, fused=True)
    out_d, eng_d = _serve(cfg, params, prompts, fused=False)
    assert out_f == out_d
    sf, sd = eng_f.execution_summary(), eng_d.execution_summary()
    assert sf["fused_prefill"] and not sd["fused_prefill"]
    # same chunk schedule either way, but 1 vs 3 device programs per chunk
    assert sf["prefill_chunks"] == sd["prefill_chunks"] > 0
    assert sf["prefill_device_programs"] == sf["prefill_chunks"]
    assert sd["prefill_device_programs"] == 3 * sd["prefill_chunks"]


@pytest.mark.parametrize("family", sorted(_ARCHS))
@pytest.mark.parametrize("qname", sorted(_QUANTS))
def test_long_prompt_needle_token_parity(family, qname, monkeypatch):
    """Needle-style long prompts: with FLASH_CHUNK shrunk to 16, a
    53-token prompt spans >= 3 flash chunks of streamed history, and the
    fused path must stay token-identical to the decomposed one — for the
    base prompt AND with the needle token near the start flipped (so the
    earliest streamed chunk provably reaches the decode logits the same
    way on both paths) — while every prefill chunk stays ONE device
    program."""
    monkeypatch.setattr(paged, "FLASH_CHUNK", 16)
    rng = np.random.default_rng(3)
    cfg = configs.get_tiny_serving(_ARCHS[family], _QUANTS[qname])
    params = api.init(jax.random.key(0), cfg)
    n = 3 * paged.FLASH_CHUNK + 5
    needle = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    short = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    flipped = needle.copy()
    flipped[1] = (needle[1] + 1) % cfg.vocab_size
    for long_prompt in (needle, flipped):
        out_f, eng_f = _serve(cfg, params, [long_prompt, short], fused=True,
                              max_seq=64)
        out_d, eng_d = _serve(cfg, params, [long_prompt, short], fused=False,
                              max_seq=64)
        assert out_f == out_d
        sf, sd = eng_f.execution_summary(), eng_d.execution_summary()
        assert sf["prefill_chunks"] == sd["prefill_chunks"] > 0
        assert sf["prefill_device_programs"] == sf["prefill_chunks"]
        assert sd["prefill_device_programs"] == 3 * sd["prefill_chunks"]


# ---------------------------------------------------------------------------
# page-size validation: no silent fall-off from the fused path
# ---------------------------------------------------------------------------


def test_engine_rejects_page_size_that_loses_fused_path(monkeypatch):
    """Regression: a page size that neither tiles FLASH_CHUNK nor fits
    every span in one flash pass used to build fine and then silently run
    EVERY chunk through the 3-program decomposed path.  An explicitly
    requested size like that must now raise at construction."""
    monkeypatch.setattr(paged, "FLASH_CHUNK", 16)
    cfg = configs.get_tiny_serving("command_r_35b",
                                   QuantPolicy(kv_cache=P16_1))
    params = api.init(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="fused"):
        ServingEngine(cfg, params, batch_slots=1, max_seq=64, page_size=12)
    # the documented escape hatch: opt out of fused prefill entirely
    eng = ServingEngine(
        cfg, params.copy(), batch_slots=1, max_seq=64, page_size=12,
        fused_prefill=False)
    assert eng.layout.page_size == 12
    assert eng._prefill_programs_per_chunk(8) == 3


def test_engine_auto_picks_tiling_page_size(monkeypatch):
    """With page_size unspecified, a policy default that would lose the
    fused path degrades to the largest FLASH_CHUNK divisor below it —
    and the engine then really does run one device program per chunk,
    token-identical to the decomposed escape hatch."""
    monkeypatch.setattr(paged, "FLASH_CHUNK", 16)
    rng = np.random.default_rng(5)
    cfg = configs.get_tiny_serving(
        "command_r_35b", QuantPolicy(kv_cache=P16_1, kv_page_size=12))
    params = api.init(jax.random.key(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (21, 6)]
    eng = ServingEngine(cfg, params, batch_slots=2, max_seq=64)
    assert eng.layout.page_size == 8  # largest divisor of 16 at/below 12
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=3))
    got = {r.rid: r.out_tokens for r in eng.run()}
    s = eng.execution_summary()
    # the formerly-falling-back config now holds the one-program contract
    assert s["prefill_device_programs"] == s["prefill_chunks"] > 0
    out_d, eng_d = _serve(cfg, params, prompts, fused=False, max_seq=64)
    assert got == out_d
    sd = eng_d.execution_summary()
    assert sd["prefill_device_programs"] == 3 * sd["prefill_chunks"]


def test_engine_page_size_untouched_when_gate_holds():
    """Sizes the span gate admits — tiling or small-span non-tiling —
    pass through unchanged, requested or defaulted."""
    cfg = configs.get_tiny_serving("command_r_35b",
                                   QuantPolicy(kv_cache=P16_1))
    params = api.init(jax.random.key(0), cfg)
    # 48 doesn't tile FLASH_CHUNK=1024 but max_seq=32 spans one page:
    # the whole span fits a single flash pass, so it stays legal
    eng = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                        page_size=48)
    assert eng.layout.page_size == 48
    eng2 = ServingEngine(cfg, params, batch_slots=1, max_seq=32)
    assert eng2.layout.page_size == cfg.quant.kv_page_size


def test_engine_counter_follows_span_gate():
    cfg = configs.get_tiny_serving("command_r_35b",
                                   QuantPolicy(kv_cache=P16_1))
    params = api.init(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=1, max_seq=32)
    assert engine.cfg.quant.fused_prefill  # the default
    span_ok = paged.fused_prefill_span_ok(engine.max_pages_per_slot,
                                          engine.layout.page_size, 8)
    assert engine._prefill_programs_per_chunk(8) == (1 if span_ok else 3)
    decomposed = ServingEngine(cfg, params, batch_slots=1, max_seq=32,
                               fused_prefill=False)
    assert not decomposed.cfg.quant.fused_prefill
    assert decomposed._prefill_programs_per_chunk(8) == 3
