"""Sharded paged posit-KV serving: cross-topology parity and invariants.

The page pool shards along kv_pages (each device owns a contiguous global
page-id range with its own budget; block tables keep global ids) and every
serving entry point runs under a fully-manual shard_map — see
models/paged.py for the id contract and serve/engine.py for the scheduler.

Three tiers here:

  * pure-host unit tests (any device count): PagedLayout global<->local
    id mapping with out-of-range/trash-page invariants, the sharded
    PageAllocator's per-device budgets + affinity/spill policy, and the
    sharding-rule helpers (spec_for / constrain / tree_specs /
    mesh_axes_for with the kv_pages rule and its axis-absent fallback).
  * 1-device numerics: the log-sum-exp partial merge vs the unsharded
    kernel finalize, with pages split across simulated owners.
  * multi-device integration via subprocesses (the test_distributed.py
    idiom — XLA_FLAGS device-count forcing must precede jax init): token
    parity of a 2-device mesh engine against the 1-device engine across
    {transformer, moe, hybrid} x {f32, coded} KV, per-device page-budget
    admission guards, full pool reclamation after drain, and mesh
    validation errors.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.paged import PagedLayout, PageShard, localize_ids
from repro.parallel import sharding
from repro.serve import PageAllocator

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env(n_devices: int):
    return {**os.environ,
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
            "PYTHONPATH": os.path.join(REPO, "src")}


def _run(code: str, n_devices: int = 2, timeout: int = 600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=_env(n_devices),
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# PagedLayout: global <-> (shard, local) page-id mapping
# ---------------------------------------------------------------------------


def test_layout_global_local_mapping():
    lay = PagedLayout(page_size=4, n_pages=12, n_shards=3)
    assert lay.pages_per_shard == 4
    assert lay.capacity == 12 - 3
    for g in range(12):
        s, loc = lay.shard_of(g), lay.local_id(g)
        assert 0 <= s < 3 and 0 <= loc < 4
        assert lay.global_id(s, loc) == g
        # every shard's local page 0 is its trash page — and nothing else is
        assert lay.is_trash(g) == (g % 4 == 0)


def test_layout_mapping_rejects_out_of_range():
    lay = PagedLayout(page_size=4, n_pages=8, n_shards=2)
    for g in (-1, 8, 100):
        with pytest.raises(ValueError):
            lay.shard_of(g)
        with pytest.raises(ValueError):
            lay.local_id(g)
        with pytest.raises(ValueError):
            lay.is_trash(g)
    with pytest.raises(ValueError):
        lay.global_id(2, 0)   # shard out of range
    with pytest.raises(ValueError):
        lay.global_id(0, 4)   # local id out of range


def test_layout_validation():
    with pytest.raises(ValueError):
        PagedLayout(page_size=4, n_pages=10, n_shards=3)  # not divisible
    with pytest.raises(ValueError):
        PagedLayout(page_size=4, n_pages=4, n_shards=4)   # <2 pages/shard


def test_for_slots_sharded_defaults():
    """Default pool sizing must give every slot its worst-case pages even
    after each shard donates a trash page."""
    for ns in (1, 2, 3):
        lay = PagedLayout.for_slots(batch=3, max_seq=17, page_size=4,
                                    n_shards=ns)
        assert lay.n_pages % ns == 0
        assert lay.capacity >= 3 * lay.pages_per_slot(17)


def test_localize_ids_maps_non_owned_to_trash():
    """Owned global ids localize; non-owned ids land on the shard's own
    local trash page 0 with owned=False (vmap axis_name stands in for the
    shard_map axis: element i sees axis_index == i)."""
    ids = jnp.asarray([0, 1, 3, 4, 7, 5])
    shard = PageShard(axis="s", n_shards=2)
    loc, owned = jax.vmap(lambda _: localize_ids(ids, 4, shard),
                          axis_name="s")(jnp.arange(2))
    # shard 0 owns globals [0, 4); shard 1 owns [4, 8)
    np.testing.assert_array_equal(np.asarray(loc[0]), [0, 1, 3, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(owned[0]),
                                  [True, True, True, False, False, False])
    np.testing.assert_array_equal(np.asarray(loc[1]), [0, 0, 0, 0, 3, 1])
    np.testing.assert_array_equal(np.asarray(owned[1]),
                                  [False, False, False, True, True, True])


# ---------------------------------------------------------------------------
# PageAllocator: per-device budgets, affinity, deterministic spill
# ---------------------------------------------------------------------------


def test_sharded_allocator_never_grants_trash_and_conserves():
    a = PageAllocator(12, n_shards=3)
    assert a.capacity == 9 and a.pages_per_shard == 4
    got = a.alloc(9)
    assert got is not None and len(set(got)) == 9
    assert all(g % 4 != 0 for g in got), "granted a trash page"
    assert a.alloc(1) is None
    assert a.pages_in_use_by_shard == [3, 3, 3]
    a.free(got)
    assert a.pages_in_use == 0 and a.pages_free == 9
    assert a.pages_free_by_shard == [3, 3, 3]


def test_sharded_allocator_affinity_and_spill():
    a = PageAllocator(12, n_shards=3)
    # prefer_shard honored when that budget fits
    got = a.alloc(2, prefer_shard=1)
    assert all(a.shard_of(p) == 1 for p in got)
    # no preference -> least-loaded single shard (most free, tie lowest
    # index): shards 0 and 2 tie at 3 free -> shard 0
    got2 = a.alloc(2)
    assert all(a.shard_of(p) == 0 for p in got2)
    # request bigger than any single remaining budget spills, most-free
    # first: free now [1, 1, 3] -> shard 2 then shards 0/1
    got3 = a.alloc(4)
    assert sorted(a.shard_of(p) for p in got3) == [0, 2, 2, 2]
    # frees go back to their own shard's budget
    a.free(got3)
    assert a.pages_free_by_shard == [1, 1, 3]
    a.free(got + got2)
    assert a.pages_free_by_shard == [3, 3, 3]


def test_sharded_allocator_prefer_falls_back_when_full():
    a = PageAllocator(8, n_shards=2)
    a.alloc(3, prefer_shard=0)
    got = a.alloc(2, prefer_shard=0)   # shard 0 exhausted -> shard 1
    assert all(a.shard_of(p) == 1 for p in got)


def test_sharded_allocator_rejects_bad_shapes():
    with pytest.raises(ValueError):
        PageAllocator(10, n_shards=3)
    with pytest.raises(ValueError):
        PageAllocator(4, n_shards=4)


# ---------------------------------------------------------------------------
# sharding rules: kv_pages mapping + axis-absent fallback
# ---------------------------------------------------------------------------


def test_kv_pages_rule_axis_absent_fallback():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1),
                             ("data",))
    assert sharding.mesh_axes_for("kv_pages", mesh) == ()
    assert sharding.mesh_axis_size("kv_pages", mesh) == 1
    spec = sharding.spec_for((2, 8, 4, 4),
                             ("layers", "kv_pages", None, "kv_heads"), mesh)
    assert spec == jax.sharding.PartitionSpec(None, None, None, None)


def test_kv_pages_rule_on_model_mesh():
    out = _run("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel import sharding
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("model",))
        assert sharding.mesh_axes_for("kv_pages", mesh) == ("model",)
        assert sharding.mesh_axis_size("kv_pages", mesh) == 2
        # pool spec: kv_pages takes 'model'; kv_heads would too but the
        # axis is already used -> dropped (never double-sharded)
        s = sharding.spec_for((2, 8, 4, 4),
                              ("layers", "kv_pages", None, "kv_heads"), mesh)
        assert s == P(None, "model", None, None), s
        # non-divisible page count falls back to replicated
        s = sharding.spec_for((2, 7, 4, 4),
                              ("layers", "kv_pages", None, "kv_heads"), mesh)
        assert s == P(None, None, None, "model"), s
        # tree_specs agrees leaf-wise
        from repro.models.module import ParamSpec
        import jax.numpy as jnp
        tree = {"k": ParamSpec((2, 8, 4, 4),
                               ("layers", "kv_pages", None, "kv_heads"),
                               "zeros", jnp.int8)}
        ns = sharding.tree_specs(tree, mesh)
        assert ns["k"].spec == P(None, "model", None, None), ns
        # constrain inside the serving shard_map is a no-op (axis Manual)
        def f(x):
            y = sharding.constrain(x, ("kv_pages", None))
            return y * 1.0
        x = jnp.zeros((8, 4))
        r = jax.jit(sharding.shard_map(
            f, mesh, in_specs=P("model", None),
            out_specs=P("model", None)))(x)
        assert r.shape == x.shape
        print("RULES-OK")
    """)
    assert "RULES-OK" in out


# ---------------------------------------------------------------------------
# partial merge numerics (1 device): split ownership == unsharded kernel
# ---------------------------------------------------------------------------


def test_merge_partials_matches_full_kernel():
    """Run the paged-attention kernel over one pool twice with
    complementary page_ok ownership masks, merge the (o, m, l) partials
    with the log-sum-exp rule, and require the full-kernel output —
    including rows whose pages all live on one 'owner' (the bitwise
    single-shard case) and a slot with an all-masked owner."""
    rng = np.random.default_rng(0)
    B, Hq, Hkv, Dh, ps, M = 3, 2, 1, 4, 4, 4
    n_pages = 8
    k = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv * Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv * Dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, Hq, Dh)), jnp.float32)
    bt = jnp.asarray([[1, 2, 3, 0],     # split across both owners
                      [4, 5, 0, 0],     # entirely owner-1 pages
                      [6, 7, 1, 2]], jnp.int32)
    lengths = jnp.asarray([11, 6, 15], jnp.int32)
    window = jnp.full((B,), 1 << 30, jnp.int32)

    full = ops.paged_attention(q, k, v, bt, lengths, window)

    own0 = jnp.asarray(np.isin(np.asarray(bt), [1, 2, 3]), jnp.int32)
    own1 = jnp.asarray(np.isin(np.asarray(bt), [4, 5, 6, 7]), jnp.int32)
    parts = [ops.paged_attention(q, k, v, bt, lengths, window,
                                 page_ok=ok, partials=True)
             for ok in (own0, own1)]
    o = jnp.stack([p[0] for p in parts])
    m = jnp.stack([p[1] for p in parts])
    l = jnp.stack([p[2] for p in parts])
    merged = jax.vmap(lambda oo, mm, ll:
                      ops.merge_attn_partials(oo, mm, ll, "owners"),
                      axis_name="owners")(o, m, l)
    # psum/pmax under vmap broadcast the merged state to every element
    np.testing.assert_allclose(np.asarray(merged[0]), np.asarray(full),
                               rtol=1e-6, atol=1e-6)
    # slot 1's pages are all owner-1: its merge must be bitwise the
    # unsharded finalize (owner-0 contributes w*l = 0)
    np.testing.assert_array_equal(np.asarray(merged[0][1]),
                                  np.asarray(full[1]))


# ---------------------------------------------------------------------------
# multi-device integration (subprocess: forced host device counts)
# ---------------------------------------------------------------------------

_ARCHS = {"transformer": "command_r_35b",
          "moe": "qwen3_moe_235b",
          "hybrid": "jamba_1_5_large"}


@pytest.mark.parametrize("family", sorted(_ARCHS))
@pytest.mark.parametrize("kv", ["f32", "coded"])
def test_mesh_engine_token_parity(family, kv):
    """A 2-device mesh engine must emit token-identical streams to the
    1-device engine on the same queue — mixed prompt lengths, shared and
    duplicate prefixes (COW), sampling on — and reclaim every page on
    every shard once the queue drains."""
    out = _run(f"""
        import jax, numpy as np
        from repro import configs
        from repro.core.formats import P8_2, P16_2
        from repro.core.quant import QuantPolicy
        from repro.models import api
        from repro.serve import Request, ServingEngine
        from repro.launch.mesh import make_serving_mesh

        quant = QuantPolicy() if "{kv}" == "f32" else \\
            QuantPolicy(weights=P16_2, kv_cache=P8_2)
        cfg = configs.get_tiny_serving("{_ARCHS[family]}", quant)
        params = api.init(jax.random.key(0), cfg)
        rng = np.random.default_rng(0)
        base = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
        prompts = [
            base.copy(),                              # donor
            base.copy(),                              # exact dup -> COW
            np.concatenate([base[:8], rng.integers(  # shared full pages
                0, cfg.vocab_size, 5).astype(np.int32)]),
            rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
        ]

        def run(mesh):
            eng = ServingEngine(cfg, params, batch_slots=2, max_seq=64,
                                greedy=False, temperature=0.8, top_k=8,
                                mesh=mesh)
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=i, prompt=p.copy(),
                                   max_new_tokens=6))
            done = eng.run()
            return {{r.rid: list(r.out_tokens) for r in done}}, eng

        ref, e1 = run(None)
        got, e2 = run(make_serving_mesh(2))
        assert e2.n_shards == 2, e2.n_shards
        assert got == ref, (got, ref)
        assert e2.allocator.pages_in_use == 0, \\
            e2.allocator.pages_in_use_by_shard
        assert e2.allocator.pages_in_use_by_shard == [0, 0]
        assert not e2.allocator._refs and not e2._held
        assert all(not p for p in e2.slot_pages)
        print("PARITY-OK")
    """)
    assert "PARITY-OK" in out


def test_mesh_engine_per_device_budget_guard_and_validation():
    """Admission must reject a request that cannot fit the sharded pool
    (capacity loses one trash page per device), name the per-device
    budgets, and the engine must reject meshes whose >1 axes the kv_pages
    rule does not cover."""
    out = _run("""
        import jax, numpy as np
        from repro import configs
        from repro.models import api
        from repro.serve import Request, ServingEngine
        from repro.launch.mesh import make_serving_mesh

        cfg = configs.get_tiny_serving("command_r_35b")
        params = api.init(jax.random.key(0), cfg)
        mesh = make_serving_mesh(2)
        ps = cfg.quant.kv_page_size
        # n_pages=4 over 2 devices: capacity 2 (one trash per shard)
        eng = ServingEngine(cfg, params, batch_slots=1, max_seq=4 * ps,
                            n_pages=4, mesh=mesh)
        assert eng.allocator.capacity == 2
        big = Request(rid=0, prompt=np.zeros(2 * ps + 1, np.int32),
                      max_new_tokens=ps)
        try:
            eng.submit(big)
            raise AssertionError("oversized request admitted")
        except ValueError as e:
            assert "per-device budgets" in str(e), e
        # the same pool on 1 device has capacity 3: the request fits
        e1 = ServingEngine(cfg, params, batch_slots=1, max_seq=4 * ps,
                           n_pages=4)
        e1.submit(Request(rid=0, prompt=np.zeros(2 * ps + 1, np.int32),
                          max_new_tokens=ps))

        # a >1 mesh axis kv_pages does not shard over is rejected
        mesh2 = jax.make_mesh((2, 1), ("data", "model"))
        try:
            ServingEngine(cfg, params, batch_slots=1, max_seq=4 * ps,
                          mesh=mesh2)
            raise AssertionError("data-axis mesh accepted")
        except ValueError as e:
            assert "kv_pages" in str(e), e
        # n_pages not divisible by the shard count is rejected
        try:
            ServingEngine(cfg, params, batch_slots=1, max_seq=4 * ps,
                          n_pages=5, mesh=mesh)
            raise AssertionError("indivisible pool accepted")
        except ValueError as e:
            assert "divisible" in str(e) or "n_shards" in str(e), e
        print("GUARD-OK")
    """)
    assert "GUARD-OK" in out


def test_mesh_engine_reclaims_after_oversubscribed_drain():
    """An oversubscribed queue (pool smaller than the queue's total
    demand, forcing admission to wait for reclamation and pages to spill
    across shards) must drain completely: every per-device budget returns
    to full and the prefix index and holds empty out."""
    out = _run("""
        import jax, numpy as np
        from repro import configs
        from repro.core.formats import P8_2, P16_2
        from repro.core.quant import QuantPolicy
        from repro.models import api
        from repro.serve import Request, ServingEngine
        from repro.launch.mesh import make_serving_mesh

        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        ps = cfg.quant.kv_page_size
        rng = np.random.default_rng(3)
        base = rng.integers(0, cfg.vocab_size, 2 * ps).astype(np.int32)
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=6 * ps,
                            n_pages=8, mesh=make_serving_mesh(2))
        for i in range(6):
            tail = rng.integers(0, cfg.vocab_size,
                                rng.integers(1, 2 * ps)).astype(np.int32)
            prompt = np.concatenate([base, tail]) if i % 2 else tail
            eng.submit(Request(rid=i, prompt=prompt,
                               max_new_tokens=int(rng.integers(1, ps))))
        done = eng.run()
        assert len(done) == 6, len(done)
        a = eng.allocator
        assert a.pages_in_use == 0 and a.pages_free == a.capacity
        assert a.pages_free_by_shard == [a.pages_per_shard - 1] * 2
        assert not a._refs and not eng._held and not eng.prefix_index
        assert all(not p for p in eng.slot_pages)
        occ = eng.execution_summary()["pages_in_use_by_shard"]
        assert occ == [0, 0], occ
        print("DRAIN-OK")
    """)
    assert "DRAIN-OK" in out


# ---------------------------------------------------------------------------
# multi-query (4-D q) partials merge + the fused sharded prefill path
# ---------------------------------------------------------------------------


def test_merge_partials_matches_full_kernel_mq():
    """The owner-split log-sum-exp merge under the multi-query grid:
    complementary page_ok masks over 4-D q [B, T, Hq, Dh] must merge to
    the full-kernel output, bitwise for slots whose pages all live on
    one owner — the decode-side guarantee the sharded engine leans on
    when several new tokens per slot decode in one launch."""
    rng = np.random.default_rng(0)
    B, T, Hq, Hkv, Dh, ps, M = 3, 4, 2, 1, 4, 4, 4
    n_pages = 8
    k = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv * Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv * Dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, T, Hq, Dh)), jnp.float32)
    bt = jnp.asarray([[1, 2, 3, 0],     # split across both owners
                      [4, 5, 0, 0],     # entirely owner-1 pages
                      [6, 7, 1, 2]], jnp.int32)
    lengths = jnp.asarray([11, 6, 15], jnp.int32)
    window = jnp.full((B,), 1 << 30, jnp.int32)

    full = ops.paged_attention(q, k, v, bt, lengths, window)

    own0 = jnp.asarray(np.isin(np.asarray(bt), [1, 2, 3]), jnp.int32)
    own1 = jnp.asarray(np.isin(np.asarray(bt), [4, 5, 6, 7]), jnp.int32)
    parts = [ops.paged_attention(q, k, v, bt, lengths, window,
                                 page_ok=ok, partials=True)
             for ok in (own0, own1)]
    o = jnp.stack([p[0] for p in parts])
    m = jnp.stack([p[1] for p in parts])
    l = jnp.stack([p[2] for p in parts])
    merged = jax.vmap(lambda oo, mm, ll:
                      ops.merge_attn_partials(oo, mm, ll, "owners"),
                      axis_name="owners")(o, m, l)
    np.testing.assert_allclose(np.asarray(merged[0]), np.asarray(full),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(merged[0][1]),
                                  np.asarray(full[1]))


def test_mesh_engine_fused_prefill_slot_spanning_all_shards():
    """One long prompt whose pages land on every shard, prefilled through
    the fused global-pool kernel (the default — history pages stream from
    the all-gathered pool by global id): the 2-device stream must be
    token-identical to the 1-device engine running the *decomposed*
    prefill path — crossing both the fused/decomposed and the
    sharded/unsharded boundaries at once — and the slot's pages must
    actually occupy both shards mid-flight."""
    out = _run("""
        import jax, numpy as np
        from repro import configs
        from repro.core.formats import P8_2, P16_2
        from repro.core.quant import QuantPolicy
        from repro.models import api
        from repro.serve import Request, ServingEngine
        from repro.launch.mesh import make_serving_mesh

        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        ps = cfg.quant.kv_page_size
        rng = np.random.default_rng(7)
        # spans > pages_per_shard pages, so one slot must spill shards
        prompt = rng.integers(0, cfg.vocab_size, 5 * ps + 3).astype(np.int32)

        def run(mesh, fused):
            eng = ServingEngine(cfg, params, batch_slots=1,
                                max_seq=8 * ps, n_pages=12, mesh=mesh,
                                fused_prefill=fused)
            eng.submit(Request(rid=0, prompt=prompt.copy(),
                               max_new_tokens=4))
            while eng.pages_in_use == 0:
                eng.step()
            by_shard = eng.allocator.pages_in_use_by_shard
            done = eng.run()
            assert len(done) == 1
            return list(done[0].out_tokens), by_shard, eng

        ref_toks, _, e1 = run(None, fused=False)
        got_toks, by_shard, e2 = run(make_serving_mesh(2), fused=True)
        assert e2.cfg.quant.fused_prefill
        assert e2.execution_summary()["fused_prefill"]
        assert len(by_shard) == 2 and all(n > 0 for n in by_shard), by_shard
        assert got_toks == ref_toks, (got_toks, ref_toks)
        assert e2.allocator.pages_in_use == 0
        print("SPAN-OK", by_shard)
    """)
    assert "SPAN-OK" in out


def test_mesh_engine_long_prompt_multi_chunk_fused_parity():
    """Needle-style long prompt spanning >= 3 flash chunks of streamed
    history on a 2-device mesh: with paged.FLASH_CHUNK shrunk to 16, a
    53-token prompt forces the fused prefill kernel through multiple
    in-kernel flash softmax steps over all-gathered history pages while
    the fused decode epilogue runs each step as ONE device program.  The
    stream must be token-identical to the 1-device fully-decomposed
    engine, for the base prompt and with the needle token flipped."""
    out = _run("""
        import jax, numpy as np
        from repro import configs
        from repro.core.formats import P8_2, P16_2
        from repro.core.quant import QuantPolicy
        from repro.models import api, paged
        from repro.serve import Request, ServingEngine
        from repro.launch.mesh import make_serving_mesh

        paged.FLASH_CHUNK = 16  # page_size 16 divides it: fused gate holds
        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
        params = api.init(jax.random.key(0), cfg)
        rng = np.random.default_rng(11)
        needle = rng.integers(0, cfg.vocab_size,
                              3 * paged.FLASH_CHUNK + 5).astype(np.int32)
        flipped = needle.copy()
        flipped[1] = (needle[1] + 1) % cfg.vocab_size

        def run(mesh, fused, prompt):
            eng = ServingEngine(cfg, params, batch_slots=1, max_seq=64,
                                mesh=mesh, fused_prefill=fused,
                                fused_decode=fused)
            eng.submit(Request(rid=0, prompt=prompt.copy(),
                               max_new_tokens=4))
            done = eng.run()
            assert len(done) == 1
            return list(done[0].out_tokens), eng.execution_summary()

        mesh = make_serving_mesh(2)
        for prompt in (needle, flipped):
            ref, s_ref = run(None, False, prompt)
            got, s = run(mesh, True, prompt)
            assert got == ref, (got, ref)
            assert s["fused_prefill"] and s["fused_decode"]
            assert s["prefill_chunks"] == s_ref["prefill_chunks"] > 0
            assert s["prefill_device_programs"] == s["prefill_chunks"]
            assert s_ref["prefill_device_programs"] == \\
                3 * s_ref["prefill_chunks"]
            assert s["decode_device_programs"] == s["decode_steps"]
            assert s_ref["decode_device_programs"] == \\
                2 * s_ref["decode_steps"]
        print("NEEDLE-OK")
    """)
    assert "NEEDLE-OK" in out
