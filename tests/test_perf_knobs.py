"""Perf-knob semantics: every §Perf optimization must be a pure
performance transform — model outputs unchanged (up to fp tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, moe as moe_m
from repro.models.module import init_params
from repro.optim import adamw, constant_schedule
from repro.train import step as step_lib


def test_grouped_dispatch_matches_flat_when_no_drops():
    cfg = configs.get_smoke("qwen3_moe_235b").replace(capacity_factor=8.0)
    params = init_params(jax.random.key(0), moe_m.param_specs(cfg))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    l_flat, a1 = moe_m.apply(params, {"tokens": tokens}, cfg, with_aux=True)
    l_grp, a2 = moe_m.apply(params, {"tokens": tokens},
                            cfg.replace(moe_grouped_dispatch=True), with_aux=True)
    assert float(jnp.max(jnp.abs(l_flat - l_grp))) < 5e-4
    assert abs(float(a1 - a2)) < 1e-5


def test_grouped_dispatch_trains(rng):
    cfg = configs.get_smoke("deepseek_moe_16b").replace(moe_grouped_dispatch=True)
    opt = adamw(constant_schedule(1e-3))
    state = step_lib.init_state(jax.random.key(0), cfg, opt)
    ts = jax.jit(step_lib.make_train_step(cfg, opt, accum=1))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    losses = []
    for _ in range(5):
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


@pytest.mark.parametrize("knobs", [
    {"cast_params_early": True},
    {"tp_bf16_reduce": True},
    {"fsdp_gather_weights": True},
    {"cast_params_early": True, "tp_bf16_reduce": True,
     "fsdp_gather_weights": True},
], ids=lambda k: "+".join(k))
def test_dense_knobs_preserve_forward(knobs, rng):
    base = configs.get_smoke("minitron_8b").replace(dtype="float32")
    params = api.init(jax.random.key(0), base)
    tokens = {"tokens": jnp.asarray(rng.integers(0, base.vocab_size, (2, 16)),
                                    jnp.int32)}
    l0 = api.apply(params, tokens, base)
    l1 = api.apply(params, tokens, base.replace(**knobs))
    # f32 smoke: knobs are sharding/dtype transforms, outputs must agree
    assert float(jnp.max(jnp.abs(l0 - l1))) < 1e-3


def test_bf16_norm_close_to_f32_norm(rng):
    from repro.models import common
    x = jnp.asarray(rng.normal(0, 1, (4, 64)).astype(np.float32))
    s = jnp.asarray(rng.normal(0, 0.1, (64,)).astype(np.float32))
    a = common.rms_norm(x, s, upcast=True)
    b = common.rms_norm(x, s, upcast=False)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5  # identical in f32
