"""Every assigned config has full packable-path coverage: no weight family
consumed through the GEMM dispatch layer is silently left float.

The invariant: any param-spec leaf whose name is a qdot/qdot_grouped-consumed
weight (attention/MLP projections, routed and shared expert stacks, SSM
in/out projections, the untied head) MUST appear in packable_paths(cfg).
Leaves consumed outside the dispatch layer (norms, routers, embeddings,
conv taps, SSM scan params, stub frontend projections) are exempt.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.formats import P16_2
from repro.core.quant import QuantPolicy
from repro.models import api, packing
from repro.models.module import ParamSpec

# every leaf name consumed via dispatch.qdot / dispatch.qdot_grouped
QDOT_WEIGHT_NAMES = frozenset({
    "wq", "wk", "wv", "wo",                    # attention projections
    "wi_gate", "wi_up", "wo_mlp",              # dense FFN
    "we_gate", "we_up", "we_down",             # routed expert stacks
    "ws_gate", "ws_up", "ws_down",             # shared experts
    "in_proj", "out_proj",                     # SSM projections
    "head",                                    # untied vocab head
})


def _spec_paths(tree, prefix=()):
    if isinstance(tree, ParamSpec):
        yield prefix
        return
    for k, v in tree.items():
        yield from _spec_paths(v, prefix + (k,))


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_packable_paths_cover_every_qdot_weight(name):
    cfg = configs.get_smoke(name).replace(quant=QuantPolicy(weights=P16_2))
    specs = api.param_specs(cfg)
    declared = set(packing.packable_paths(cfg))
    present = {p for p in _spec_paths(specs)}
    # 1) every declared packable path exists in the spec tree
    missing = declared - present
    assert not missing, f"{name}: packable paths absent from specs: {missing}"
    # 2) every qdot-weight leaf in the spec tree is declared packable
    qdot_leaves = {p for p in present if p[-1] in QDOT_WEIGHT_NAMES}
    unpacked = qdot_leaves - declared
    assert not unpacked, (
        f"{name}: weight families silently left float: {sorted(unpacked)}")
    # 3) something actually packs for every family
    assert declared, f"{name}: no packable paths at all"


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_pack_params_round_trip_types(name):
    """pack_params output agrees with packed_param_specs leaf for leaf
    (dtype + shape) — the contract from_checkpoint restores against."""
    cfg = configs.get_smoke(name).replace(quant=QuantPolicy(weights=P16_2))
    params = api.init(jax.random.key(0), cfg)
    packed = api.pack_params(params, cfg)
    abstract = jax.tree.map(
        lambda s: s.abstract(), api.packed_param_specs(cfg),
        is_leaf=lambda s: isinstance(s, ParamSpec))
    flat_p = jax.tree.leaves(packed)
    flat_a = jax.tree.leaves(abstract)
    assert len(flat_p) == len(flat_a)
    for arr, st in zip(flat_p, flat_a):
        assert arr.shape == st.shape
        assert arr.dtype == st.dtype
    # packed leaves really shrink the storage footprint
    assert api.weight_bytes(packed) < api.weight_bytes(params)
    # and decode back to exactly the quantized masters
    restored = api.unpack_params(packed, cfg)
    path = packing.packable_paths(cfg)[0]
    leaf, master = restored, params
    for k in path:
        leaf, master = leaf[k], master[k]
    from repro.core import posit
    want = posit.quantize(jnp.asarray(master, jnp.float32), P16_2)
    assert (np.asarray(leaf) == np.asarray(want)).all()
