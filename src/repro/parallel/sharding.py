"""Logical-axis sharding: one rule table drives DP / FSDP / TP / EP / SP.

Every parameter and activation in `repro.models` is annotated with *logical*
axis names; this module maps them onto physical mesh axes, dropping any
assignment whose dimension is not divisible by the mesh axis (so the same
model code runs on 1 device, a 16x16 pod, or a 2x16x16 multi-pod mesh).

Physical axes:
  pod   : slowest interconnect (inter-pod DCN/ICI) — data parallel only
  data  : in-pod data parallel + FSDP parameter sharding
  model : tensor/expert parallel

Rule highlights (1000+-chip posture):
  batch        -> (pod, data)   activations data-parallel across everything
  heads/mlp/
  vocab/expert -> model         tensor & expert parallelism
  embed/ffout  -> data          ZeRO-3/FSDP: parameters sharded over the DP
                                axis, all-gathered by XLA at use site
  kv_seq       -> model         sequence-parallel KV cache for long decode
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes). None = replicate.
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",        # sequence-sharded KV cache (long-context decode)
    "kv_pages": "model",      # paged KV: the page pool shards over the same
                              # axis as kv_seq (a page is a sequence block)
    "embed": "data",          # FSDP shard of params' d_model dim
    "embed_act": None,        # activations keep embed replicated (TP gathers)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": "data",
    "expert_mlp": None,
    "vocab": "model",
    "layers": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    "stack": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple = tuple(sorted(DEFAULT_RULES.items()))

    def as_dict(self):
        return dict(self.rules)

    def replace(self, **kw):
        d = self.as_dict()
        d.update(kw)
        return ShardingRules(tuple(sorted(d.items())))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             mesh: Mesh, rules: ShardingRules = ShardingRules()) -> P:
    """Build a PartitionSpec, dropping assignments that don't divide evenly
    or that reference axes missing from this mesh (e.g. 'pod' on 1 pod)."""
    table = rules.as_dict()
    used = set()
    entries = []
    for dim, name in zip(shape, logical_axes):
        phys = table.get(name) if name is not None else None
        if phys is None:
            entries.append(None)
            continue
        phys_t = tuple(a for a in (phys if isinstance(phys, (tuple, list)) else (phys,))
                       if a in mesh.shape and a not in used)
        size = 1
        for a in phys_t:
            size *= mesh.shape[a]
        if size <= 1 or dim % size != 0:
            # retry with a shrinking prefix (e.g. (pod,data) -> (pod,))
            while phys_t and (size <= 1 or dim % size != 0):
                phys_t = phys_t[:-1]
                size = 1
                for a in phys_t:
                    size *= mesh.shape[a]
        if not phys_t or size <= 1 or dim % size != 0:
            entries.append(None)
            continue
        used.update(phys_t)
        entries.append(phys_t if len(phys_t) > 1 else phys_t[0])
    return P(*entries)


def sharding_for(shape, logical_axes, mesh, rules=ShardingRules()) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical_axes, mesh, rules))


def mesh_axes_for(logical: str, mesh: Mesh,
                  rules: ShardingRules = ShardingRules()) -> tuple:
    """Physical mesh axes the rule table maps `logical` onto, restricted to
    axes actually present in this mesh (absent axes — e.g. 'model' on a
    data-only mesh — are dropped, the same fallback `spec_for` applies)."""
    phys = rules.as_dict().get(logical)
    if phys is None:
        return ()
    phys_t = phys if isinstance(phys, (tuple, list)) else (phys,)
    return tuple(a for a in phys_t if a in mesh.shape)


def mesh_axis_size(logical: str, mesh: Mesh,
                   rules: ShardingRules = ShardingRules()) -> int:
    """Total device count a `logical` axis shards over on this mesh (1 when
    its physical axes are absent — the replicate fallback)."""
    out = 1
    for a in mesh_axes_for(logical, mesh, rules):
        out *= mesh.shape[a]
    return out


def shard_map(f, mesh, in_specs, out_specs):
    """Fully-manual shard_map, tolerant of the jax API move.

    New jax exposes `jax.shard_map(axis_names=..., check_vma=...)`; older
    releases only have `jax.experimental.shard_map.shard_map`.  We always
    go fully manual (every mesh axis): partial-manual (`auto=...`) trips
    XLA partitioner check-failures on older jaxlibs.  Used by the
    compressed train step (train/step.py) and the sharded serving engine
    (serve/engine.py)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, axis_names=set(mesh.axis_names),
                  in_specs=in_specs, out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def constrain(x, logical_axes, mesh: Optional[Mesh] = None,
              rules: ShardingRules = ShardingRules()):
    """with_sharding_constraint by logical axes; no-op without a mesh.

    Axes that are Manual in the current context (inside a shard_map) are
    dropped from the spec — the surrounding shard_map already owns them."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty or len(mesh.devices.flatten()) == 1:
        return x
    manual = _manual_axes()
    spec = spec_for(x.shape, logical_axes, mesh, rules)
    if manual:
        cleaned = []
        for entry in spec:
            if entry is None:
                cleaned.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a not in manual)
                cleaned.append(kept if kept else None)
            else:
                cleaned.append(None if entry in manual else entry)
        spec = P(*cleaned)
        if all(e is None for e in spec):
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _manual_axes():
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return set()
        return {name for name, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    except AttributeError:
        # older jax: no abstract-mesh introspection, but shard_map binds its
        # axes as named axes — anything in the axis env is manual here.
        try:
            from jax._src import core
            return set(core.get_axis_env().axis_sizes)
        except Exception:
            return set()
    except Exception:
        return set()


def _current_mesh() -> Optional[Mesh]:
    try:
        env = jax._src.mesh.thread_resources.env  # jax's implicit mesh ctx
        m = env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def tree_specs(spec_tree, mesh, rules=ShardingRules()):
    """Map a pytree of (shape, logical_axes) ParamSpecs (see models.module)
    to a pytree of NamedShardings."""
    from repro.models.module import ParamSpec  # local import to avoid cycle

    def one(ps):
        return sharding_for(ps.shape, ps.logical_axes, mesh, rules)

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
