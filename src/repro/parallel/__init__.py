"""Distribution: logical-axis sharding rules (DP/FSDP/TP/EP/SP)."""
from . import sharding  # noqa: F401
