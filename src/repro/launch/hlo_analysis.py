"""Trip-count-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts every computation ONCE — a
lax.scan over 64 layers reports 1/64th of the real FLOPs, and collectives
inside the scanned layer body (the dominant FSDP all-gathers!) are equally
undercounted.  This module parses the optimized (post-SPMD, per-device) HLO
text, builds the computation call graph, multiplies while-loop bodies by
their `known_trip_count`, and accumulates:

  * flops            : 2 * prod(out_dims) * contracted_size per dot op
  * collective bytes : output bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute
  * hbm bytes        : operand+output bytes of top-level (fusion-level) ops
                       — a standard post-fusion traffic proxy

All numbers are per-device (the compiled module is the per-device program).
Verified against hand-counted models in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls|called_computations)=\{?%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[\\\":{ ]+n[\\\": ]+(\d+)')
_OPERANDS = re.compile(r"%([\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        b = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Computation:
    def __init__(self, name):
        self.name = name
        self.ops = []          # (name, shape_str, opcode, rest_of_line)
        self.shapes = {}       # op name -> shape str
        self.calls = []        # (child_name, multiplier)
        self.is_fusion_target = False


def parse_hlo(text: str):
    comps = {}
    cur = None
    for line in text.splitlines():
        header = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if header:
            cur = Computation(header.group(1))
            cur.is_entry = line.startswith("ENTRY")
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        cur.ops.append((name, shape, opcode, rest))
        cur.shapes[name] = shape
        if opcode in ("while",):
            body = None
            trip = 1
            for cm in _CALLED.finditer(rest):
                pass
            bm = re.search(r"body=%?([\w.\-]+)", rest)
            tm = _TRIP.search(rest)
            if tm:
                trip = int(tm.group(1))
            if bm:
                cur.calls.append((bm.group(1), trip))
            cm = re.search(r"condition=%?([\w.\-]+)", rest)
            if cm:
                cur.calls.append((cm.group(1), trip + 1))
        elif opcode == "conditional":
            for br in _BRANCHES.findall(rest):
                for b in re.findall(r"%?([\w.\-]+)", br):
                    cur.calls.append((b, 1))
        else:
            for cm in _CALLED.finditer(rest):
                cur.calls.append((cm.group(1), 1))
            if opcode == "fusion":
                km = re.search(r"calls=%?([\w.\-]+)", rest)
                if km:
                    pass  # already added via _CALLED
    return comps


def _multiplicities(comps):
    entry = None
    for c in comps.values():
        if getattr(c, "is_entry", False):
            entry = c.name
    mult = defaultdict(float)
    if entry is None:
        return mult
    # iterate to fixpoint over the DAG (call graph is acyclic in HLO)
    mult[entry] = 1.0
    order = list(comps)
    for _ in range(len(order)):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for name, c in comps.items():
            m = mult.get(name, 0.0)
            if m == 0:
                continue
            for child, k in c.calls:
                if child in comps:
                    new[child] += m * k
        for k, v in new.items():
            if abs(mult.get(k, 0.0) - v) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return mult


def _mark_fusion_targets(comps):
    for c in comps.values():
        for _, shape, opcode, rest in c.ops:
            if opcode == "fusion":
                km = re.search(r"calls=%?([\w.\-]+)", rest)
                if km and km.group(1) in comps:
                    _mark_rec(comps, km.group(1))


def _mark_rec(comps, name):
    c = comps[name]
    if c.is_fusion_target:
        return
    c.is_fusion_target = True
    for child, _ in c.calls:
        if child in comps:
            _mark_rec(comps, child)


def analyze_hlo(text: str) -> dict:
    comps = parse_hlo(text)
    mult = _multiplicities(comps)
    _mark_fusion_targets(comps)

    flops = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_count = {k: 0 for k in _COLLECTIVES}
    hbm = 0.0

    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        for name, shape, opcode, rest in c.ops:
            if opcode == "dot":
                out_elems = 1
                for d in _shape_dims(shape):
                    out_elems *= d
                # contracted size from lhs shape + contracting dims
                ops_m = _OPERANDS.findall(rest)
                lhs = ops_m[0] if ops_m else None
                cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                csize = 1
                if lhs and lhs in c.shapes and cd:
                    ldims = _shape_dims(c.shapes[lhs])
                    for i in (int(x) for x in cd.group(1).split(",") if x):
                        if i < len(ldims):
                            csize *= ldims[i]
                flops += m * 2.0 * out_elems * csize
            elif opcode == "convolution":
                # rough: 2 * out_elems * kernel_elems (enough for stubs)
                out_elems = 1
                for d in _shape_dims(shape):
                    out_elems *= d
                flops += m * 2.0 * out_elems
            elif opcode in _COLLECTIVES:
                b = _shape_bytes(shape)
                coll[opcode] += m * b
                coll_count[opcode] += int(m)
            # HBM traffic at fusion granularity: top-level ops only
            if not c.is_fusion_target and opcode not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "while", "conditional"):
                b = _shape_bytes(shape)
                for opnd in _OPERANDS.findall(rest):
                    if opnd in c.shapes:
                        b += _shape_bytes(c.shapes[opnd])
                hbm += m * b

    return {
        "flops": flops,
        "collective_bytes": sum(coll.values()),
        "collective_per_op": coll,
        "collective_counts": coll_count,
        "hbm_bytes": hbm,
    }
