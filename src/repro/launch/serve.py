"""Serving entry point (continuous batching).

    python -m repro.launch.serve --arch gemma3_4b --smoke --requests 8 \
        --quant serve_p16_kv8

Posit-native speculative decoding (draft policy proposes k tokens, one
batched multi-query verify dispatch commits the matching prefix — token
streams stay bitwise identical to plain decode):

    python -m repro.launch.serve --arch gemma3_4b --smoke --requests 8 \
        --quant serve_fused_p16 --speculate 4

Async front end — SLO classes, deadlines, preemption, per-token
streaming callbacks, TTFT/ITL histograms — lives in
`repro.serve.AsyncServingFrontend`; `examples/serve_async.py` is the
runnable walkthrough (mixed interactive/batch queue, a mid-flight
high-priority arrival preempting a batch slot, streaming dedup across
the replay, speculation on top):

    PYTHONPATH=src python examples/serve_async.py
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.quant import policy_by_name
from repro.models import api
from repro.serve import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default="none")
    ap.add_argument("--dense", action="store_true",
                    help="dense KV cache instead of the paged pool")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV page size (default: quant policy kv_page_size)")
    ap.add_argument("--sample", action="store_true",
                    help="temperature/top-k sampling instead of greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0,
                    help="shard the paged KV pool over this many devices "
                         "(0 = single-device pool)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative decoding span k (>= 2; draft policy "
                         "= quant.with_draft(), bitwise-identical tokens)")
    args = ap.parse_args()
    if not args.sample and (args.temperature != 1.0 or args.top_k):
        raise SystemExit("--temperature/--top-k only take effect with "
                         "--sample (greedy decoding ignores them)")

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.replace(quant=policy_by_name(args.quant))
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to serve")
    params = api.init(jax.random.key(0), cfg)
    mesh = None
    if args.mesh_model > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.mesh_model)
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           max_seq=args.max_seq, paged=not args.dense,
                           page_size=args.page_size,
                           greedy=not args.sample,
                           temperature=args.temperature, top_k=args.top_k,
                           speculate_k=args.speculate, mesh=mesh)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    layout = (f"paged(ps={engine.layout.page_size}, "
              f"peak={engine.allocator.peak_in_use}/"
              f"{engine.allocator.capacity} pages)"
              if engine.paged else "dense")
    print(f"[serve] {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s) kv dtype="
          f"{'posit' if cfg.quant.kv_cache else cfg.dtype} cache={layout}")
    if args.speculate:
        s = engine.execution_summary()
        print(f"[serve] speculation: k={s['speculate_k']} "
              f"rounds={s['speculation_rounds']} "
              f"accept_rate={s['speculation_accept_rate']:.3f} "
              f"committed={s['speculation_committed_tokens']}")
    if engine.paged and engine.n_shards > 1:
        occ = engine.allocator.pages_in_use_by_shard
        per = engine.allocator.pages_per_shard - 1
        print("[serve] per-device page occupancy: "
              + " ".join(f"d{i}={u}/{per}" for i, u in enumerate(occ)))


if __name__ == "__main__":
    main()
