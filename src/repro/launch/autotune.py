"""Autotune sweep CLI: regenerate the committed kernel tile cache.

    PYTHONPATH=src python -m repro.launch.autotune [--commit] [--out PATH]
                                                   [--reps N] [--quick]
                                                   [--oracle-check]

Sweeps every tunable kernel (kernels/autotune.TUNABLES) over the
serving-representative shape set below, prunes each candidate grid with
the roofline cost oracle, wall-clock times the survivors, and prints the
per-shape winners.  `--commit` rewrites the committed cache JSON
(`kernels/autotune_cache.json`, the CI-host cache that ops.py resolves
launch params from); `--out` writes anywhere else.  `--oracle-check`
additionally lowers each winner through XLA and prints the
launch/hlo_analysis FLOP/byte accounting next to the analytic oracle, as
a sanity check that the pruning model tracks the compiler's view.

The shape set is intentionally small: shapes are *bucketed* into the
cache key (kernels/autotune.shape_bucket), so each swept point covers
its whole power-of-two band.  The committed file is regenerated on the
CI host platform — entries from other backends are keyed separately and
never collide.
"""
from __future__ import annotations

import argparse
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P8_2, P13_2, P16_1, P16_2
from repro.kernels import autotune
from repro.kernels import paged_attention as paged_attention_mod
from repro.kernels import prefill_attention as prefill_attention_mod
from repro.kernels import posit_codec, posit_matmul


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# serving-representative sweep points: (shape, fmts) per kernel.  Shapes
# bucket to powers of two, so e.g. (512, 512) covers every codec call up
# to that band.
def sweep_points(quick: bool):
    codec = [((512, 512), (P16_2,)), ((2048, 512), (P16_1,)),
             ((1024, 1024), (P16_2,)), ((1024, 1024), (P8_2,))]
    mm = [((256, 256, 256), (P16_2, P16_2)),
          ((512, 512, 512), (P16_1, P16_1))]
    # the serving demo's smoke-config buckets (decode-step rows, chunk
    # prefill, activation-coded GEMMs), so the example's tuned-config
    # hit report shows live coverage rather than all-misses
    codec += [((r, c), (P13_2,)) for r in (8, 16, 64) for c in (64, 256)]
    codec += [((64, 512), (P16_2,))]
    mm += [((r, k, n), (P13_2, P16_2)) for r in (8, 16, 64)
           for k, n in ((64, 32), (64, 64), (64, 256), (256, 64))]
    grouped = [((4, 128, 128, 128), (None, P16_2))]
    paged = [((4, 8, 8, 16, 128), (P16_1,)),
             ((8, 8, 16, 16, 128), (P8_2,)),
             ((4, 8, 8, 4, 16), (P16_1,))]
    # fused prefill (B, C, M, ps, F): serving-default paged geometry plus
    # the tiny smoke-config band
    prefill = [((2, 64, 8, 16, 128), (P16_1,)),
               ((4, 64, 4, 16, 128), (P8_2,)),
               ((2, 16, 4, 16, 8), (P8_2,)),
               ((2, 16, 4, 16, 8), (P16_1,))]
    # fused decode epilogue (B, D, V): packed-head serving bands plus the
    # tiny smoke-config vocab and a float-master (fake_quant) point
    decode = [((4, 256, 4096), (P16_2,)),
              ((2, 16, 64), (P16_2,)),
              ((2, 64, 256), (None,))]
    if quick:
        codec, mm, grouped, paged = codec[:1], mm[:1], grouped[:1], paged[:1]
        prefill, decode = prefill[:1], decode[:1]
    return {"posit_codec.decode": codec, "posit_codec.encode": codec,
            "posit_matmul": mm, "posit_matmul_grouped": grouped,
            "paged_attention": paged,
            "prefill_attention": prefill,
            "decode_sample": decode}


def _runner(kernel: str, shape, fmts, rng):
    """Build `run(params) -> thunk` for one sweep point (see
    autotune.sweep); inputs are generated once and closed over."""
    interp = _interpret()
    if kernel in ("posit_codec.decode", "posit_codec.encode"):
        (fmt,) = fmts
        vals = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
        codes = posit.pack(vals, fmt)
        if kernel.endswith("decode"):
            return lambda p: functools.partial(
                posit_codec.decode, codes, fmt, interpret=interp, **p)
        return lambda p: functools.partial(
            posit_codec.encode, vals, fmt, interpret=interp, **p)
    if kernel in ("posit_matmul", "posit_matmul_grouped"):
        if kernel == "posit_matmul":
            M, K, N = shape
            a_shape, b_shape = (M, K), (K, N)
        else:
            E, M, K, N = shape
            a_shape, b_shape = (E, M, K), (E, K, N)
        fmt_a, fmt_b = fmts
        a = jnp.asarray(rng.normal(0, 1, a_shape), jnp.float32)
        if fmt_a is not None:
            a = posit.pack(a, fmt_a)
        b = posit.pack(jnp.asarray(rng.normal(0, 1, b_shape), jnp.float32),
                       fmt_b)
        fn = (posit_matmul.posit_matmul if kernel == "posit_matmul"
              else posit_matmul.posit_matmul_grouped)
        return lambda p: functools.partial(
            fn, a, b, fmt_a, fmt_b, None, interpret=interp, **p)
    if kernel == "paged_attention":
        B, T, M, ps, F = shape
        (fmt,) = fmts
        Dh = 64 if F % 128 == 0 else F // 2
        Hkv = F // Dh
        n_pages = 1 + B * M
        q = jnp.asarray(rng.normal(0, 1, (B, T, 4 * Hkv, Dh)), jnp.float32)
        kp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                    jnp.float32), fmt)
        vp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                    jnp.float32), fmt)
        bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
        lengths = jnp.full((B,), M * ps, jnp.int32)
        win = jnp.full((1,), 2 ** 30, jnp.int32)
        return lambda p: functools.partial(
            paged_attention_mod.paged_attention, q, kp, vp, bt, lengths,
            win, fmt_kv=fmt, interpret=interp, **p)
    if kernel == "prefill_attention":
        B, C, M, ps, F = shape
        (fmt,) = fmts
        Dh = 64 if F % 128 == 0 else F // 2
        Hkv = F // Dh
        n_pages = 1 + B * M
        q = jnp.asarray(rng.normal(0, 1, (B, C, 4 * Hkv, Dh)), jnp.float32)
        kc = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
        vc = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
        kp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                    jnp.float32), fmt)
        vp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                    jnp.float32), fmt)
        bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
        starts = jnp.full((B,), ps, jnp.int32)  # one history page
        win = jnp.full((1,), 2 ** 30, jnp.int32)
        return lambda p: functools.partial(
            prefill_attention_mod.prefill_attention_paged, q, kc, vc,
            kp, vp, bt, starts, win, fmt_kv=fmt, interpret=interp, **p)
    if kernel == "decode_sample":
        B, D, V = shape
        (fmt,) = fmts
        x = jnp.asarray(rng.normal(0, 1, (B, D)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 1, (D, V)), jnp.float32)
        plan = "fused" if fmt is not None else "fake_quant"
        if fmt is not None:
            w = posit.pack(w, fmt)
        noise = jnp.asarray(rng.gumbel(size=(B, V)), jnp.float32)
        temp = jnp.float32(0.8)
        # the sweep grid's 0 sentinel = whole vocab (ops.decode_sample
        # applies the same translation at dispatch time)
        return lambda p: functools.partial(
            paged_attention_mod.decode_sample, x, w, noise, temp,
            plan=plan, fmt_w=fmt, top_k=min(8, V), interpret=interp,
            v_block=(None if p["v_block"] == 0 else p["v_block"]))
    raise KeyError(kernel)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--commit", action="store_true",
                    help="rewrite the committed cache "
                         "(kernels/autotune_cache.json)")
    ap.add_argument("--out", default=None,
                    help="write the cache JSON to this path instead")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="one sweep point per kernel (smoke)")
    ap.add_argument("--prune-factor", type=float, default=4.0)
    ap.add_argument("--oracle-check", action="store_true",
                    help="lower each winner and print hlo_analysis "
                         "accounting next to the analytic oracle")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    cache = autotune.AutotuneCache()
    print(f"backend: {cache.backend} (interpret={_interpret()})")
    for kernel, points in sweep_points(args.quick).items():
        for shape, fmts in points:
            run = _runner(kernel, shape, fmts, rng)
            params, ms, table = autotune.sweep(
                kernel, shape, run, fmts=fmts, reps=args.reps,
                prune_factor=args.prune_factor)
            oracle_ms = autotune.oracle_cost(kernel, shape, params, fmts) * 1e3
            cache.put(kernel, shape, params, fmts=fmts, ms=ms,
                      oracle_ms=oracle_ms)
            timed = sum(1 for t in table if t["ms"] is not None)
            print(f"{kernel} @ {autotune.shape_bucket(shape)} "
                  f"{[autotune._fmt_name(f) for f in fmts]}: {params} "
                  f"({ms:.3f} ms; {timed}/{len(table)} timed)")
            if args.oracle_check:
                acct = autotune.hlo_cost(run(params))
                print(f"  hlo: flops={acct['flops']:.3g} "
                      f"hbm_bytes={acct['hbm_bytes']:.3g} "
                      f"oracle_ms={oracle_ms:.4f}")
    if args.commit or args.out:
        path = cache.save(args.out or autotune.DEFAULT_CACHE_PATH)
        print(f"wrote {len(cache.entries)} entries -> {path}")
    else:
        print(f"{len(cache.entries)} entries swept (dry run; "
              f"--commit to persist)")


if __name__ == "__main__":
    main()
