"""Training entry point.

    python -m repro.launch.train --arch gemma3_4b --smoke --steps 200
    python -m repro.launch.train --arch mamba2_1_3b --smoke \
        --quant paper_mixed --grad-compress

Full (non-smoke) configs on real hardware pick up the production mesh; on
this CPU container use --smoke, which is the same code path end to end
(models, quantization, trainer, checkpointing) at laptop scale.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.core.quant import policy_by_name
from repro.data import DataConfig, Pipeline
from repro.models.config import ShapeConfig, shape_by_name
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--quant", default="none",
                    help="none|paper_mixed|uniform_p16|serve_p16_kv8")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--shape", default=None,
                    help="assigned shape name (full-scale); default custom")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.replace(quant=policy_by_name(args.quant))
    if args.shape:
        shape = shape_by_name(args.shape)
    else:
        shape = ShapeConfig("custom", args.seq, args.batch, "train")

    pipe = Pipeline(cfg, shape, DataConfig(seed=0))
    opt = adamw(cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                                total=args.steps))
    trainer = Trainer(cfg, shape, opt, pipe,
                      TrainerConfig(total_steps=args.steps, log_every=10,
                                    ckpt_every=max(args.steps // 4, 1),
                                    ckpt_dir=args.ckpt_dir, accum=args.accum))
    state = trainer.run(jax.random.key(0))
    print(f"[train] done at step {int(state.step)}; "
          f"final loss {trainer.history[-1]['loss']:.4f}; "
          f"throughput {trainer.history[-1]['tokens_per_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
