"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first
device init, and tests must see a 1-device world.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ('data' x 'model'); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small explicit mesh for tests on host platform devices."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(n_devices: int = 0):
    """1-D mesh over the first n devices (0 = all) on the 'model' axis —
    the axis the sharding rules map kv_pages onto, so handing this to
    ServingEngine(mesh=...) shards the paged KV pool n_devices ways."""
    import numpy as np
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.sharding.Mesh(np.array(devs[:n]), ("model",))


HW = {
    # TPU v5e-class target constants for the roofline (per chip)
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link (~per-chip usable)
    "hbm_bytes": 16 * 2**30,
}
