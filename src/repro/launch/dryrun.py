"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE proof of distribution coherence without hardware: a successful
`.lower().compile()` on the production mesh means every sharding,
collective, and memory assignment is consistent; the compiled artifact's
cost/memory analysis feeds the roofline (EXPERIMENTS.md).

Usage:
    python -m repro.launch.dryrun --arch gemma3_4b --shape train_4k
    python -m repro.launch.dryrun --arch all --multi-pod
    python -m repro.launch.dryrun --arch all --shape all --both-meshes \
        --out experiments/dryrun
"""
# The host platform must present 512 virtual devices BEFORE jax initializes;
# these two lines must precede every other import (including repro.*).
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                     # noqa: E402
from repro.launch.mesh import make_production_mesh, HW  # noqa: E402
from repro.models import api                  # noqa: E402
from repro.models.config import shape_by_name, ALL_SHAPES  # noqa: E402
from repro.models.module import ParamSpec, abstract_params, param_bytes  # noqa: E402
from repro.optim import adamw, adafactor, cosine_schedule  # noqa: E402
from repro.parallel import sharding           # noqa: E402
from repro.train import step as step_lib      # noqa: E402

_IS_SPEC = lambda s: isinstance(s, ParamSpec)

# gradient-accumulation factor per train cell: microbatch 32 sequences
# divides both the 16-way and 32-way batch shardings and bounds live
# activations to one microbatch per layer under remat.
TRAIN_ACCUM = 8


def _opt_for(cfg):
    # >100B params: factored second moment keeps optimizer state in HBM
    n = param_bytes(api.param_specs(cfg)) / 4
    lr = cosine_schedule(3e-4, 2000, 100_000)
    return adafactor(lr) if n > 100e9 else adamw(lr)


def _spec_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: sharding.sharding_for(s.shape, s.logical_axes, mesh),
        spec_tree, is_leaf=_IS_SPEC)


def _opt_state_shardings(opt_state_abs, pspecs, mesh):
    """Build shardings for optimizer state: moments follow the parameter
    logical axes (matching trailing dims); scalars replicate."""
    flat_p, _ = jax.tree_util.tree_flatten(pspecs, is_leaf=_IS_SPEC)

    def for_array(a):
        # match a moment leaf to its parameter by shape suffix
        for ps in flat_p:
            if a.shape == ps.shape:
                return sharding.sharding_for(a.shape, ps.logical_axes, mesh)
            if len(ps.shape) >= 2 and a.shape == ps.shape[:-1]:  # adafactor vr
                return sharding.sharding_for(a.shape, ps.logical_axes[:-1], mesh)
            if len(ps.shape) >= 2 and a.shape == ps.shape[:-2] + ps.shape[-1:]:
                return sharding.sharding_for(
                    a.shape, ps.logical_axes[:-2] + ps.logical_axes[-1:], mesh)
        return sharding.sharding_for(a.shape, (None,) * len(a.shape), mesh)

    return jax.tree.map(for_array, opt_state_abs)


def _batch_shardings(batch_abs, mesh):
    return jax.tree.map(
        lambda a: sharding.sharding_for(
            a.shape, ("batch",) + (None,) * (len(a.shape) - 1), mesh),
        batch_abs)


# ---------------------------------------------------------------------------
# HLO collective-byte accounting (per-device program => per-device bytes)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> bytes; tuple shapes handled by caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in a (per-device) HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    # lines look like: '  %x = f32[8,128]{1,0} all-reduce(...)' or
    # '  ROOT %t = (f32[2,4]{...}, f32[2,4]{...}) all-gather(...)'
    pat = re.compile(
        r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z-]+)")
    for m in pat.finditer(hlo_text):
        shapes, op = m.groups()
        if op not in out:
            continue
        count[op] += 1
        if shapes.startswith("("):
            for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", shapes):
                out[op] += _shape_bytes(s)
        else:
            out[op] += _shape_bytes(shapes)
    total = sum(out.values())
    return {"per_op": out, "counts": count, "total_bytes": total}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def build_lowered(cfg, shape, mesh, accum=None):
    accum = accum or TRAIN_ACCUM
    pspecs = api.param_specs(cfg)
    params_abs = abstract_params(pspecs)
    params_sh = _spec_shardings(pspecs, mesh)
    batch_abs = api.input_specs(cfg, shape)
    batch_sh = _batch_shardings(batch_abs, mesh)

    with mesh:
        if shape.kind == "train":
            opt = _opt_for(cfg)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_sh = _opt_state_shardings(opt_abs, pspecs, mesh)
            state_abs = step_lib.TrainState(params_abs, opt_abs,
                                            jax.ShapeDtypeStruct((), jnp.int32))
            state_sh = step_lib.TrainState(
                params_sh, opt_sh,
                sharding.sharding_for((), (), mesh))
            fn = step_lib.make_train_step(cfg, opt, accum=accum)
            lowered = jax.jit(
                fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            cspecs = api.cache_specs(cfg, shape.global_batch, shape.seq_len) \
                if not cfg.is_encoder else None
            if cfg.is_encoder:
                fn = lambda p, b: api.apply(p, b, cfg)
                out_sh = None
            else:
                fn = lambda p, b: api.prefill(p, b, cfg, max_seq=shape.seq_len)
                out_sh = (None, _spec_shardings(cspecs, mesh))
            lowered = jax.jit(
                fn, in_shardings=(params_sh, batch_sh), out_shardings=out_sh,
            ).lower(params_abs, batch_abs)
        elif shape.kind == "decode":
            cspecs = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
            cache_abs = abstract_params(cspecs)
            cache_sh = _spec_shardings(cspecs, mesh)
            tokens_abs = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            tokens_sh = sharding.sharding_for(tokens_abs.shape, ("batch",), mesh)
            fn = lambda p, t, c: api.decode_step(p, t, c, cfg)
            lowered = jax.jit(
                fn, in_shardings=(params_sh, tokens_sh, cache_sh),
                out_shardings=(None, cache_sh), donate_argnums=(2,),
            ).lower(params_abs, tokens_abs, cache_abs)
        else:
            raise ValueError(shape.kind)
    return lowered


def analyze(lowered, compiled, cfg, shape, mesh, compile_s):
    from repro.launch.hlo_analysis import analyze_hlo

    n_dev = mesh.devices.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per partition
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    # trip-count-aware per-device analysis (XLA's cost_analysis counts loop
    # bodies once — useless for scanned layers; see hlo_analysis.py)
    hlo = analyze_hlo(compiled.as_text())
    flops = hlo["flops"]
    bytes_acc = hlo["hbm_bytes"]
    coll = {"per_op": hlo["collective_per_op"],
            "counts": hlo["collective_counts"],
            "total_bytes": hlo["collective_bytes"]}

    # --- roofline terms (per-device program -> per-chip seconds) ----------
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_coll = coll["total_bytes"] / HW["ici_bw"]
    # model flops: 6*N*D for train, 2*N*D for a forward/prefill token batch
    from repro.models.module import param_count
    n_params = param_count(api.param_specs(cfg))
    n_active = _active_params(cfg)
    if shape.kind == "train":
        model_flops = 6.0 * n_active * shape.tokens
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * shape.tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    terms = {
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": max(
            [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
            key=lambda kv: kv[1])[0],
        "model_flops_total": model_flops,
        "model_flops_per_dev": model_flops / n_dev,
        "hlo_flops_per_dev": flops,
        "useful_flops_ratio": (model_flops / n_dev) / flops if flops else None,
    }
    return {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "devices": n_dev,
        "params": n_params, "active_params": n_active,
        "compile_seconds": compile_s,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_info,
        "collectives": coll,
        "roofline": terms,
    }


def _active_params(cfg):
    """Parameters touched per token (MoE: top-k + shared only)."""
    from repro.models.module import param_count
    total = param_count(api.param_specs(cfg))
    if cfg.n_experts == 0:
        return total
    # subtract inactive routed-expert params
    expert = cfg.d_model * cfg.moe_d_ff * 3
    if cfg.family == "hybrid":
        n_moe_layers = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_moe(i))
    else:
        n_moe_layers = cfg.n_layers
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * expert
    return total - inactive


def run_cell(arch, shape_name, multi_pod, out_dir=None, cfg_overrides=None,
             tag="", accum=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = shape_by_name(shape_name)
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, accum=accum)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = analyze(lowered, compiled, cfg, shape, mesh, compile_s=t2 - t1)
    rec["lower_seconds"] = t1 - t0
    rec["mesh_tag"] = mesh_tag
    rec["variant"] = tag or "baseline"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}"
          f"{' x ' + tag if tag else ''}: OK "
          f"(lower {t1-t0:.1f}s compile {t2-t1:.1f}s) "
          f"dominant={rec['roofline']['dominant']} "
          f"flops/dev={rec['roofline']['hlo_flops_per_dev']:.3g} "
          f"coll={rec['collectives']['total_bytes']:.3g}B")
    print("  memory_analysis:", rec["memory_analysis"])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}__{shape_name}__{mesh_tag}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    ap.add_argument("--quant", default=None,
                    help="QuantPolicy name (none|paper_mixed|serve_p16_kv8|...)")
    ap.add_argument("--cast-params-early", action="store_true")
    ap.add_argument("--shard-expert-cap", action="store_true")
    ap.add_argument("--tp-bf16-reduce", action="store_true")
    ap.add_argument("--fsdp-gather-weights", action="store_true")
    ap.add_argument("--moe-grouped-dispatch", action="store_true")
    ap.add_argument("--accum", type=int, default=None,
                    help="gradient accumulation steps for train cells")
    args = ap.parse_args()

    overrides = {}
    if args.quant:
        from repro.core.quant import policy_by_name
        overrides["quant"] = policy_by_name(args.quant)
    if args.cast_params_early:
        overrides["cast_params_early"] = True
    if args.shard_expert_cap:
        overrides["shard_expert_cap"] = True
    if args.tp_bf16_reduce:
        overrides["tp_bf16_reduce"] = True
    if args.fsdp_gather_weights:
        overrides["fsdp_gather_weights"] = True
    if args.moe_grouped_dispatch:
        overrides["moe_grouped_dispatch"] = True

    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        shapes = ([s.name for s in configs.runnable_shapes(arch)]
                  if args.shape == "all" else [args.shape])
        for shape_name in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape_name, mp, args.out,
                             cfg_overrides=overrides or None, tag=args.tag,
                             accum=args.accum)
                except Exception as e:
                    failures.append((arch, shape_name, mp, repr(e)))
                    print(f"[dryrun] {arch} x {shape_name} x multipod={mp}: "
                          f"FAIL {e}")
                    traceback.print_exc()
                    if not args.continue_on_error:
                        raise
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all requested cells compiled.")


if __name__ == "__main__":
    main()
