"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only (wav2vec2-style backbone). [arXiv:2106.07447; unverified]

The convolutional audio frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings [B, S, 512].
Encoder-only => no decode_32k / long_500k cells.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, causal=False,
    frontend="audio_stub", frontend_dim=512,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="hubert-xlarge-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=59, frontend_dim=24,
    dtype="float32")

SHAPE_SKIPS = {
    "decode_32k": "encoder-only architecture: no autoregressive decode step",
    "long_500k": "encoder-only architecture: no autoregressive decode step",
}
