"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000, rope_theta=75_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="command-r-plus-104b-smoke", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, head_dim=16, d_ff=264, vocab_size=503, dtype="float32")

SHAPE_SKIPS = {
    "long_500k": "pure full attention: 500k-context decode excluded by "
                 "assignment rule",
}
