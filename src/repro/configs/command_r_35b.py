"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000, rope_theta=8_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="command-r-35b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=176, vocab_size=503, dtype="float32")

SHAPE_SKIPS = {
    "long_500k": "pure full attention (no sliding/SSM path): 500k-context "
                 "decode excluded by assignment rule",
}
