"""Architecture registry: the 10 assigned configs (+ paper workload).

Each module defines CONFIG (the exact assigned architecture) and
SMOKE (a reduced same-family config for CPU tests).  `get(name)` /
`get_smoke(name)` / `ARCH_NAMES` are the public API; `shape_skips(name)`
returns the assigned-shape cells this arch does not run, with reasons
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import importlib

ARCH_NAMES = (
    "command_r_35b",
    "command_r_plus_104b",
    "gemma3_4b",
    "minitron_8b",
    "hubert_xlarge",
    "qwen3_moe_235b",
    "deepseek_moe_16b",
    "jamba_1_5_large",
    "mamba2_1_3b",
    "paligemma_3b",
)

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}


def _module(name: str):
    name = _ALIASES.get(name, name)
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch '{name}' (have {ARCH_NAMES})")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def shape_skips(name: str) -> dict:
    """shape_name -> reason, for cells this arch skips by assignment rule."""
    return getattr(_module(name), "SHAPE_SKIPS", {})


def runnable_shapes(name: str):
    from repro.models.config import ALL_SHAPES
    skips = shape_skips(name)
    return tuple(s for s in ALL_SHAPES if s.name not in skips)
