"""Architecture registry: the 10 assigned configs (+ paper workload).

Each module defines CONFIG (the exact assigned architecture) and
SMOKE (a reduced same-family config for CPU tests).  `get(name)` /
`get_smoke(name)` / `ARCH_NAMES` are the public API; `shape_skips(name)`
returns the assigned-shape cells this arch does not run, with reasons
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import importlib

ARCH_NAMES = (
    "command_r_35b",
    "command_r_plus_104b",
    "gemma3_4b",
    "minitron_8b",
    "hubert_xlarge",
    "qwen3_moe_235b",
    "deepseek_moe_16b",
    "jamba_1_5_large",
    "mamba2_1_3b",
    "paligemma_3b",
)

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}


def _module(name: str):
    name = _ALIASES.get(name, name)
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch '{name}' (have {ARCH_NAMES})")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def get_tiny_serving(name: str, quant=None):
    """Reduced-further smoke config for fast CPU serving parity checks
    (shared by tests/test_paged_serving.py and the exec-path benchmark so
    both always measure the same geometry)."""
    cfg = get_smoke(name)
    shrink = {
        "command_r_35b": dict(n_layers=1, d_model=16, n_heads=2,
                              n_kv_heads=1, head_dim=8, d_ff=32,
                              vocab_size=64),
        "mamba2_1_3b": dict(n_layers=1, vocab_size=64),
        "jamba_1_5_large": dict(n_layers=2, d_model=32, d_ff=48,
                                moe_d_ff=48, vocab_size=64),
        "qwen3_moe_235b": dict(n_layers=1, d_model=32, n_experts=4,
                               top_k=2, moe_d_ff=16, vocab_size=64),
    }.get(_ALIASES.get(name, name), {})
    if quant is not None:
        shrink["quant"] = quant
    return cfg.replace(**shrink)


def shape_skips(name: str) -> dict:
    """shape_name -> reason, for cells this arch skips by assignment rule."""
    return getattr(_module(name), "SHAPE_SKIPS", {})


def runnable_shapes(name: str):
    from repro.models.config import ALL_SHAPES
    skips = shape_skips(name)
    return tuple(s for s in ALL_SHAPES if s.name not in skips)
