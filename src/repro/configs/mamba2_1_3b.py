"""mamba2-1.3b [ssm]: 48L d_model=2048, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

long_500k RUNS: attention-free, O(1) state per decoded token.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="mamba2-smoke", n_layers=2, d_model=64, vocab_size=97,
    ssm_state=16, ssm_head_dim=8, ssm_chunk=8, dtype="float32")

SHAPE_SKIPS = {}
