"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab_size=151936, rope_theta=1_000_000.0, qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=1536, moe_interval=1,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    capacity_factor=2.5,  # avoid routing drops at smoke scale (decode==forward tests)
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, vocab_size=499, n_experts=8, top_k=2,
    moe_d_ff=48, dtype="float32")

SHAPE_SKIPS = {
    "long_500k": "pure full attention: 500k-context decode excluded by "
                 "assignment rule",
}
