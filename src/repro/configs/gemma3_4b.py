"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global sliding attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

long_500k RUNS for this arch: 5-in-6 layers are sliding-window
(sub-quadratic); the global layers attend into the existing KV cache,
linear per decode step (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144, rope_theta=1_000_000.0,
    sliding_window=1024, global_interval=6, qk_norm=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="gemma3-4b-smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=160, vocab_size=511,
    sliding_window=8, dtype="float32")

SHAPE_SKIPS = {}
