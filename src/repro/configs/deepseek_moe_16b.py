"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained.
[arXiv:2401.06066; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=102400, rope_theta=10_000.0,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408, moe_interval=1,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    capacity_factor=2.5,  # avoid routing drops at smoke scale (decode==forward tests)
    name="deepseek-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, vocab_size=499, n_experts=8,
    n_shared_experts=2, top_k=3, moe_d_ff=32, dtype="float32")

SHAPE_SKIPS = {
    "long_500k": "pure full attention: 500k-context decode excluded by "
                 "assignment rule",
}
