"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]

long_500k RUNS: 7-in-8 layers are O(1)/token Mamba; the attention layers
read the 500k KV cache linearly per decode step.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536, rope_theta=10_000.0,
    attn_interval=8, moe_interval=2,
    n_experts=16, top_k=2, moe_d_ff=24576,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    capacity_factor=2.5,  # avoid routing drops at smoke scale (decode==forward tests)
    name="jamba-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=96, vocab_size=257, attn_interval=2, moe_interval=2,
    n_experts=4, top_k=2, moe_d_ff=96, ssm_state=16, ssm_head_dim=8,
    ssm_chunk=8, dtype="float32")

SHAPE_SKIPS = {}
