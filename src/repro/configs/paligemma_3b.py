"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384
vocab=257216 — SigLIP vision frontend + gemma decoder. [arXiv:2407.07726; hf]

The SigLIP tower is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings [B, 256, 1152] that are linearly projected and
prepended to the text sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, rope_theta=10_000.0,
    frontend="vision_stub", frontend_tokens=256, frontend_dim=1152,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="paligemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    head_dim=16, d_ff=128, vocab_size=509, frontend_tokens=4,
    frontend_dim=24, dtype="float32")

SHAPE_SKIPS = {
    "long_500k": "pure full attention: 500k-context decode excluded by "
                 "assignment rule",
}
