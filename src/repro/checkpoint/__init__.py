"""Atomic sharded async checkpointing with elastic restore."""
from .checkpoint import CheckpointManager  # noqa: F401
