"""Sharded, atomic, async, mesh-elastic checkpointing (no orbax offline).

Layout of one checkpoint:
    <dir>/step_000123.tmp-<nonce>/   (written)
        manifest.json                (tree structure, shapes, dtypes, step)
        shard_h000.npz               (this host's unique array shards)
    <dir>/step_000123/               (atomic rename after fsync)

Guarantees:
  * atomicity      — readers only ever see fully-written checkpoints
                     (tmp dir + rename; manifest written last)
  * async          — `save_async` snapshots to host RAM on the caller's
                     thread (device->host copy) and writes in background,
                     off the training critical path
  * elasticity     — the manifest stores *global* arrays; `restore` reshards
                     onto whatever mesh/device-count the restart has
                     (single-process runs store full arrays; a multi-host
                     deployment writes per-host unique shards — same format)
  * retention      — keep_last k, never deleting an unfinished write
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def tree_paths(tree):
    flat, _ = _flatten(tree)
    return sorted(flat)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------- write path ----------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             extra: Optional[dict] = None):
        """Snapshot to host, then write (optionally in the background).

        `extra` is caller metadata stored verbatim in the manifest — e.g.
        models.packing.pack_manifest(cfg) marks posit-packed weights so
        readers (ServingEngine.from_checkpoint) pick the right dtypes.
        """
        self.wait()  # one in-flight save at a time
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host copy

        if blocking:
            self._write(step, host, extra)
        else:
            self._thread = threading.Thread(
                target=self._write_guard, args=(step, host, extra), daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.save(step, tree, blocking=False, extra=extra)

    def _write_guard(self, step, host, extra):
        try:
            self._write(step, host, extra)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host: dict, extra: Optional[dict] = None):
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(os.path.join(final, "manifest.json")):
            return  # this step is already committed — idempotent save
        tmp = final + f".tmp-{os.getpid()}-{int(time.time()*1e6)}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_h000.npz"), **host)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "format": 1,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------- read path ----------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: int) -> dict:
        """The committed manifest of one checkpoint (shapes/dtypes/extra)."""
        path = os.path.join(self.dir, f"step_{step:09d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Load step onto the current mesh.

        `like` is a pytree of arrays or ShapeDtypeStructs defining the
        structure; `shardings` (same structure, optional) puts each leaf
        onto its (possibly different-than-at-save) sharding — this is the
        elastic-restart path.
        """
        self.wait()
        path = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(path, "shard_h000.npz")) as z:
            host = {k: z[k] for k in z.files}
        flat_like, treedef = _flatten(like)
        if set(flat_like) != set(host):
            missing = set(flat_like) ^ set(host)
            raise ValueError(f"checkpoint/tree structure mismatch: {sorted(missing)[:5]} ...")
        if shardings is not None:
            flat_sh, _ = _flatten(shardings)
        leaves = []
        # rebuild in treedef leaf order
        flat_items, _ = jax.tree_util.tree_flatten_with_path(like)
        ordered_keys = [
            _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            for path_, _ in flat_items]
        for key in ordered_keys:
            arr = host[key]
            if shardings is not None:
                leaves.append(jax.device_put(arr, flat_sh[key]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
