"""Serving engine: jit'd prefill/decode steps + continuous batching.

Slot-based continuous batching: the decode step always runs a fixed [B]
batch; finished sequences free their slot and the host control loop refills
it by prefilling a queued request into that slot (cache splice).  This is
the standard TPU serving shape (fixed shapes, no recompilation) — the KV
cache may be posit-coded per the model's QuantPolicy, halving/quartering
the decode memory roofline (the PDPU storage-format win).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: Optional[list] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_seq: int, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, cfg, max_seq=max_seq))
        self.cache = api.init_cache(cfg, batch_slots, max_seq)
        from repro.models.module import ParamSpec
        self.cache_bdim = jax.tree.map(
            lambda s: s.logical_axes.index("batch"),
            api.cache_specs(cfg, batch_slots, max_seq),
            is_leaf=lambda s: isinstance(s, ParamSpec))
        self.slot_free = [True] * batch_slots
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int64)
        self.next_token = np.zeros(batch_slots, np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _fill_slots(self):
        for slot in range(self.B):
            if not self.slot_free[slot] or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, cache1 = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None])})
            # splice single-row cache into this slot
            self.cache = jax.tree.map(
                lambda full, one, bdim: _slot_update(full, one, slot, bdim),
                self.cache, cache1, self.cache_bdim)
            tok = int(jnp.argmax(logits[0, -1]))
            req.out_tokens.append(tok)
            self.next_token[slot] = tok
            self.slot_free[slot] = False
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new_tokens - 1

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        self.done.append(req)
        self.slot_free[slot] = True
        self.slot_req[slot] = None

    def step(self):
        """One engine iteration: refill free slots, one decode step."""
        self._fill_slots()
        if all(self.slot_free):
            return False
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.next_token), self.cache)
        toks = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for slot in range(self.B):
            if self.slot_free[slot]:
                continue
            req = self.slot_req[slot]
            tok = int(toks[slot])
            req.out_tokens.append(tok)
            self.next_token[slot] = tok
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0 or (
                    req.eos_id is not None and tok == req.eos_id):
                self._retire(slot)
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or not all(self.slot_free)) and it < max_iters:
            if not self.step():
                break
            it += 1
        return self.done


def _slot_update(full, one, slot: int, bdim: int):
    """Insert a batch-1 cache leaf into slot `slot` along dim `bdim`
    (batch dims come from the cache ParamSpec logical axes)."""
    idx = tuple([slice(None)] * bdim + [slice(slot, slot + 1)])
    return full.at[idx].set(one.astype(full.dtype))
