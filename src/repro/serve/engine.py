"""Paged posit-KV serving runtime: block-table cache, chunked prefill,
page reclamation, continuous batching.

The engine is a slot scheduler over two jit'd model entry points, both with
fixed shapes (no per-request recompilation):

  * `prefill_chunk` — prompts are decomposed into chunks drawn from a small
    bucket table (e.g. 64/16/4/1 tokens, composed exactly — no padding), so
    serving a mixed-length queue compiles O(#buckets) prefill programs
    instead of O(#distinct lengths), and each chunk writes its KV straight
    into the slot's cache rows/pages — there is no whole-prompt prefill and
    no cache-splice `.at[].set` over the full cache.
  * `decode_step` — one token for all slots per iteration.

**Paged KV cache** (the default for attention families): the KV cache is a
pool of fixed-size pages `[n_pages, page_size, Hkv*Dh]` stored at the
QuantPolicy's `kv_cache` posit code width, plus a per-slot block table
(models/paged.py).  A host-side free-list allocator hands each admitted
request exactly the pages its prompt + token budget needs and reclaims them
at retirement — decode memory scales with *tokens in flight* at code width,
not with `batch_slots x max_seq` at f32.  Reclaimed pages are reused
without zeroing: every position is written before any attention may read
it, so stale keys cannot leak between requests.  The decode hot path runs
the Pallas paged-attention kernel (kernels/paged_attention.py): block-table
gather, in-kernel posit decode next to the q·k dot, streaming softmax — the
PDPU fused-decode idea applied to attention.  `paged=False` (or an SSM
family, whose recurrent state is already O(1)) serves the dense cache as a
special case of the same scheduler.

**Sampling**: greedy argmax by default; `greedy=False` enables temperature/
top-k sampling with a per-request seed (`Request.seed`, default the rid)
folded with the token index — reproducible across runs and independent of
batch composition or paged/dense layout.

Weights may equally be posit-coded: `from_checkpoint` restores a packed
checkpoint (models/packing.py) and the GEMM dispatch layer routes it
through the fused Pallas kernels (`execution='fused'`), including grouped
MoE expert stacks and activation-coded policies — see
`execution_summary()` for the datapath and the kv_bytes/metadata_bytes
storage split an engine is actually running.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.models.paged import PagedLayout


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    seed: Optional[int] = None   # sampling stream (defaults to rid)
    out_tokens: Optional[list] = None


class PageAllocator:
    """Host-side free-list over the KV page pool.

    Page 0 is reserved as the trash page (zeroed block-table rows direct
    stray writes/gathers there) and is never handed out."""

    def __init__(self, n_pages: int):
        self.capacity = n_pages - 1
        self.peak_in_use = 0
        self._free = list(range(n_pages - 1, 0, -1))  # pop() -> low ids first

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return out

    def free(self, pages: List[int]):
        self._free.extend(pages)


def _build_sampler(greedy: bool, top_k: int):
    """jit'd token sampler: logits [B, V] + per-row keys -> [B] int32."""

    def sample(logits, keys, temperature):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        l = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
        if top_k > 0 and top_k < l.shape[-1]:
            kth = jnp.sort(l, axis=-1)[..., -top_k][..., None]
            l = jnp.where(l >= kth, l, -1e30)
        return jax.vmap(jax.random.categorical)(keys, l).astype(jnp.int32)

    return jax.jit(sample)


_FREE, _PREFILL, _DECODE = 0, 1, 2


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_seq: int, greedy: bool = True, *,
                 temperature: float = 1.0, top_k: int = 0,
                 base_seed: int = 0, paged: bool = True,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 prefill_buckets=(64, 16, 4, 1),
                 prefill_chunks_per_step: int = 0):
        """batch_slots decode slots over a max_seq position budget per slot.

        paged=True (default) serves attention families from a posit-coded
        page pool; page_size defaults to cfg.quant.kv_page_size and n_pages
        to full capacity (batch_slots * pages_per_slot + trash page) —
        pass a smaller n_pages to oversubscribe (admission then waits for
        reclaimed pages).  prefill_chunks_per_step=0 completes a prompt's
        chunks at admission; k>0 interleaves at most k chunks per slot per
        engine step with ongoing decode (chunked prefill inside the decode
        loop).
        """
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.greedy = greedy
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.prefill_chunks_per_step = int(prefill_chunks_per_step)
        self.layout = None
        if paged:
            ps = cfg.quant.kv_page_size if page_size is None else page_size
            self.layout = PagedLayout.for_slots(batch_slots, max_seq, ps,
                                                n_pages)
        self.cache = api.init_cache(cfg, batch_slots, max_seq, self.layout)
        self.paged = "block_table" in self.cache  # SSM families: no pages
        if not self.paged:
            self.layout = None
        self.allocator = (PageAllocator(self.layout.n_pages)
                          if self.paged else None)
        self.max_pages_per_slot = (self.cache["block_table"].shape[1]
                                   if self.paged else 0)

        self.prefill_buckets = self._valid_buckets(prefill_buckets)
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, t, c, cfg))
        self._chunk = jax.jit(
            lambda p, t, c, s: api.prefill_chunk(p, t, c, s, cfg))
        # whole-prompt prefill, kept as a reference/debug probe only — the
        # serving path never calls it (chunked prefill replaces it)
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, cfg, max_seq=max_seq))
        self._sampler = _build_sampler(greedy, self.top_k)
        self._base_key = jax.random.key(base_seed)
        self._dummy_keys = jax.random.split(self._base_key, batch_slots)

        # host-owned scheduler state (device copies are refreshed per call)
        self.lengths = np.zeros(batch_slots, np.int32)
        self.block_tables = np.zeros(
            (batch_slots, max(self.max_pages_per_slot, 1)), np.int32)
        self.slot_phase = np.full(batch_slots, _FREE, np.int8)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pages: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_cursor = np.zeros(batch_slots, np.int64)  # prompt progress
        self.slot_remaining = np.zeros(batch_slots, np.int64)
        self.next_token = np.zeros(batch_slots, np.int32)
        self._slot_keys = [None] * batch_slots
        self._slot_sampled = np.zeros(batch_slots, np.int64)
        self.queue: List[Request] = []
        self.done: List[Request] = []

        # batch-dim index per cache leaf, for restoring rows of slots that
        # were mid-prefill during a decode call (page pools have no batch
        # dim — zeroed block-table rows protect them instead)
        from repro.models.module import ParamSpec
        specs = api.cache_specs(cfg, batch_slots, max_seq, self.layout)
        self._state_bdim = {
            name: (s.logical_axes.index("batch")
                   if "batch" in s.logical_axes else None)
            for name, s in specs.items()}

    def _valid_buckets(self, buckets):
        """Descending chunk sizes; 1 is always included (exact prompt
        decomposition), and sizes incompatible with the SSD chunk length
        are dropped (ssd_forward needs C % min(ssm_chunk, C) == 0)."""
        out = set(int(b) for b in buckets if b >= 1) | {1}
        if self.cfg.family in ("ssm", "hybrid"):
            q = self.cfg.ssm_chunk
            out = {b for b in out if b <= q or b % q == 0}
        return tuple(sorted(out, reverse=True))

    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, directory: str,
                        batch_slots: int, max_seq: int,
                        step: Optional[int] = None, **kw) -> "ServingEngine":
        """Restore params (float or posit-packed) and build an engine.

        The checkpoint manifest's `extra` metadata (models.packing.
        pack_manifest) decides the restore dtypes: packed checkpoints come
        back as int8/int16 code arrays that the dispatch layer consumes
        directly — no float materialization of the weights.
        """
        from repro.checkpoint import CheckpointManager
        from repro.models import packing
        from repro.models.module import abstract_params

        mgr = CheckpointManager(directory)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
        extra = mgr.read_manifest(step).get("extra") or {}
        if extra.get("packed_weights"):
            from repro.core.formats import PositFormat
            fmt = PositFormat(extra["weights_n"], extra["weights_es"])
            if cfg.quant.weights != fmt:
                # the dispatch layer decodes codes with cfg.quant.weights —
                # a silent mismatch would serve garbage values
                raise ValueError(
                    f"checkpoint packed as {fmt} but cfg.quant.weights is "
                    f"{cfg.quant.weights}; align the serving QuantPolicy "
                    f"with the pack format")
            specs = packing.packed_param_specs(cfg, fmt)
        else:
            specs = api.param_specs(cfg)
        params = mgr.restore(step, abstract_params(specs))
        return cls(cfg, params, batch_slots, max_seq, **kw)

    # ------------------------------------------------------------------
    # storage accounting
    # ------------------------------------------------------------------

    def weight_bytes(self) -> int:
        """Resident weight-storage bytes (int codes count at container width)."""
        from repro.models.packing import weight_bytes
        return weight_bytes(self.params)

    def kv_cache_summary(self) -> dict:
        """Decode-state storage split: `kv_bytes` is the K/V payload (pages
        or dense rows, plus SSM/conv state — at code width when posit-
        coded); `metadata_bytes` is positions + block tables.  The bench
        storage comparisons use kv_bytes — metadata must not dilute the
        coded-page win."""
        kv = meta = 0
        for name, leaf in self.cache.items():
            if name in ("length", "block_table"):
                meta += int(leaf.nbytes)
            else:
                kv += int(leaf.nbytes)
        out = {"kv_bytes": kv, "metadata_bytes": meta,
               "total_bytes": kv + meta}
        if self.paged:
            # bytes actually backing tokens in flight: what a pool sized to
            # the workload would allocate (decode memory scales with pages
            # in use at code width, not batch_slots x max_seq at f32)
            page_b = int(self.cache["k"].nbytes + self.cache["v"].nbytes) \
                // self.layout.n_pages
            out["kv_bytes_in_use"] = self.pages_in_use * page_b
            out["kv_bytes_peak"] = self.allocator.peak_in_use * page_b
        return out

    def kv_cache_bytes(self) -> int:
        """Total allocated decode-state bytes (payload + metadata); see
        kv_cache_summary() for the split."""
        return self.kv_cache_summary()["total_bytes"]

    @property
    def slot_free(self) -> List[bool]:
        """Per-slot availability (compat view over the phase array)."""
        return [bool(p == _FREE) for p in self.slot_phase]

    @property
    def pages_in_use(self) -> int:
        return self.allocator.pages_in_use if self.allocator else 0

    @property
    def pages_free(self) -> int:
        return self.allocator.pages_free if self.allocator else 0

    def execution_summary(self) -> dict:
        """Which datapath this engine serves on, plus its storage terms."""
        q = self.cfg.quant
        kv = self.kv_cache_summary()
        return {
            "execution": q.execution,
            "weights": str(q.weights) if q.weights else None,
            "activations": str(q.activations) if q.activations else None,
            "kv_cache": str(q.kv_cache) if q.kv_cache else None,
            "activation_coded": q.execution == "fused"
                                and q.activations is not None,
            "weight_bytes": self.weight_bytes(),
            "kv_cache_bytes": kv["total_bytes"],
            "kv_bytes": kv["kv_bytes"],
            "metadata_bytes": kv["metadata_bytes"],
            "paged": self.paged,
            "page_size": self.layout.page_size if self.paged else None,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.pages_free,
        }

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        n = len(req.prompt)
        if n < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1")
        # every written position must fit the slot's budget: positions
        # 0 .. n + max_new_tokens - 2 < max_seq.  Past-the-end writes would
        # silently wrap into the slot's last page (insert_tokens clips the
        # page index) / be silently dropped (dense scatter), corrupting or
        # losing KV — reject at submission instead.
        if n + req.max_new_tokens - 1 > self.S:
            raise ValueError(
                f"request {req.rid}: prompt ({n}) + max_new_tokens "
                f"({req.max_new_tokens}) needs {n + req.max_new_tokens - 1} "
                f"positions but max_seq is {self.S}")
        if self.paged and self._pages_needed(req) > self.allocator.capacity:
            raise ValueError(
                f"request {req.rid} needs {self._pages_needed(req)} pages "
                f"but the pool only has {self.allocator.capacity}; raise "
                f"n_pages or shorten prompt/max_new_tokens")
        req.out_tokens = []
        self.queue.append(req)

    def _pages_needed(self, req: Request) -> int:
        last_pos = len(req.prompt) + req.max_new_tokens - 2  # final write
        return min(last_pos // self.layout.page_size + 1,
                   self.max_pages_per_slot)

    def _chunk_sizes(self, n: int):
        """Exact greedy decomposition of n into bucket sizes (1 included)."""
        out = []
        for b in self.prefill_buckets:
            while n >= b:
                out.append(b)
                n -= b
        return out

    def _refresh_meta(self, cache, decode_mask=None):
        """Push host-owned lengths/block tables into the device cache.
        decode_mask zeroes rows of slots that must not touch real state
        during a decode call (free / mid-prefill slots)."""
        lengths = self.lengths.copy()
        if decode_mask is not None:
            lengths[~decode_mask] = 0
        cache = dict(cache)
        cache["length"] = jnp.asarray(lengths)
        if self.paged:
            bts = self.block_tables.copy()
            if decode_mask is not None:
                bts[~decode_mask] = 0
            cache["block_table"] = jnp.asarray(bts)
        return cache

    def _reset_slot_state(self, slot: int):
        """Zero a slot's recurrent/dense state rows before reuse (SSM and
        conv states are *seeded* by prefill — stale values would leak)."""
        new = {}
        for name, leaf in self.cache.items():
            bdim = self._state_bdim.get(name)
            if name in ("length", "block_table") or bdim is None:
                new[name] = leaf
                continue
            idx = (slice(None),) * bdim + (slot,)
            new[name] = leaf.at[idx].set(0)
        self.cache = new

    def _slot_key(self, req: Request):
        seed = req.seed if req.seed is not None else req.rid
        return jax.random.fold_in(self._base_key, seed)

    def _sample(self, logits_rows, slots, live=None):
        """Sample one token per row of logits_rows [n, V] for `slots`.
        `live` masks slots whose draw is discarded (dummy keys, counter
        not advanced) — lets the decode path sample a fixed [B, V] batch."""
        if self.greedy:  # argmax never reads keys: skip building them
            keys = self._dummy_keys[:len(slots)]
        else:
            keys = jnp.stack([
                jax.random.fold_in(self._slot_keys[s],
                                   int(self._slot_sampled[s]))
                if (live is None or live[s]) else self._dummy_keys[0]
                for s in slots])
            for s in slots:
                if live is None or live[s]:
                    self._slot_sampled[s] += 1
        toks = self._sampler(logits_rows, keys,
                             jnp.float32(self.temperature))
        return np.asarray(toks, np.int32)

    def _admit(self):
        """Move queued requests into free slots (allocating their pages)."""
        for slot in range(self.B):
            if self.slot_phase[slot] != _FREE or not self.queue:
                continue
            req = self.queue[0]
            if self.paged:
                # capacity was validated at submit(); a transient shortfall
                # here just waits for another request's pages to reclaim
                pages = self.allocator.alloc(self._pages_needed(req))
                if pages is None:
                    return  # wait for reclamation
                self.slot_pages[slot] = pages
                self.block_tables[slot] = 0
                self.block_tables[slot, :len(pages)] = pages
            self.queue.pop(0)
            self.slot_req[slot] = req
            self.slot_phase[slot] = _PREFILL
            self.slot_cursor[slot] = 0
            self.lengths[slot] = 0
            self._slot_keys[slot] = self._slot_key(req)
            self._slot_sampled[slot] = 0
            self._reset_slot_state(slot)

    def _release(self, slot: int):
        if self.paged:
            self.allocator.free(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self.block_tables[slot] = 0
        self.lengths[slot] = 0
        self.slot_phase[slot] = _FREE
        self.slot_req[slot] = None

    def _retire(self, slot: int):
        self.done.append(self.slot_req[slot])
        self._release(slot)

    def _advance_prefill(self, slot: int, max_chunks: Optional[int]) -> bool:
        """Run up to max_chunks prompt chunks for a prefilling slot (None =
        all remaining).  Returns True if any chunk ran."""
        req = self.slot_req[slot]
        prompt = np.asarray(req.prompt, np.int32)
        remaining = len(prompt) - int(self.slot_cursor[slot])
        sizes = self._chunk_sizes(remaining)
        if max_chunks is not None:
            sizes = sizes[:max_chunks]
        ran = False
        logits = None
        for c in sizes:
            lo = int(self.slot_cursor[slot])
            tokens = jnp.asarray(prompt[None, lo:lo + c])
            cache = self._refresh_meta(self.cache)
            logits, self.cache = self._chunk(self.params, tokens, cache,
                                             jnp.int32(slot))
            self.slot_cursor[slot] += c
            self.lengths[slot] += c
            ran = True
        if int(self.slot_cursor[slot]) >= len(prompt):
            # prompt complete: sample the first token from the last chunk
            tok = int(self._sample(logits[:, -1], [slot])[0])
            req.out_tokens.append(tok)
            if req.max_new_tokens <= 1 or (
                    req.eos_id is not None and tok == req.eos_id):
                self._retire(slot)  # finished at prefill: reclaim pages now
            else:
                self.next_token[slot] = tok
                self.slot_remaining[slot] = req.max_new_tokens - 1
                self.slot_phase[slot] = _DECODE
        return ran

    def _fill_slots(self) -> bool:
        """Admission + prefill progression for one engine step.  The
        per-step chunk budget applies per request: a request retiring at
        prefill frees its slot for the next queued one within the same
        step (so eos-at-prefill bursts never burn decode iterations)."""
        budget = self.prefill_chunks_per_step or None
        ran = False
        advanced = set()  # request ids already given their budget this step
        while True:
            self._admit()
            todo = [s for s in range(self.B)
                    if self.slot_phase[s] == _PREFILL
                    and id(self.slot_req[s]) not in advanced]
            if not todo:
                break
            for slot in todo:
                advanced.add(id(self.slot_req[slot]))
                if self._advance_prefill(slot, budget):
                    ran = True
        return ran

    def step(self) -> bool:
        """One engine iteration: admit/prefill, then one decode step for
        every decoding slot.  Returns False when the engine is idle: no
        slot is decoding and no prefill remains in flight."""
        self._fill_slots()
        decode_mask = self.slot_phase == _DECODE
        if not decode_mask.any():
            return bool((self.slot_phase == _PREFILL).any())
        cache_in = self._refresh_meta(self.cache, decode_mask)
        logits, new_cache = self._decode(
            self.params, jnp.asarray(self.next_token), cache_in)
        if (self.slot_phase == _PREFILL).any():
            # slots mid-prefill (interleaved mode) must not have their
            # recurrent/dense state rows advanced by this decode call
            mask = jnp.asarray(decode_mask)
            for name, leaf in new_cache.items():
                bdim = self._state_bdim.get(name)
                if name in ("length", "block_table") or bdim is None:
                    continue
                shape = [1] * leaf.ndim
                shape[bdim] = self.B
                m = mask.reshape(shape)
                new_cache[name] = jnp.where(m, leaf, self.cache[name])
        self.cache = new_cache
        # sample over the full fixed [B, V] batch (rows of non-decoding
        # slots draw from dummy keys and are discarded) so the jitted
        # sampler never retraces as slots retire
        slots = [s for s in range(self.B) if decode_mask[s]]
        toks = self._sample(logits, list(range(self.B)),
                            live=decode_mask)[np.asarray(slots)]
        for tok, slot in zip(toks, slots):
            req = self.slot_req[slot]
            req.out_tokens.append(int(tok))
            self.next_token[slot] = tok
            self.lengths[slot] += 1
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0 or (
                    req.eos_id is not None and int(tok) == req.eos_id):
                self._retire(slot)
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or (self.slot_phase != _FREE).any()) \
                and it < max_iters:
            if not self.step():
                break
            it += 1
        return self.done
