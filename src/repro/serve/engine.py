"""Serving engine: jit'd prefill/decode steps + continuous batching.

Slot-based continuous batching: the decode step always runs a fixed [B]
batch; finished sequences free their slot and the host control loop refills
it by prefilling a queued request into that slot (cache splice).  This is
the standard TPU serving shape (fixed shapes, no recompilation) — the KV
cache may be posit-coded per the model's QuantPolicy, halving/quartering
the decode memory roofline (the PDPU storage-format win).

Weights may equally be posit-coded: `from_checkpoint` restores a packed
checkpoint (models/packing.py) using the manifest's pack metadata, and the
GEMM dispatch layer routes the packed weights through the fused Pallas
kernel when cfg.quant.execution == 'fused' — posit codes HBM-to-MXU with
one in-kernel decode, end to end.  This includes MoE expert stacks: packed
`we_*` weights restore as [.., E, K, N] code arrays and run through the
grouped fused kernel (kernels/dispatch.qdot_grouped), so EP serving reads
expert weights at int8/int16 width too.

Activation-coded fused serving: a policy with `activations` set (e.g.
`serve_fused_p16_a13`, or any policy via
`QuantPolicy.with_serving_activations`) makes every matmul run the
both-operands `fused_matmul` path — activations are encoded to posit codes
and decoded inside the kernel next to the weights, so both GEMM operands
travel at code width (int8/int16) instead of f32.  The trade is one extra
rounding per activation element for halved/quartered operand bandwidth;
benchmarks/bench_exec_paths.py measures it.  `execution_summary()` reports
which datapath an engine is actually running.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: Optional[list] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_seq: int, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, cfg, max_seq=max_seq))
        self.cache = api.init_cache(cfg, batch_slots, max_seq)
        from repro.models.module import ParamSpec
        self.cache_bdim = jax.tree.map(
            lambda s: s.logical_axes.index("batch"),
            api.cache_specs(cfg, batch_slots, max_seq),
            is_leaf=lambda s: isinstance(s, ParamSpec))
        self.slot_free = [True] * batch_slots
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int64)
        self.next_token = np.zeros(batch_slots, np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []

    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, directory: str,
                        batch_slots: int, max_seq: int,
                        step: Optional[int] = None, **kw) -> "ServingEngine":
        """Restore params (float or posit-packed) and build an engine.

        The checkpoint manifest's `extra` metadata (models.packing.
        pack_manifest) decides the restore dtypes: packed checkpoints come
        back as int8/int16 code arrays that the dispatch layer consumes
        directly — no float materialization of the weights.
        """
        from repro.checkpoint import CheckpointManager
        from repro.models import packing
        from repro.models.module import abstract_params

        mgr = CheckpointManager(directory)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
        extra = mgr.read_manifest(step).get("extra") or {}
        if extra.get("packed_weights"):
            from repro.core.formats import PositFormat
            fmt = PositFormat(extra["weights_n"], extra["weights_es"])
            if cfg.quant.weights != fmt:
                # the dispatch layer decodes codes with cfg.quant.weights —
                # a silent mismatch would serve garbage values
                raise ValueError(
                    f"checkpoint packed as {fmt} but cfg.quant.weights is "
                    f"{cfg.quant.weights}; align the serving QuantPolicy "
                    f"with the pack format")
            specs = packing.packed_param_specs(cfg, fmt)
        else:
            specs = api.param_specs(cfg)
        params = mgr.restore(step, abstract_params(specs))
        return cls(cfg, params, batch_slots, max_seq, **kw)

    def weight_bytes(self) -> int:
        """Resident weight-storage bytes (int codes count at container width)."""
        from repro.models.packing import weight_bytes
        return weight_bytes(self.params)

    def kv_cache_bytes(self) -> int:
        """Allocated KV/state cache bytes for the current slot configuration."""
        return int(sum(v.nbytes for v in jax.tree.leaves(self.cache)))

    def execution_summary(self) -> dict:
        """Which datapath this engine serves on, plus its storage terms."""
        q = self.cfg.quant
        return {
            "execution": q.execution,
            "weights": str(q.weights) if q.weights else None,
            "activations": str(q.activations) if q.activations else None,
            "kv_cache": str(q.kv_cache) if q.kv_cache else None,
            "activation_coded": q.execution == "fused"
                                and q.activations is not None,
            "weight_bytes": self.weight_bytes(),
            "kv_cache_bytes": self.kv_cache_bytes(),
        }

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _fill_slots(self):
        for slot in range(self.B):
            # a request can finish at prefill (first token == eos, or
            # max_new_tokens == 1): it must not occupy the slot burning
            # decode steps until slot_remaining drains — complete it here
            # and keep pulling from the queue until a surviving request
            # actually occupies the slot
            while self.slot_free[slot] and self.queue:
                req = self.queue.pop(0)
                logits, cache1 = self._prefill(
                    self.params, {"tokens": jnp.asarray(req.prompt[None])})
                tok = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(tok)
                if req.max_new_tokens <= 1 or (
                        req.eos_id is not None and tok == req.eos_id):
                    self.done.append(req)  # finished at prefill: the slot
                    continue               # stays free, no cache splice
                # splice single-row cache into this slot
                self.cache = jax.tree.map(
                    lambda full, one, bdim: _slot_update(full, one, slot, bdim),
                    self.cache, cache1, self.cache_bdim)
                self.next_token[slot] = tok
                self.slot_free[slot] = False
                self.slot_req[slot] = req
                self.slot_remaining[slot] = req.max_new_tokens - 1

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        self.done.append(req)
        self.slot_free[slot] = True
        self.slot_req[slot] = None

    def step(self):
        """One engine iteration: refill free slots, one decode step."""
        self._fill_slots()
        if all(self.slot_free):
            return False
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.next_token), self.cache)
        toks = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for slot in range(self.B):
            if self.slot_free[slot]:
                continue
            req = self.slot_req[slot]
            tok = int(toks[slot])
            req.out_tokens.append(tok)
            self.next_token[slot] = tok
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0 or (
                    req.eos_id is not None and tok == req.eos_id):
                self._retire(slot)
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or not all(self.slot_free)) and it < max_iters:
            if not self.step():
                break
            it += 1
        return self.done


def _slot_update(full, one, slot: int, bdim: int):
    """Insert a batch-1 cache leaf into slot `slot` along dim `bdim`
    (batch dims come from the cache ParamSpec logical axes)."""
    idx = tuple([slice(None)] * bdim + [slice(slot, slot + 1)])
    return full.at[idx].set(one.astype(full.dtype))
