"""Paged posit-KV serving runtime: shared block-table cache, batched
chunked prefill, prefix sharing, page reclamation, continuous batching.

The engine is a slot scheduler over jit'd model entry points, all with
fixed shapes (no per-request recompilation):

  * `prefill_chunk_batched` — prompts are decomposed into chunks drawn from
    a small bucket table (e.g. 64/16/4/1 tokens, composed exactly — no
    padding), and all slots whose next chunk has the same bucket size run
    as ONE `[batch_slots, chunk]` program: serving a mixed-length queue
    compiles O(#buckets) prefill programs and issues one device call per
    (step, bucket) regardless of how many slots are filling.  Each chunk
    writes its KV straight into the slot's cache rows/pages — there is no
    whole-prompt prefill and no cache-splice over the full cache.
  * `decode_step` — one token for all slots per iteration.

**Shared paged KV cache** (the default for attention families): the KV
cache is a pool of fixed-size pages `[n_pages, page_size, Hkv*Dh]` stored
at the QuantPolicy's `kv_cache` posit code width, plus a per-slot block
table (models/paged.py).  The host-side allocator refcounts every page, so
one physical page may appear in many block tables:

  * a **prefix index** maps the hash of each prompt-token prefix that
    exactly fills a page to the physical page holding its KV.  A request
    whose prompt shares that prefix maps the donor's pages into its block
    table (refcount++) and only prefills the unshared tail — repeated-
    system-prompt traffic costs O(unique prefix) prefill compute and KV
    pages instead of O(requests x prompt).  Sharing stops at boundaries
    aligned with the request's own chunk decomposition, so shared serving
    is bit-identical to unshared serving.  For recurrent families
    (hybrid), index entries carry the donor's conv/SSM state snapshot at
    the boundary; entries without one are chain links only.
  * shared pages are **copy-on-write**: a page is immutable below its
    frozen prefix (the positions sharers trust).  A slot about to write
    below it first forks the page into a private copy (swapping its
    block-table entry); a donor appending decode tokens past every
    sharer's trusted range writes in place.  Admission pre-reserves each
    request's fork page, so a COW fork never allocates mid-flight — pages
    promised to admitted requests are accounted up front rather than per
    request in isolation.

**Sharded pools** (`mesh=...`): on a mesh with a >1 kv_pages axis the
pool's page dimension splits into contiguous per-device ranges with one
page budget per device; block tables keep *global* page ids (the id
contract lives in models/paged.py), every entry point runs under a
fully-manual shard_map, and decode log-sum-exp-merges per-device
streaming-softmax partials exactly — a multi-device engine is
token-identical to the 1-device engine over the same pool.

Pages reclaim at retirement (refcount--, recycled at zero, prefix-index
entries evicted) and are reused without zeroing: every position is written
before any attention may read it, so stale keys cannot leak.  The decode
hot path runs the Pallas paged-attention kernel
(kernels/paged_attention.py): block-table gather, in-kernel posit decode
next to the q·k dot, streaming softmax — the PDPU fused-decode idea
applied to attention.  `paged=False` (or an SSM family, whose recurrent
state is already O(1)) serves the dense cache as a special case of the
same scheduler.

**Sampling**: greedy argmax by default; `greedy=False` enables temperature/
top-k sampling with a per-request seed (`Request.seed`, default the rid)
folded with the token index — reproducible across runs and independent of
batch composition, paged/dense layout, or prefix sharing.

Weights may equally be posit-coded: `from_checkpoint` restores a packed
checkpoint (models/packing.py) and the GEMM dispatch layer routes it
through the fused Pallas kernels (`execution='fused'`), including grouped
MoE expert stacks and activation-coded policies — see
`execution_summary()` for the datapath and the kv_bytes/metadata_bytes
storage split an engine is actually running.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models import paged as paged_mod
from repro.models.config import ModelConfig
from repro.models.paged import (PagedLayout, PageShard, fork_page,
                                fused_prefill_span_ok)
from repro.parallel import sharding


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    seed: Optional[int] = None   # sampling stream (defaults to rid)
    out_tokens: Optional[list] = None


class PageAllocator:
    """Host-side refcounted free-list over the (possibly sharded) KV pool.

    Block tables address *global* page ids throughout (models/paged.py).
    With n_shards=1 this is the single-pool allocator: page 0 is the trash
    page and is never handed out.  With n_shards>1 the pool's page dim is
    split over the kv_pages mesh axis into contiguous per-shard ranges and
    the allocator keeps one free list — one *page budget* — per device:
    every shard's local page 0 (global ids ≡ 0 mod pages_per_shard) is that
    shard's trash page, so capacity is n_pages - n_shards.

    `alloc(n, prefer_shard=...)` grants fresh pages at refcount 1, with
    *slot affinity*: all n pages come from one shard when any single shard
    can serve them (prefer_shard first — a prefix donor's shard, so shared
    chains stay device-local — else the least-loaded shard), and spill
    deterministically across shards (most-free first, ties by shard index)
    only when no single budget fits.  Cross-shard slots stay correct via
    the log-sum-exp partial merge; single-shard slots decode bitwise
    identically to an unsharded pool.

    `share` maps an already-live page into another block table
    (refcount++); `free` drops one reference per page and recycles a page
    onto its own shard's free list only when its last reference goes —
    freeing a page that holds no reference raises (double-free)."""

    def __init__(self, n_pages: int, n_shards: int = 1):
        if n_pages % n_shards:
            raise ValueError(f"n_pages={n_pages} not divisible by "
                             f"n_shards={n_shards}")
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        if self.pages_per_shard < 2:
            raise ValueError(f"need >= 2 pages per shard (trash + 1), got "
                             f"{self.pages_per_shard}")
        self.capacity = n_pages - n_shards
        self.peak_in_use = 0
        self.total_allocs = 0   # fresh grants ever (shares not counted)
        # per-shard free lists of global ids; pop() -> low local ids first;
        # local page 0 of every shard is its trash page, never listed
        self._free = [list(range((s + 1) * self.pages_per_shard - 1,
                                 s * self.pages_per_shard, -1))
                      for s in range(n_shards)]
        self._refs: Dict[int, int] = {}

    @property
    def pages_free(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - self.pages_free

    @property
    def pages_free_by_shard(self) -> List[int]:
        return [len(f) for f in self._free]

    @property
    def pages_in_use_by_shard(self) -> List[int]:
        per = self.pages_per_shard - 1  # usable pages per device
        return [per - len(f) for f in self._free]

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int,
              prefer_shard: Optional[int] = None) -> Optional[List[int]]:
        if n > self.pages_free:
            return None
        if n == 0:
            return []
        if prefer_shard is not None and len(self._free[prefer_shard]) >= n:
            order = [prefer_shard]
        else:
            fits = [s for s in range(self.n_shards)
                    if len(self._free[s]) >= n]
            if fits:
                # single-shard fit: least-loaded (most free), ties by index
                order = [max(fits, key=lambda s: (len(self._free[s]), -s))]
            else:
                # deterministic spill: most-free first, ties by index
                order = sorted(range(self.n_shards),
                               key=lambda s: (-len(self._free[s]), s))
        out: List[int] = []
        for s in order:
            while len(out) < n and self._free[s]:
                out.append(self._free[s].pop())
        for p in out:
            self._refs[p] = 1
        self.total_allocs += len(out)
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return out

    def share(self, pages: List[int]):
        """Take one extra reference per page (prefix sharing)."""
        for p in pages:
            if self._refs.get(p, 0) < 1:
                raise ValueError(f"cannot share free page {p}")
            self._refs[p] += 1

    def free(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; returns the pages actually
        recycled (refcount reached zero) so callers can evict metadata."""
        recycled = []
        for p in pages:
            rc = self._refs.get(p, 0)
            if rc < 1:
                raise ValueError(f"double free of page {p}")
            if rc == 1:
                del self._refs[p]
                self._free[self.shard_of(p)].append(p)
                recycled.append(p)
            else:
                self._refs[p] = rc - 1
        return recycled


def _build_sampler(greedy: bool, top_k: int):
    """jit'd token sampler: logits [B, V] + per-row keys -> [B] int32."""

    def sample(logits, keys, temperature):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        l = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
        if top_k > 0 and top_k < l.shape[-1]:
            kth = jnp.sort(l, axis=-1)[..., -top_k][..., None]
            l = jnp.where(l >= kth, l, -1e30)
        return jax.vmap(jax.random.categorical)(keys, l).astype(jnp.int32)

    return jax.jit(sample)


_FREE, _PREFILL, _DECODE = 0, 1, 2
_META = ("k", "v", "length", "block_table")


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_seq: int, greedy: bool = True, *,
                 temperature: float = 1.0, top_k: int = 0,
                 base_seed: int = 0, paged: bool = True,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 prefill_buckets=(64, 16, 4, 1),
                 prefill_chunks_per_step: int = 0,
                 prefix_sharing: Optional[bool] = None,
                 batched_prefill: Optional[bool] = None,
                 fused_prefill: Optional[bool] = None,
                 fused_decode: Optional[bool] = None,
                 speculate_k: int = 0,
                 draft_quant=None,
                 draft_params=None,
                 mesh=None):
        """batch_slots decode slots over a max_seq position budget per slot.

        paged=True (default) serves attention families from a posit-coded
        page pool; page_size defaults to cfg.quant.kv_page_size and n_pages
        to full capacity (batch_slots * pages_per_slot + trash pages) —
        pass a smaller n_pages to oversubscribe (admission then waits for
        reclaimed pages).  prefill_chunks_per_step=0 completes a prompt's
        chunks at admission; k>0 interleaves at most k chunks per request
        per engine step with ongoing decode (chunked prefill inside the
        decode loop).  prefix_sharing / batched_prefill default to the
        QuantPolicy knobs (both on); sharing applies to paged engines only.
        fused_prefill overrides QuantPolicy.fused_prefill per instance
        (rewriting cfg.quant before tracing): paged prefill chunks run
        attention + KV encode + page scatter as ONE device program instead
        of three, bit-identically, for arbitrary history spans (history
        beyond one flash chunk streams through the kernel's running flash
        softmax) — the per-chunk program counts are reported by
        execution_summary().  fused_decode likewise overrides
        QuantPolicy.fused_decode: each paged decode step runs attention +
        logits head + sampling as ONE device dispatch
        (api.decode_and_sample) instead of a decode program followed by a
        sampler program, with bit-identical tokens; bit_exact execution
        keeps the decomposed pair.

        mesh: optional jax Mesh.  When the mesh has a >1-sized axis that the
        sharding rules map `kv_pages` onto (the 'model' axis by default),
        the page pool's page dimension is sharded over it: each device owns
        a contiguous global-page-id range and one per-device page budget
        (see PageAllocator), n_pages must divide by the shard count, and
        every entry point runs under a fully-manual shard_map — paged
        attention merges per-device softmax partials exactly, so tokens are
        identical to a 1-device engine over the same pool.  All other state
        (weights, metadata, SSM/conv rows) stays replicated; extra >1 mesh
        axes are rejected.  The host scheduler is unchanged: block tables
        keep global page ids, and allocation prefers single-shard slots
        (prefix donors' shards for shared chains) before spilling.
        Dense-cache and SSM-family engines ignore the mesh.

        speculate_k >= 2 turns on posit-native speculative decoding: a
        cheap draft policy (`draft_quant`, default
        `cfg.quant.with_draft()`; `draft_params` defaults to the serve
        weights) proposes up to k-1 tokens per round, all verified in ONE
        batched multi-query `ops.paged_attention` dispatch against the
        serve policy.  Draft and verify read/write the *same* posit-coded
        KV pages (with_draft pins kv_cache + kv_page_size), and the verify
        pass re-encodes every proposed position with the serve policy's
        codes before attending, so the accepted token stream is bitwise
        identical to plain decode over the same seeds — speculation only
        changes how many device programs that stream costs.  Paged
        single-shard attention families only.
        """
        if fused_prefill is not None:
            cfg = dataclasses.replace(
                cfg, quant=dataclasses.replace(
                    cfg.quant, fused_prefill=bool(fused_prefill)))
        if fused_decode is not None:
            cfg = dataclasses.replace(
                cfg, quant=dataclasses.replace(
                    cfg.quant, fused_decode=bool(fused_decode)))
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.greedy = greedy
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.prefill_chunks_per_step = int(prefill_chunks_per_step)
        self.mesh = None
        self._shard_axis = None
        n_shards = 1
        if mesh is not None and paged:
            axes = [a for a in sharding.mesh_axes_for("kv_pages", mesh)
                    if mesh.shape[a] > 1]
            extra = [a for a in mesh.axis_names
                     if mesh.shape[a] > 1 and a not in axes]
            if extra:
                raise ValueError(
                    f"serving mesh has >1-sized axes {extra} that kv_pages "
                    f"does not shard over; the engine only shards the page "
                    f"pool — use a mesh whose non-trivial axis is the "
                    f"kv_pages one (default: 'model')")
            if len(axes) > 1:
                raise ValueError(
                    f"kv_pages maps onto multiple >1 mesh axes {axes}; "
                    f"shard the page pool over a single axis")
            if axes:
                self.mesh = mesh
                self._shard_axis = axes[0]
                n_shards = mesh.shape[axes[0]]
        self.prefill_buckets = self._valid_buckets(prefill_buckets)
        self.layout = None
        if paged:
            ps = self._resolve_page_size(page_size, max_seq)
            self.layout = PagedLayout.for_slots(batch_slots, max_seq, ps,
                                                n_pages, n_shards=n_shards)
        self.cache = api.init_cache(cfg, batch_slots, max_seq, self.layout)
        self.paged = "block_table" in self.cache  # SSM families: no pages
        if not self.paged:
            self.layout = None
            self.mesh = None      # SSM recurrent state is O(1): nothing to
            self._shard_axis = None  # shard; serve replicated
        self.n_shards = self.layout.n_shards if self.paged else 1
        self.allocator = (PageAllocator(self.layout.n_pages,
                                        self.layout.n_shards)
                          if self.paged else None)
        self.max_pages_per_slot = (self.cache["block_table"].shape[1]
                                   if self.paged else 0)
        q = cfg.quant
        self.prefix_sharing = self.paged and bool(
            q.prefix_sharing if prefix_sharing is None else prefix_sharing)
        if batched_prefill is None:
            # routed-MoE capacity is computed over the whole [B, C] batch:
            # unless the capacity factor is drop-proof (capacity >= tokens
            # even if routing concentrates), padding rows of a batched
            # chunk could displace active tokens and make outputs depend
            # on batch composition — fall back to per-slot prefill there.
            # An explicit batched_prefill=True overrides.
            droppy_moe = (cfg.n_experts > 0 and
                          cfg.capacity_factor * cfg.top_k < cfg.n_experts)
            self.batched_prefill = bool(q.batched_prefill) and not droppy_moe
        else:
            self.batched_prefill = bool(batched_prefill)

        # fused one-program decode: attention + logits head + sampler in a
        # single device dispatch.  Paged engines only (the structural
        # launch-pair residual this removes lives in the serving decode
        # loop); bit_exact has no fused head replay.
        self.fused_decode = (self.paged and bool(q.fused_decode)
                             and q.execution != "bit_exact")
        if self.n_shards > 1:
            self._install_sharded_fns()
        else:
            self._page_shard = None
            self._decode = jax.jit(
                lambda p, t, c: api.decode_step(p, t, c, cfg))
            if self.fused_decode:
                gd, tk, V = greedy, self.top_k, cfg.vocab_size
                self._decode_sample = jax.jit(
                    lambda p, t, c, keys, temp: api.decode_and_sample(
                        p, t, c, cfg,
                        None if gd else api.sample_noise(keys, V),
                        temp, greedy=gd, top_k=tk))
            self._chunk = jax.jit(
                lambda p, t, c, s: api.prefill_chunk(p, t, c, s, cfg))
            self._chunk_batched = jax.jit(
                lambda p, t, c, a: api.prefill_chunk_batched(p, t, c, a,
                                                             cfg))
            # COW page duplication; dst/src are traced so one compile
            # covers every fork
            self._fork_fn = jax.jit(fork_page)
        # whole-prompt prefill, kept as a reference/debug probe only — the
        # serving path never calls it (chunked prefill replaces it)
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, cfg, max_seq=max_seq))
        self._sampler = _build_sampler(greedy, self.top_k)
        self._base_key = jax.random.key(base_seed)
        self._dummy_keys = jax.random.split(self._base_key, batch_slots)

        # host-owned scheduler state (device copies are refreshed per call)
        self.lengths = np.zeros(batch_slots, np.int32)
        self.block_tables = np.zeros(
            (batch_slots, max(self.max_pages_per_slot, 1)), np.int32)
        self.slot_phase = np.full(batch_slots, _FREE, np.int8)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pages: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_reserve: List[Optional[int]] = [None] * batch_slots
        self.slot_cursor = np.zeros(batch_slots, np.int64)  # prompt progress
        self.slot_remaining = np.zeros(batch_slots, np.int64)
        self.next_token = np.zeros(batch_slots, np.int32)
        self._slot_keys = [None] * batch_slots
        self._slot_sampled = np.zeros(batch_slots, np.int64)
        self._slot_registered = np.zeros(batch_slots, np.int64)
        self.queue: List[Request] = []
        self.done: List[Request] = []

        # prefix index: digest(prompt token prefix) -> (page, state or
        # None); _page_keys/_frozen support eviction and COW decisions;
        # _held are pages a retiring request left behind because a queued
        # request's prefix still matches them (the engine owns their last
        # reference until that request admits or leaves the queue)
        self.prefix_index: Dict[bytes, tuple] = {}
        self._page_keys: Dict[int, set] = {}
        self._frozen: Dict[int, int] = {}
        self._held: set = set()
        self.stats = {"pages_shared": 0, "shared_admissions": 0,
                      "cow_forks": 0, "prefill_batch_sizes": {},
                      "prefill_chunks": 0, "prefill_device_programs": 0,
                      "decode_steps": 0, "decode_device_programs": 0,
                      "preemptions": 0, "spec_rounds": 0,
                      "spec_draft_tokens": 0, "spec_accepted_tokens": 0,
                      "spec_committed_tokens": 0}

        # batch-dim index per cache leaf, for restoring rows of slots that
        # were mid-prefill during a decode call (page pools have no batch
        # dim — zeroed block-table rows protect them instead)
        specs = api.cache_specs(cfg, batch_slots, max_seq, self.layout)
        self._state_bdim = {
            name: (s.logical_axes.index("batch")
                   if "batch" in s.logical_axes else None)
            for name, s in specs.items()}
        # recurrent families (hybrid) carry per-slot conv/SSM state that
        # prefix sharing must snapshot/restore at the shared boundary
        self._recurrent = any(name not in _META for name in self.cache)

        # ---- speculative decoding (draft-propose / batched-verify) ----
        self.speculate_k = int(speculate_k)
        self.draft_quant = None
        self._spec_dummy_keys: Dict[int, object] = {}
        if self.speculate_k:
            if self.speculate_k < 2:
                raise ValueError("speculate_k must be >= 2 (k=1 is plain "
                                 "decode); pass 0 to disable speculation")
            if not self.paged:
                raise ValueError("speculative decoding requires the paged "
                                 "KV cache (draft and verify must address "
                                 "the same posit-coded pages)")
            if self._recurrent:
                raise ValueError(
                    "speculative decoding is limited to pure-attention "
                    "paged families: recurrent (conv/SSM) state cannot be "
                    "rolled back when a draft token is rejected")
            if self.n_shards > 1:
                raise ValueError("speculative decoding is not implemented "
                                 "for sharded page pools yet")
            if not hasattr(api._mod(cfg), "decode_verify"):
                raise ValueError(f"family {cfg.family!r} has no k-token "
                                 f"verify step")
            dq = draft_quant if draft_quant is not None else \
                cfg.quant.with_draft()
            if (dq.kv_cache != cfg.quant.kv_cache
                    or dq.kv_page_size != cfg.quant.kv_page_size):
                raise ValueError(
                    "draft policy must keep the serve policy's kv_cache "
                    "format and kv_page_size — draft and target decode "
                    "the same posit-coded pages, which is what makes "
                    "speculative acceptance exact (QuantPolicy.with_draft "
                    "preserves both)")
            self.draft_quant = dq
            draft_cfg = dataclasses.replace(cfg, quant=dq)
            self.draft_params = params if draft_params is None else \
                draft_params
            self._draft_decode = jax.jit(
                lambda p, t, c: api.decode_step(p, t, c, draft_cfg))
            gd, tk, V = greedy, self.top_k, cfg.vocab_size
            self._verify = jax.jit(
                lambda p, t, c, keys, temp: api.decode_verify(
                    p, t, c, cfg,
                    None if gd else api.sample_noise(keys, V),
                    temp, greedy=gd, top_k=tk))

    def _valid_buckets(self, buckets):
        """Descending chunk sizes; 1 is always included (exact prompt
        decomposition), and sizes incompatible with the SSD chunk length
        are dropped (ssd_forward needs C % min(ssm_chunk, C) == 0)."""
        out = set(int(b) for b in buckets if b >= 1) | {1}
        if self.cfg.family in ("ssm", "hybrid"):
            q = self.cfg.ssm_chunk
            out = {b for b in out if b <= q or b % q == 0}
        return tuple(sorted(out, reverse=True))

    def _resolve_page_size(self, requested: Optional[int],
                           max_seq: int) -> int:
        """Page size the paged layout is actually built with.

        With fused prefill on, a page size that neither tiles
        paged.FLASH_CHUNK nor keeps every possible prefill span inside one
        flash chunk would silently drop every chunk onto the 3-program
        decomposed path (fused_prefill_span_ok) — the exact quiet fallback
        the ROADMAP carried as a residual.  An explicitly requested size
        that cannot hold the one-program gate raises; the policy default
        (page_size=None) auto-picks the largest FLASH_CHUNK divisor not
        above cfg.quant.kv_page_size instead, so the fused path is never
        lost to a configuration accident."""
        q = self.cfg.quant
        ps = int(q.kv_page_size if requested is None else requested)
        if ps < 1:
            raise ValueError(f"page_size must be >= 1, got {ps}")
        if not q.fused_prefill or self.cfg.family == "ssm":
            return ps  # no paged attention prefill to keep fused
        chunk = paged_mod.FLASH_CHUNK  # read live: tests/CI retune it
        per = -(-max_seq // ps)
        if fused_prefill_span_ok(per, ps, max(self.prefill_buckets)):
            return ps
        if requested is not None:
            raise ValueError(
                f"page_size={ps} cannot tile FLASH_CHUNK={chunk} and the "
                f"slot span ({per} pages x {ps} + a "
                f"{max(self.prefill_buckets)}-token chunk) exceeds one "
                f"flash chunk: every prefill chunk would silently fall "
                f"back to the 3-program decomposed path.  Pass a divisor "
                f"of {chunk} (or page_size=None to auto-pick one), or "
                f"construct with fused_prefill=False to accept the "
                f"decomposed path explicitly")
        return max(d for d in range(1, ps + 1) if chunk % d == 0)

    def _install_sharded_fns(self):
        """Wrap the serving entry points in a fully-manual shard_map over
        the kv_pages mesh axis.  Only the page pools' page dim is sharded
        (each device holds its contiguous global-id range, re-indexed
        locally by models/paged.py); params, metadata, and any recurrent
        conv/SSM state stay replicated.  PartitionSpecs are built from the
        cache leaves' logical axes directly rather than through the global
        rule table: on a serving mesh the kv_pages axis must not drag
        heads/experts/SSM channels along with it (the table maps those onto
        'model' too, for training-time tensor parallelism)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = self.cfg
        ax = self._shard_axis
        sctx = PageShard(axis=ax, n_shards=self.n_shards)
        self._page_shard = sctx
        specs = api.cache_specs(cfg, self.B, self.S, self.layout)
        cspec = {name: P(*[ax if la == "kv_pages" else None
                           for la in s.logical_axes])
                 for name, s in specs.items()}
        rep = P()
        prep = jax.tree.map(lambda _: rep, self.params)
        sm, mesh = sharding.shard_map, self.mesh
        self._decode = jax.jit(sm(
            lambda p, t, c: api.decode_step(p, t, c, cfg, shard=sctx),
            mesh, in_specs=(prep, rep, cspec), out_specs=(rep, cspec)))
        if self.fused_decode:
            gd, tk, V = self.greedy, self.top_k, cfg.vocab_size
            if gd:
                inner = sm(
                    lambda p, t, c, temp: api.decode_and_sample(
                        p, t, c, cfg, None, temp, greedy=True, top_k=tk,
                        shard=sctx),
                    mesh, in_specs=(prep, rep, cspec, rep),
                    out_specs=(rep, cspec))
                self._decode_sample = jax.jit(
                    lambda p, t, c, keys, temp: inner(p, t, c, temp))
            else:
                # gumbel noise is drawn once outside the shard_map (it only
                # depends on the replicated per-slot keys) and enters
                # replicated, so every shard samples from identical rows
                inner = sm(
                    lambda p, t, c, n, temp: api.decode_and_sample(
                        p, t, c, cfg, n, temp, greedy=False, top_k=tk,
                        shard=sctx),
                    mesh, in_specs=(prep, rep, cspec, rep, rep),
                    out_specs=(rep, cspec))
                self._decode_sample = jax.jit(
                    lambda p, t, c, keys, temp: inner(
                        p, t, c, api.sample_noise(keys, V), temp))
        self._chunk = jax.jit(sm(
            lambda p, t, c, s: api.prefill_chunk(p, t, c, s, cfg,
                                                 shard=sctx),
            mesh, in_specs=(prep, rep, cspec, rep),
            out_specs=(rep, cspec)))
        self._chunk_batched = jax.jit(sm(
            lambda p, t, c, a: api.prefill_chunk_batched(p, t, c, a, cfg,
                                                         shard=sctx),
            mesh, in_specs=(prep, rep, cspec, rep),
            out_specs=(rep, cspec)))
        pool = cspec["k"]
        self._fork_fn = jax.jit(sm(
            lambda kv, d, s: fork_page(kv, d, s, shard=sctx),
            mesh, in_specs=(pool, rep, rep), out_specs=pool))
        # place the freshly-zeroed cache on the mesh up front so the first
        # entry-point call doesn't implicitly reshard host-resident arrays
        self.cache = {
            name: jax.device_put(leaf, NamedSharding(mesh, cspec[name]))
            for name, leaf in self.cache.items()}

    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, directory: str,
                        batch_slots: int, max_seq: int,
                        step: Optional[int] = None, **kw) -> "ServingEngine":
        """Restore params (float or posit-packed) and build an engine.

        The checkpoint manifest's `extra` metadata (models.packing.
        pack_manifest) decides the restore dtypes: packed checkpoints come
        back as int8/int16 code arrays that the dispatch layer consumes
        directly — no float materialization of the weights.
        """
        from repro.checkpoint import CheckpointManager
        from repro.models import packing
        from repro.models.module import abstract_params

        mgr = CheckpointManager(directory)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
        extra = mgr.read_manifest(step).get("extra") or {}
        if extra.get("packed_weights"):
            from repro.core.formats import PositFormat
            fmt = PositFormat(extra["weights_n"], extra["weights_es"])
            if cfg.quant.weights != fmt:
                # the dispatch layer decodes codes with cfg.quant.weights —
                # a silent mismatch would serve garbage values
                raise ValueError(
                    f"checkpoint packed as {fmt} but cfg.quant.weights is "
                    f"{cfg.quant.weights}; align the serving QuantPolicy "
                    f"with the pack format")
            specs = packing.packed_param_specs(cfg, fmt)
        else:
            specs = api.param_specs(cfg)
        params = mgr.restore(step, abstract_params(specs))
        return cls(cfg, params, batch_slots, max_seq, **kw)

    # ------------------------------------------------------------------
    # storage accounting
    # ------------------------------------------------------------------

    def weight_bytes(self) -> int:
        """Resident weight-storage bytes (int codes count at container width)."""
        from repro.models.packing import weight_bytes
        return weight_bytes(self.params)

    def kv_cache_summary(self) -> dict:
        """Decode-state storage split: `kv_bytes` is the K/V payload (pages
        or dense rows, plus SSM/conv state — at code width when posit-
        coded); `metadata_bytes` is positions + block tables.  The bench
        storage comparisons use kv_bytes — metadata must not dilute the
        coded-page win."""
        kv = meta = 0
        for name, leaf in self.cache.items():
            if name in ("length", "block_table"):
                meta += int(leaf.nbytes)
            else:
                kv += int(leaf.nbytes)
        out = {"kv_bytes": kv, "metadata_bytes": meta,
               "total_bytes": kv + meta}
        if self.paged:
            # bytes actually backing tokens in flight: what a pool sized to
            # the workload would allocate (decode memory scales with pages
            # in use at code width, not batch_slots x max_seq at f32)
            page_b = int(self.cache["k"].nbytes + self.cache["v"].nbytes) \
                // self.layout.n_pages
            out["kv_bytes_in_use"] = self.pages_in_use * page_b
            out["kv_bytes_peak"] = self.allocator.peak_in_use * page_b
            if self.n_shards > 1:
                out["pages_in_use_by_shard"] = \
                    self.allocator.pages_in_use_by_shard
        return out

    def kv_cache_bytes(self) -> int:
        """Total allocated decode-state bytes (payload + metadata); see
        kv_cache_summary() for the split."""
        return self.kv_cache_summary()["total_bytes"]

    @property
    def slot_free(self) -> List[bool]:
        """Per-slot availability (compat view over the phase array)."""
        return [bool(p == _FREE) for p in self.slot_phase]

    @property
    def pages_in_use(self) -> int:
        return self.allocator.pages_in_use if self.allocator else 0

    @property
    def pages_free(self) -> int:
        return self.allocator.pages_free if self.allocator else 0

    @property
    def pages_promised(self) -> int:
        """Diagnostic: worst-case pages the queued-but-unscheduled
        requests will draw (each counted unshared — sharing is
        opportunistic and may evaporate if donors retire first).  The
        engine does not gate submission on this sum: joint oversubscription
        is served by waiting for reclamation, and what admission accounts
        up front is each admitted request's full private demand including
        its copy-on-write fork reserve (see _admit), so an admitted
        request never allocates mid-flight."""
        if not self.paged:
            return 0
        return sum(self._pages_needed(r) for r in self.queue)

    @property
    def pages_shared_mapped(self) -> int:
        """Extra block-table references onto live pages beyond the first
        (how many page-loads prefix sharing is currently deduplicating)."""
        if not self.paged:
            return 0
        return sum(rc - 1 for rc in self.allocator._refs.values())

    def execution_summary(self) -> dict:
        """Which datapath this engine serves on, plus its storage terms."""
        q = self.cfg.quant
        kv = self.kv_cache_summary()
        return {
            "execution": q.execution,
            "weights": str(q.weights) if q.weights else None,
            "activations": str(q.activations) if q.activations else None,
            "kv_cache": str(q.kv_cache) if q.kv_cache else None,
            "activation_coded": q.execution == "fused"
                                and q.activations is not None,
            "weight_bytes": self.weight_bytes(),
            "kv_cache_bytes": kv["total_bytes"],
            "kv_bytes": kv["kv_bytes"],
            "metadata_bytes": kv["metadata_bytes"],
            "paged": self.paged,
            "page_size": self.layout.page_size if self.paged else None,
            "kv_shards": self.n_shards,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.pages_free,
            "pages_in_use_by_shard": (self.allocator.pages_in_use_by_shard
                                      if self.paged else None),
            "prefix_sharing": self.prefix_sharing,
            "batched_prefill": self.batched_prefill,
            "fused_prefill": self.paged and bool(q.fused_prefill),
            "fused_decode": self.fused_decode,
            "prefill_chunks": self.stats["prefill_chunks"],
            "prefill_device_programs": self.stats["prefill_device_programs"],
            "decode_steps": self.stats["decode_steps"],
            "decode_device_programs": self.stats["decode_device_programs"],
            "pages_shared_mapped": self.pages_shared_mapped,
            "cow_forks": self.stats["cow_forks"],
            "preemptions": self.stats["preemptions"],
            "speculative": bool(self.speculate_k),
            "speculate_k": self.speculate_k or None,
            "speculation_rounds": self.stats["spec_rounds"],
            "speculation_committed_tokens":
                self.stats["spec_committed_tokens"],
            "speculation_accept_rate": (
                self.stats["spec_accepted_tokens"]
                / self.stats["spec_draft_tokens"]
                if self.stats["spec_draft_tokens"] else None),
        }

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        n = len(req.prompt)
        if n < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1")
        # every written position must fit the slot's budget: positions
        # 0 .. n + max_new_tokens - 2 < max_seq.  Past-the-end writes would
        # silently wrap into the slot's last page (insert_tokens clips the
        # page index) / be silently dropped (dense scatter), corrupting or
        # losing KV — reject at submission instead.
        if n + req.max_new_tokens - 1 > self.S:
            raise ValueError(
                f"request {req.rid}: prompt ({n}) + max_new_tokens "
                f"({req.max_new_tokens}) needs {n + req.max_new_tokens - 1} "
                f"positions but max_seq is {self.S}")
        if self.paged and self._pages_needed(req) > self.allocator.capacity:
            budgets = ("" if self.allocator.n_shards == 1 else
                       f" ({self.allocator.n_shards} per-device budgets of "
                       f"{self.allocator.pages_per_shard - 1} pages)")
            raise ValueError(
                f"request {req.rid} needs {self._pages_needed(req)} pages "
                f"but the pool only has {self.allocator.capacity}{budgets}; "
                f"raise n_pages or shorten prompt/max_new_tokens")
        req.out_tokens = []
        self.queue.append(req)

    def _pages_needed(self, req: Request) -> int:
        last_pos = len(req.prompt) + req.max_new_tokens - 2  # final write
        return min(last_pos // self.layout.page_size + 1,
                   self.max_pages_per_slot)

    def _chunk_sizes(self, n: int):
        """Exact greedy decomposition of n into bucket sizes (1 included).
        The decomposition has the suffix property — the tail after any
        chunk boundary equals the greedy decomposition of the remainder —
        which is what makes prefix-shared prefill bit-identical to
        unshared prefill when sharing stops at a boundary."""
        out = []
        for b in self.prefill_buckets:
            while n >= b:
                out.append(b)
                n -= b
        return out

    def _next_chunk(self, slot: int) -> int:
        remaining = len(self.slot_req[slot].prompt) \
            - int(self.slot_cursor[slot])
        return self._chunk_sizes(remaining)[0]

    def _refresh_meta(self, cache, mask=None, lengths=None):
        """Push host-owned lengths/block tables into the device cache.
        mask zeroes rows of slots that must not touch real state during a
        batched call (free / mid-prefill slots in decode, non-group slots
        in batched prefill).  lengths overrides the host array (the
        speculative draft loop advances a transient per-slot position
        without committing it)."""
        lengths = (self.lengths if lengths is None else lengths).copy()
        if mask is not None:
            lengths[~mask] = 0
        cache = dict(cache)
        cache["length"] = jnp.asarray(lengths)
        if self.paged:
            bts = self.block_tables.copy()
            if mask is not None:
                bts[~mask] = 0
            cache["block_table"] = jnp.asarray(bts)
        return cache

    def _reset_slot_state(self, slot: int):
        """Zero a slot's recurrent/dense state rows before reuse (SSM and
        conv states are *seeded* by prefill — stale values would leak)."""
        new = {}
        for name, leaf in self.cache.items():
            bdim = self._state_bdim.get(name)
            if name in ("length", "block_table") or bdim is None:
                new[name] = leaf
                continue
            idx = (slice(None),) * bdim + (slot,)
            new[name] = leaf.at[idx].set(0)
        self.cache = new

    def _slot_key(self, req: Request):
        seed = req.seed if req.seed is not None else req.rid
        return jax.random.fold_in(self._base_key, seed)

    def _sample_keys(self, slots, live=None):
        """Per-row sampling keys for `slots` (dummy rows for non-live
        slots), advancing each live slot's draw counter — shared by the
        decomposed sampler and the fused decode-and-sample dispatch so
        both consume the identical key stream."""
        if self.greedy:  # argmax never reads keys: skip building them
            return self._dummy_keys[:len(slots)]
        keys = jnp.stack([
            jax.random.fold_in(self._slot_keys[s],
                               int(self._slot_sampled[s]))
            if (live is None or live[s]) else self._dummy_keys[0]
            for s in slots])
        for s in slots:
            if live is None or live[s]:
                self._slot_sampled[s] += 1
        return keys

    def _sample(self, logits_rows, slots, live=None):
        """Sample one token per row of logits_rows [n, V] for `slots`.
        `live` masks slots whose draw is discarded (dummy keys, counter
        not advanced) — lets batched paths sample a fixed [B, V] batch."""
        keys = self._sample_keys(slots, live=live)
        toks = self._sampler(logits_rows, keys,
                             jnp.float32(self.temperature))
        return np.asarray(toks, np.int32)

    # ------------------------------------------------------------------
    # prefix index: registration, lookup, eviction
    # ------------------------------------------------------------------

    @staticmethod
    def _digest(tokens) -> bytes:
        return hashlib.blake2b(
            np.ascontiguousarray(tokens, np.int32).tobytes(),
            digest_size=16).digest()

    def _prompt_digests(self, req: Request):
        """Per-request digest cache: ([digest per full-page boundary],
        full-prompt digest).  Admission walks these every pass for every
        queued request (lookup, deferral, holds) — hashing each prefix
        once per request instead of once per pass keeps that host work
        O(prompt/page) lookups."""
        ps = self.layout.page_size
        cached = getattr(req, "_prefix_digests", None)
        if cached is not None and cached[0] == ps:
            return cached[1], cached[2]
        prompt = np.ascontiguousarray(req.prompt, np.int32)
        h = hashlib.blake2b(digest_size=16)
        full = []
        for i in range(len(prompt) // ps):
            h.update(prompt[i * ps:(i + 1) * ps].tobytes())
            full.append(h.copy().digest())
        req._prefix_digests = (ps, full, self._digest(prompt))
        return full, req._prefix_digests[2]

    def _put_index(self, key: bytes, page: int, frozen: int, state=None):
        """Register a page for the token prefix hashed by `key`; `frozen`
        is the first position holders may still write (everything below is
        trusted by sharers and must copy-on-write)."""
        if key in self.prefix_index:
            return  # first registration wins; duplicates are identical KV
        self.prefix_index[key] = (page, state)
        self._page_keys.setdefault(page, set()).add(key)
        self._frozen[page] = max(self._frozen.get(page, 0), frozen)

    def _evict(self, recycled: List[int]):
        """Drop index entries whose page went back to the free list (its
        content is about to be reused — the hash no longer describes it)."""
        for p in recycled:
            for key in self._page_keys.pop(p, ()):
                self.prefix_index.pop(key, None)
            self._frozen.pop(p, None)

    def _snapshot_state(self, slot: int):
        """Host copy of the slot's recurrent (conv/SSM) rows, or None for
        pure-attention families."""
        out = {}
        for name, leaf in self.cache.items():
            bdim = self._state_bdim.get(name)
            if name in _META or bdim is None:
                continue
            idx = (slice(None),) * bdim + (slot,)
            out[name] = np.asarray(leaf[idx])
        return out or None

    def _restore_state(self, slot: int, state: dict):
        new = dict(self.cache)
        for name, arr in state.items():
            bdim = self._state_bdim[name]
            idx = (slice(None),) * bdim + (slot,)
            new[name] = new[name].at[idx].set(jnp.asarray(arr))
        self.cache = new

    def _register_pages(self, slot: int):
        """Publish the slot's freshly prompt-filled pages to the prefix
        index (called after every prefill chunk, while cursor <= prompt
        length — so every registered page holds prompt KV only).  For
        recurrent families a conv/SSM snapshot rides along when the chunk
        end lands exactly on the page boundary; boundary-misaligned pages
        become stateless chain links."""
        if not self.prefix_sharing:
            return
        req = self.slot_req[slot]
        ps = self.layout.page_size
        cur = int(self.slot_cursor[slot])
        full = cur // ps
        digests, full_digest = self._prompt_digests(req)
        snap = (self._snapshot_state(slot)
                if self._recurrent and cur % ps == 0 and full else None)
        for i in range(int(self._slot_registered[slot]), full):
            self._put_index(digests[i], int(self.block_tables[slot, i]),
                            (i + 1) * ps,
                            snap if (i + 1) * ps == cur else None)
        self._slot_registered[slot] = full
        if cur == len(req.prompt) and cur % ps and not self._recurrent:
            # the partially-filled tail page: exact-duplicate prompts map
            # it too and copy-on-write their divergence
            self._put_index(full_digest,
                            int(self.block_tables[slot, full]), cur, None)

    def _tail_shareable(self, n: int) -> bool:
        """May a request of prompt length n map an exact-duplicate donor's
        partially-filled tail page?  Requires a divergence point the
        request can actually prefill bit-identically: position n-1 must be
        a boundary of its own chunk decomposition (the same condition
        _lookup_prefix enforces — holds and deferral must not wait for a
        share admission would refuse), and recurrent families need a state
        snapshot partial pages never carry."""
        ps = self.layout.page_size
        return (not self._recurrent and n % ps != 0
                and (n - 1) in {int(t)
                                for t in np.cumsum(self._chunk_sizes(n))})

    def _chain_pages(self, req: Request) -> set:
        """Pages the index currently offers for this request's prefix
        (full-page chain plus the exact-duplicate tail page)."""
        ps = self.layout.page_size
        n = len(req.prompt)
        digests, full_digest = self._prompt_digests(req)
        out = set()
        for i in range((n - 1) // ps):
            ent = self.prefix_index.get(digests[i])
            if ent is None:
                break
            out.add(ent[0])
        else:
            if self._tail_shareable(n):
                ent = self.prefix_index.get(full_digest)
                if ent is not None:
                    out.add(ent[0])
        return out

    def _wanted_by_queue(self) -> set:
        """Index pages some queued-but-unscheduled request would map."""
        wanted = set()
        for req in self.queue:
            wanted |= self._chain_pages(req)
        return wanted

    def _prune_holds(self):
        """Free held pages no queued request's prefix matches anymore.
        Once the queue drains this releases every hold — the pool always
        reclaims completely."""
        if not self._held:
            return
        wanted = self._wanted_by_queue()
        for p in list(self._held):
            if p not in wanted:
                self._held.discard(p)
                self._evict(self.allocator.free([p]))

    def _drop_all_holds(self):
        """Release every held page (liveness over sharing: when admission
        cannot proceed and nothing in flight will ever reclaim, the cached
        prefixes must yield their pages)."""
        for p in list(self._held):
            self._held.discard(p)
            self._evict(self.allocator.free([p]))

    def _lookup_prefix(self, req: Request):
        """Longest shareable prompt prefix for `req` from the index.

        Returns (shared pages, n_shared_tokens, state, partial).  Sharing
        stops at a boundary of the request's own chunk decomposition (the
        greedy suffix property then makes the tail's chunking — and hence
        every logit — bit-identical to an unshared run), leaves at least
        one prompt token to prefill (the engine samples from its logits),
        and for recurrent families requires a state snapshot at the
        boundary."""
        if not self.prefix_sharing:
            return [], 0, None, False
        n = len(req.prompt)
        ps = self.layout.page_size
        digests, full_digest = self._prompt_digests(req)
        bounds = set(int(t) for t in np.cumsum(self._chunk_sizes(n)))
        chain = []
        for i in range((n - 1) // ps):
            ent = self.prefix_index.get(digests[i])
            if ent is None:
                break
            chain.append(ent)
        best = ([], 0, None, False)
        for i, (page, state) in enumerate(chain):
            t = (i + 1) * ps
            if t in bounds and (state is not None or not self._recurrent):
                best = ([p for p, _ in chain[:i + 1]], t, state, False)
        if self._tail_shareable(n) and len(chain) == n // ps:
            ent = self.prefix_index.get(full_digest)
            if ent is not None:
                best = ([p for p, _ in chain] + [ent[0]], n - 1, None, True)
        return best

    # ------------------------------------------------------------------
    # copy-on-write
    # ------------------------------------------------------------------

    def _ensure_writable(self, slot: int, lo: int, hi: int):
        """Fork any shared page the slot is about to write below its
        frozen prefix.  Writes at/after the frozen position (a donor
        appending decode tokens past every sharer's trusted range) stay in
        place — sharers never read there."""
        ps = self.layout.page_size
        for idx in range(lo // ps, (hi - 1) // ps + 1):
            if idx >= self.max_pages_per_slot:
                break
            p = int(self.block_tables[slot, idx])
            if p == 0 or self.allocator.refcount(p) <= 1:
                continue
            if max(lo, idx * ps) >= self._frozen.get(p, 1 << 30):
                continue
            self._fork_slot_page(slot, idx, p)

    def _fork_slot_page(self, slot: int, idx: int, src: int):
        dst = self.slot_reserve[slot]
        self.slot_reserve[slot] = None
        if dst is None:
            # fork copies run device-local when the source's shard has a
            # free page (fork_page broadcasts across shards otherwise)
            got = self.allocator.alloc(
                1, prefer_shard=self.allocator.shard_of(src))
            if got is None:
                raise RuntimeError(
                    f"page pool exhausted during copy-on-write fork for "
                    f"slot {slot}: admission must reserve fork pages up "
                    f"front")
            dst = got[0]
            self.slot_pages[slot].append(dst)
        cache = dict(self.cache)
        cache["k"] = self._fork_fn(cache["k"], dst, src)
        cache["v"] = self._fork_fn(cache["v"], dst, src)
        self.cache = cache
        self.block_tables[slot, idx] = dst
        self.slot_pages[slot].remove(src)
        self._evict(self.allocator.free([src]))
        self.stats["cow_forks"] += 1

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _next_admissible(self) -> Optional[int]:
        """Queue index of the first request to admit.  A request whose
        prompt would share a longer prefix with a still-prefilling slot
        than the index currently offers is deferred (it admits next pass,
        after the donor registers its pages) — later distinct requests may
        jump ahead so slots keep filling."""
        for qi, req in enumerate(self.queue):
            if self.prefix_sharing and self._should_defer(req):
                continue
            return qi
        return None

    def _should_defer(self, req: Request) -> bool:
        n = len(req.prompt)
        ps = self.layout.page_size
        max_full = (n - 1) // ps
        if max_full == 0 and (self._recurrent or n % ps == 0):
            return False
        digests, full_digest = self._prompt_digests(req)
        # tokens the index can hand us right now
        have = 0
        for i in range(max_full):
            if digests[i] in self.prefix_index:
                have = (i + 1) * ps
            else:
                break
        tail_ok = self._tail_shareable(n)
        if (tail_ok and have == (n // ps) * ps
                and full_digest in self.prefix_index):
            have = n - 1
        # tokens a still-prefilling donor will register once it finishes
        for s in range(self.B):
            if self.slot_phase[s] != _PREFILL:
                continue
            o_digests, o_full = self._prompt_digests(self.slot_req[s])
            k = 0
            for i in range(min(max_full, len(o_digests))):
                if digests[i] == o_digests[i]:
                    k = (i + 1) * ps
                else:
                    break
            if tail_ok and k == (n // ps) * ps and o_full == full_digest:
                k = n - 1  # exact duplicate: the tail page will share too
            if k > have:
                return True
        return False

    def _admit(self) -> bool:
        """Move queued requests into free slots.  Paged admission is
        atomic per request and accounts the full private demand up front —
        shared prefix pages are mapped by reference and a copy-on-write
        fork page is pre-reserved, so an admitted request never allocates
        mid-flight.  Returns True if any request was admitted."""
        admitted = False
        for slot in range(self.B):
            if self.slot_phase[slot] != _FREE or not self.queue:
                continue
            qi = self._next_admissible()
            if qi is None:
                break
            req = self.queue[qi]
            n_shared, state = 0, None
            if self.paged:
                # capacity was validated at submit(); a transient shortfall
                # here just waits for another request's pages to reclaim
                shared, n_shared, state, partial = self._lookup_prefix(req)
                k_full = len(shared) - (1 if partial else 0)
                # shard affinity: extend a shared chain on its donor's
                # shard so the whole slot stays device-local when it fits
                prefer = (self.allocator.shard_of(shared[0])
                          if shared else None)
                pages = self.allocator.alloc(self._pages_needed(req) - k_full,
                                             prefer_shard=prefer)
                if pages is None and self._held \
                        and not (self.slot_phase != _FREE).any():
                    # nothing in flight will ever reclaim: held prefix
                    # pages must yield so the head of the queue can run
                    # (its demand may not overlap what the holds cache)
                    self._drop_all_holds()
                    shared, n_shared, state, partial = \
                        self._lookup_prefix(req)
                    k_full = len(shared) - (1 if partial else 0)
                    prefer = (self.allocator.shard_of(shared[0])
                              if shared else None)
                    pages = self.allocator.alloc(
                        self._pages_needed(req) - k_full,
                        prefer_shard=prefer)
                if pages is None:
                    return admitted  # wait for reclamation
                self.allocator.share(shared)
                reserve = pages.pop() if partial else None
                row = shared + pages
                self.slot_pages[slot] = list(row) + (
                    [reserve] if reserve is not None else [])
                self.slot_reserve[slot] = reserve
                self.block_tables[slot] = 0
                self.block_tables[slot, :len(row)] = row
                self._slot_registered[slot] = n_shared \
                    // self.layout.page_size
                if shared:
                    self.stats["pages_shared"] += len(shared)
                    self.stats["shared_admissions"] += 1
            self.queue.pop(qi)
            if self.paged:
                self._prune_holds()
            self.slot_req[slot] = req
            self.slot_phase[slot] = _PREFILL
            self.slot_cursor[slot] = n_shared
            self.lengths[slot] = n_shared
            self._slot_keys[slot] = self._slot_key(req)
            self._slot_sampled[slot] = 0
            self._reset_slot_state(slot)
            if state is not None:
                self._restore_state(slot, state)
            admitted = True
        return admitted

    def _release(self, slot: int):
        if self.paged:
            pages = self.slot_pages[slot]
            if self.prefix_sharing and self.queue:
                # keep prefix pages a queued request still matches alive:
                # the slot's reference becomes an engine hold, released by
                # _prune_holds once nothing in the queue wants the page
                wanted = self._wanted_by_queue()
                keep = {p for p in pages
                        if p in wanted and p not in self._held
                        and self.allocator.refcount(p) == 1}
                self._held.update(keep)
                pages = [p for p in pages if p not in keep]
            self._evict(self.allocator.free(pages))
            self.slot_pages[slot] = []
            self.slot_reserve[slot] = None
            self.block_tables[slot] = 0
        self.lengths[slot] = 0
        self.slot_phase[slot] = _FREE
        self.slot_req[slot] = None

    def _retire(self, slot: int):
        self.done.append(self.slot_req[slot])
        self._release(slot)

    def preempt(self, slot: int) -> Optional[Request]:
        """Evict a mid-flight slot and requeue its request at the head of
        the queue; returns the requeued request (None for a free slot).

        The request is requeued BEFORE the slot releases so _release sees
        its own registered prompt pages as wanted-by-queue and turns them
        into engine holds instead of recycling them — on re-admission the
        prefix lookup maps those pages straight back and only the unshared
        tail re-prefills.  Emitted tokens are discarded and replayed: the
        sampling keys derive from (seed, draw index), so the rerun emits
        the identical stream regardless of when the preemption landed.
        Front ends that streamed tokens out already must dedup by count."""
        if self.slot_phase[slot] == _FREE:
            return None
        req = self.slot_req[slot]
        req.out_tokens = []
        self.queue.insert(0, req)
        self._release(slot)
        self.stats["preemptions"] += 1
        return req

    def cancel(self, rid: int) -> bool:
        """Drop a queued (not yet admitted) request.  Holds that only this
        request's prefix was keeping alive are pruned immediately — a
        cancelled request must not pin pages."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(i)
                if self.paged:
                    self._prune_holds()
                return True
        return False

    # ------------------------------------------------------------------
    # prefill progression
    # ------------------------------------------------------------------

    def _finish_prompt(self, slot: int, tok: int):
        """Prompt complete: record the sampled first token, retire at
        prefill (eos / single-token budget) or move to decode."""
        req = self.slot_req[slot]
        req.out_tokens.append(tok)
        if req.max_new_tokens <= 1 or (
                req.eos_id is not None and tok == req.eos_id):
            self._retire(slot)  # finished at prefill: reclaim pages now
        else:
            self.next_token[slot] = tok
            self.slot_remaining[slot] = req.max_new_tokens - 1
            self.slot_phase[slot] = _DECODE

    def _prefill_programs_per_chunk(self, size: int) -> int:
        """Device programs the paged-attention stage of one prefill chunk
        issues per layer: 1 when the fused kernel applies (attention + KV
        encode + page scatter collapsed into a single Pallas program),
        else 3 (flash_attention, kv_encode, insert_chunk)."""
        if (self.paged and self.cfg.quant.fused_prefill
                and fused_prefill_span_ok(self.max_pages_per_slot,
                                          self.layout.page_size, size)):
            return 1
        return 3

    def _advance_prefill(self, slot: int):
        """Run one prompt chunk for a prefilling slot (per-slot path,
        batched_prefill=False)."""
        req = self.slot_req[slot]
        prompt = np.asarray(req.prompt, np.int32)
        lo = int(self.slot_cursor[slot])
        size = self._next_chunk(slot)
        if self.paged:
            self._ensure_writable(slot, lo, lo + size)
        tokens = jnp.asarray(prompt[None, lo:lo + size])
        cache = self._refresh_meta(self.cache)
        logits, self.cache = self._chunk(self.params, tokens, cache,
                                         jnp.int32(slot))
        sizes = self.stats["prefill_batch_sizes"]
        sizes[1] = sizes.get(1, 0) + 1
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_device_programs"] += \
            self._prefill_programs_per_chunk(size)
        self.slot_cursor[slot] += size
        self.lengths[slot] += size
        self._register_pages(slot)
        if int(self.slot_cursor[slot]) >= len(prompt):
            tok = int(self._sample(logits[:, -1], [slot])[0])
            self._finish_prompt(slot, tok)

    def _run_chunk_group(self, slots: List[int], size: int):
        """Advance every slot in `slots` by one chunk of `size` tokens in
        a single [batch_slots, size] program (cross-slot batched prefill).
        Non-group rows are masked: their length/block-table metadata is
        zeroed (paged writes land on the trash page) and the model reverts
        their batch-dim state rows against the input cache."""
        tokens = np.zeros((self.B, size), np.int32)
        for s in slots:
            lo = int(self.slot_cursor[s])
            tokens[s] = np.asarray(self.slot_req[s].prompt,
                                   np.int32)[lo:lo + size]
            if self.paged:
                self._ensure_writable(s, lo, lo + size)
        active = np.zeros(self.B, bool)
        active[slots] = True
        cache_in = self._refresh_meta(self.cache, active)
        logits, self.cache = self._chunk_batched(
            self.params, jnp.asarray(tokens), cache_in, jnp.asarray(active))
        sizes = self.stats["prefill_batch_sizes"]
        sizes[len(slots)] = sizes.get(len(slots), 0) + 1
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_device_programs"] += \
            self._prefill_programs_per_chunk(size)
        for s in slots:
            self.slot_cursor[s] += size
            self.lengths[s] += size
            self._register_pages(s)
        done = [s for s in slots if int(self.slot_cursor[s])
                >= len(self.slot_req[s].prompt)]
        if done:
            # sample over the fixed [B, V] batch (same trace as decode);
            # rows of unfinished slots draw from dummy keys, discarded
            live = np.zeros(self.B, bool)
            live[done] = True
            toks = self._sample(logits, list(range(self.B)), live=live)
            for s in done:
                self._finish_prompt(s, int(toks[s]))

    def _fill_slots(self) -> bool:
        """Admission + prefill progression for one engine step.  The
        per-step chunk budget applies per request: a request retiring at
        prefill frees its slot for the next queued one within the same
        step (so eos-at-prefill bursts never burn decode iterations).
        With batched_prefill, all slots whose next chunk shares a bucket
        size advance in one program per pass."""
        budget = self.prefill_chunks_per_step or None
        ran = False
        used: Dict[int, int] = {}  # chunks run per request this step
        while True:
            admitted = self._admit()
            todo = [s for s in range(self.B)
                    if self.slot_phase[s] == _PREFILL
                    and (budget is None
                         or used.get(id(self.slot_req[s]), 0) < budget)]
            if not todo:
                if not admitted:
                    break
                continue
            for s in todo:
                used[id(self.slot_req[s])] = \
                    used.get(id(self.slot_req[s]), 0) + 1
            if self.batched_prefill:
                groups: Dict[int, List[int]] = {}
                for s in todo:
                    groups.setdefault(self._next_chunk(s), []).append(s)
                for size in sorted(groups, reverse=True):
                    self._run_chunk_group(groups[size], size)
            else:
                for s in todo:
                    self._advance_prefill(s)
            ran = True
        return ran

    def step(self) -> bool:
        """One engine iteration: admit/prefill, then one decode step for
        every decoding slot.  Returns False when the engine is idle: no
        slot is decoding and no prefill remains in flight."""
        self._fill_slots()
        decode_mask = self.slot_phase == _DECODE
        if not decode_mask.any():
            return bool((self.slot_phase == _PREFILL).any())
        if self.speculate_k:
            T = self._spec_span(decode_mask)
            if T >= 2:
                self._spec_round(decode_mask, T)
                return True
        if self.paged:
            for s in np.nonzero(decode_mask)[0]:
                pos = int(self.lengths[s])
                self._ensure_writable(int(s), pos, pos + 1)
        cache_in = self._refresh_meta(self.cache, decode_mask)
        if self.fused_decode:
            # one device program per decode step: attention + logits head +
            # sampler fused; keys are built (and counters advanced) exactly
            # as the decomposed path would before its sampler dispatch
            keys = self._sample_keys(list(range(self.B)), live=decode_mask)
            toks_all, new_cache = self._decode_sample(
                self.params, jnp.asarray(self.next_token), cache_in, keys,
                jnp.float32(self.temperature))
        else:
            logits, new_cache = self._decode(
                self.params, jnp.asarray(self.next_token), cache_in)
        self.stats["decode_steps"] += 1
        self.stats["decode_device_programs"] += 1 if self.fused_decode else 2
        if (self.slot_phase == _PREFILL).any():
            # slots mid-prefill (interleaved mode) must not have their
            # recurrent/dense state rows advanced by this decode call
            mask = jnp.asarray(decode_mask)
            for name, leaf in new_cache.items():
                bdim = self._state_bdim.get(name)
                if name in ("length", "block_table") or bdim is None:
                    continue
                shape = [1] * leaf.ndim
                shape[bdim] = self.B
                m = mask.reshape(shape)
                new_cache[name] = jnp.where(m, leaf, self.cache[name])
        self.cache = new_cache
        # sample over the full fixed [B, V] batch (rows of non-decoding
        # slots draw from dummy keys and are discarded) so the jitted
        # sampler never retraces as slots retire
        slots = [s for s in range(self.B) if decode_mask[s]]
        if self.fused_decode:
            toks = np.asarray(toks_all, np.int32)[np.asarray(slots)]
        else:
            toks = self._sample(logits, list(range(self.B)),
                                live=decode_mask)[np.asarray(slots)]
        for tok, slot in zip(toks, slots):
            req = self.slot_req[slot]
            req.out_tokens.append(int(tok))
            self.next_token[slot] = tok
            self.lengths[slot] += 1
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0 or (
                    req.eos_id is not None and int(tok) == req.eos_id):
                self._retire(slot)
        return True

    # ------------------------------------------------------------------
    # speculative decoding
    # ------------------------------------------------------------------

    def _spec_span(self, decode_mask) -> int:
        """Tokens per speculative round this iteration.  Capped by the
        addressable tail of every live slot (a write past the block-table
        row would clip-wrap onto the slot's last page — insert_tokens/
        insert_chunk_batched clamp the page index) and by the longest
        remaining budget (drafting past every slot's budget is wasted
        work).  A span < 2 falls back to plain decode."""
        cap = self.max_pages_per_slot * self.layout.page_size
        slots = np.nonzero(decode_mask)[0]
        head = min(cap - int(self.lengths[s]) for s in slots)
        rem = max(int(self.slot_remaining[s]) for s in slots)
        return min(self.speculate_k, head, rem)

    def _spec_verify_keys(self, decode_mask, T: int, base):
        """Row keys for the verify dispatch, b-major to match the verify
        head's [B*T] row order: row (s, j) samples target token t_j with
        the key the plain decode loop would use for that very draw
        (fold_in(slot key, base + j)) — parity of the committed stream
        follows key-for-key."""
        if self.greedy:
            keys = self._spec_dummy_keys.get(T)
            if keys is None:
                keys = jax.random.split(self._base_key, self.B * T)
                self._spec_dummy_keys[T] = keys
            return keys
        return jnp.stack([
            jax.random.fold_in(self._slot_keys[s], base[s] + j)
            if decode_mask[s] else self._dummy_keys[0]
            for s in range(self.B) for j in range(T)])

    def _spec_round(self, decode_mask, T: int):
        """One speculative round over every decoding slot: T-1 draft
        proposals (cheap draft policy, plain decode steps) followed by ONE
        batched multi-query verify under the serve policy.  The verify
        re-encodes all T positions with the serve policy's KV codes before
        attending, so a committed token stream is bitwise identical to
        plain decode — only draws that commit advance the per-slot key
        counter, and lengths roll forward by exactly the committed count.
        """
        slots = [int(s) for s in np.nonzero(decode_mask)[0]]
        base = {s: int(self._slot_sampled[s]) for s in slots}
        for s in slots:
            pos = int(self.lengths[s])
            self._ensure_writable(s, pos, pos + T)
        inputs = np.zeros((self.B, T), np.int32)
        inputs[:, 0] = self.next_token
        # ---- draft: propose d_1 .. d_{T-1}.  d_j guesses the target's
        # j-th draw, so it samples with that draw's key (base + j - 1) —
        # a draft whose logits match the target bitwise accepts 100%.
        cur = jnp.asarray(self.next_token)
        cache = self._refresh_meta(self.cache, decode_mask)
        pool = self.cache
        for j in range(1, T):
            logits, pool = self._draft_decode(self.draft_params, cur, cache)
            if self.greedy:
                keys = self._dummy_keys
            else:
                keys = jnp.stack([
                    jax.random.fold_in(self._slot_keys[s],
                                       base[s] + j - 1)
                    if decode_mask[s] else self._dummy_keys[0]
                    for s in range(self.B)])
            toks = np.asarray(
                self._sampler(logits, keys, jnp.float32(self.temperature)),
                np.int32)
            inputs[:, j] = toks
            cur = jnp.asarray(toks)
            if j < T - 1:
                drafted = self.lengths + np.where(
                    decode_mask, j, 0).astype(np.int32)
                cache = self._refresh_meta(pool, decode_mask,
                                           lengths=drafted)
        # the draft's page writes are placeholders: the verify pass below
        # re-inserts every one of the T positions with the serve policy's
        # codes (per layer, before its attention reads them)
        cache = self._refresh_meta(pool, decode_mask)
        keys = self._spec_verify_keys(decode_mask, T, base)
        toks_bt, self.cache = self._verify(
            self.params, jnp.asarray(inputs), cache, keys,
            jnp.float32(self.temperature))
        toks = np.asarray(toks_bt, np.int32)
        self.stats["spec_rounds"] += 1
        self.stats["decode_steps"] += 1
        # per draft token: one draft decode + one sampler dispatch
        self.stats["decode_device_programs"] += 2 * (T - 1) + 1
        for s in slots:
            req = self.slot_req[s]
            # accept the longest prefix whose drafts matched the verified
            # targets: t_j is trustworthy iff inputs[1..j] == t[0..j-1]
            n_acc = 1
            while n_acc < T and inputs[s, n_acc] == toks[s, n_acc - 1]:
                n_acc += 1
            self.stats["spec_draft_tokens"] += T - 1
            self.stats["spec_accepted_tokens"] += n_acc - 1
            commit = []
            for j in range(min(n_acc, int(self.slot_remaining[s]))):
                t = int(toks[s, j])
                commit.append(t)
                if req.eos_id is not None and t == req.eos_id:
                    break
            c = len(commit)
            req.out_tokens.extend(commit)
            if not self.greedy:
                self._slot_sampled[s] = base[s] + c
            self.lengths[s] += c
            self.next_token[s] = commit[-1]
            self.slot_remaining[s] -= c
            self.stats["spec_committed_tokens"] += c
            if self.slot_remaining[s] <= 0 or (
                    req.eos_id is not None and commit[-1] == req.eos_id):
                self._retire(s)

    def run(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or (self.slot_phase != _FREE).any()) \
                and it < max_iters:
            if not self.step():
                break
            it += 1
        return self.done
