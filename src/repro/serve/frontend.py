"""Asyncio continuous-batching front end over ServingEngine.

The engine itself is a synchronous slot scheduler: `step()` advances
admission, chunked prefill, and one decode iteration.  This module wraps
it in an event loop so callers interact with serving the way clients do —
submit, stream, await — while the engine keeps iteration-level
continuous batching underneath (Orca-style: requests join and leave the
running batch at step granularity, never between prompt boundaries):

  * **streaming**: `submit(..., on_token=cb)` fires the callback per
    generated token as the engine emits it.  Preemption replays a
    request's stream from the start (the engine discards and regenerates
    bit-identically); the front end dedups by emitted count so a client
    never sees a token twice.
  * **SLO classes + deadlines**: each request carries an `SLOClass`
    (priority, preemptible flag) and an optional deadline.  Queued
    requests that blow their deadline are cancelled (`engine.cancel`,
    which prunes any holds their prefix pinned); the queue is kept sorted
    by priority, then submission order.
  * **admission control with preemption**: when a strictly-higher-
    priority request is stuck queued and no slot is free, the lowest-
    priority preemptible running slot is evicted via `engine.preempt` —
    its pages flow through the existing refcount/held-page paths (prompt
    pages become holds the requeued request remaps on re-admission) and
    its request requeues at its original priority, so the preemption
    cannot thrash: the victim sorts behind the request that displaced it.
  * **latency accounting**: time-to-first-token and inter-token-latency
    histograms per request, surfaced through `execution_summary()` next
    to the engine's own datapath counters.

The loop yields control (`await asyncio.sleep(0)`) after every engine
step, so client coroutines interleave submissions with serving on one
thread — no locks, no background threads, deterministic token streams.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from .engine import Request, ServingEngine, _FREE


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A service class: higher `priority` admits first; `preemptible`
    slots may be evicted for a strictly-higher-priority queued request;
    `deadline_ms` is a default queueing deadline for the class (None =
    no deadline)."""
    name: str
    priority: int
    deadline_ms: Optional[float] = None
    preemptible: bool = True


INTERACTIVE = SLOClass("interactive", priority=10, preemptible=False)
BATCH = SLOClass("batch", priority=0)
DEFAULT_SLOS = {c.name: c for c in (INTERACTIVE, BATCH)}


class DeadlineExceeded(Exception):
    """Raised by Ticket.wait() for a request cancelled at its deadline."""


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted request."""
    rid: int
    request: Request
    slo: SLOClass
    deadline: Optional[float]          # absolute clock() time, or None
    on_token: Optional[Callable]
    submitted: float
    seq: int
    state: str = "pending"             # pending | done | expired
    streamed: int = 0                  # tokens already delivered
    first_token_at: Optional[float] = None
    last_token_at: Optional[float] = None
    done_event: asyncio.Event = dataclasses.field(
        default_factory=asyncio.Event)

    async def wait(self) -> List[int]:
        """Block until the request finishes; returns its tokens (raises
        DeadlineExceeded if it was cancelled at its deadline)."""
        await self.done_event.wait()
        if self.state == "expired":
            raise DeadlineExceeded(
                f"request {self.rid} ({self.slo.name}) expired in queue")
        return list(self.request.out_tokens)


class _Histogram:
    """Fixed-bucket latency histogram (milliseconds)."""

    BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
                  1000.0, 2000.0, 5000.0)

    def __init__(self):
        self.samples: List[float] = []

    def add(self, ms: float):
        self.samples.append(float(ms))

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0}
        arr = np.asarray(self.samples, np.float64)
        buckets: Dict[str, int] = {}
        lo = 0.0
        for hi in self.BUCKETS_MS:
            n = int(((arr > lo) & (arr <= hi)).sum()) if lo else \
                int((arr <= hi).sum())
            if n:
                buckets[f"<={hi:g}ms"] = n
            lo = hi
        over = int((arr > self.BUCKETS_MS[-1]).sum())
        if over:
            buckets[f">{self.BUCKETS_MS[-1]:g}ms"] = over
        return {
            "count": int(arr.size),
            "mean_ms": float(arr.mean()),
            "p50_ms": float(np.percentile(arr, 50)),
            "p95_ms": float(np.percentile(arr, 95)),
            "max_ms": float(arr.max()),
            "buckets": buckets,
        }


class AsyncServingFrontend:
    """Asyncio front end over a ServingEngine (see module docstring).

    Typical shape::

        frontend = AsyncServingFrontend(engine)
        t = frontend.submit(prompt, slo="interactive", on_token=cb)
        tokens = (await asyncio.gather(frontend.run(), t.wait()))[1]
    """

    def __init__(self, engine: ServingEngine, slo_classes=None,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.slos = dict(DEFAULT_SLOS)
        for c in (slo_classes or ()):
            self.slos[c.name] = c
        self._clock = clock
        self._tickets: Dict[int, Ticket] = {}
        self._rids = itertools.count()
        self._seq = itertools.count()
        self._done_seen = 0
        self.ttft = _Histogram()
        self.itl = _Histogram()
        self.preemptions = 0
        self.expired = 0

    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 16,
               eos_id: Optional[int] = None, seed: Optional[int] = None,
               slo: str = "batch", deadline_ms: Optional[float] = None,
               on_token: Optional[Callable] = None,
               rid: Optional[int] = None) -> Ticket:
        """Queue a request under an SLO class; returns its Ticket.

        on_token(rid, index, token) fires as tokens stream out (dedup'd
        across preemption replays).  deadline_ms (default: the class's)
        bounds *queueing*: a request still unadmitted past it is
        cancelled and its ticket expires."""
        cls = self.slos[slo]
        if rid is None:
            rid = next(self._rids)
            while rid in self._tickets:
                rid = next(self._rids)
        elif rid in self._tickets:
            raise ValueError(f"duplicate rid {rid}")
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      seed=seed)
        self.engine.submit(req)  # validates budget/capacity; may raise
        dl = cls.deadline_ms if deadline_ms is None else deadline_ms
        now = self._clock()
        ticket = Ticket(rid=rid, request=req, slo=cls,
                        deadline=None if dl is None else now + dl / 1e3,
                        on_token=on_token, submitted=now,
                        seq=next(self._seq))
        self._tickets[rid] = ticket
        self._sort_queue()
        return ticket

    def _sort_queue(self):
        """Priority-then-submission-order queue discipline.  The sort is
        stable over the engine's own queue (which preemption may have
        reordered), so a preempted request resumes in its original
        position among its equals."""
        self.engine.queue.sort(
            key=lambda r: (-self._tickets[r.rid].slo.priority,
                           self._tickets[r.rid].seq))

    # ------------------------------------------------------------------
    def _expire_queued(self, now: float):
        queued = {r.rid for r in self.engine.queue}
        for t in self._tickets.values():
            if (t.state == "pending" and t.deadline is not None
                    and now > t.deadline and t.rid in queued
                    and self.engine.cancel(t.rid)):
                t.state = "expired"
                self.expired += 1
                t.done_event.set()

    def _maybe_preempt(self):
        """Evict the lowest-priority preemptible running slot when a
        strictly-higher-priority request is stuck queued with no free
        slot.  One eviction per loop iteration: the requeued victim sorts
        behind what displaced it, so priorities settle without thrash."""
        eng = self.engine
        if not eng.queue or (eng.slot_phase == _FREE).any():
            return
        top = max(self._tickets[r.rid].slo.priority for r in eng.queue)
        victims = []
        for slot in range(eng.B):
            req = eng.slot_req[slot]
            if req is None:
                continue
            t = self._tickets.get(req.rid)
            prio = t.slo.priority if t else 0
            if (t is None or t.slo.preemptible) and prio < top:
                victims.append((prio, t.seq if t else 0, slot))
        if not victims:
            return
        # the victim replays from scratch after re-admission; _pump's
        # emitted-count dedup resumes its client stream seamlessly
        eng.preempt(min(victims)[2])
        self.preemptions += 1
        self._sort_queue()

    def _pump(self, now: float):
        """Deliver newly generated tokens (dedup'd across preemption
        replays) and settle finished tickets."""
        for t in self._tickets.values():
            if t.state != "pending":
                continue
            out = t.request.out_tokens or []
            while t.streamed < len(out):
                tok = int(out[t.streamed])
                if t.first_token_at is None:
                    t.first_token_at = now
                    self.ttft.add((now - t.submitted) * 1e3)
                else:
                    self.itl.add((now - t.last_token_at) * 1e3)
                t.last_token_at = now
                t.streamed += 1
                if t.on_token is not None:
                    t.on_token(t.rid, t.streamed - 1, tok)
        for req in self.engine.done[self._done_seen:]:
            t = self._tickets.get(req.rid)
            if t is not None and t.state == "pending":
                t.state = "done"
                t.done_event.set()
        self._done_seen = len(self.engine.done)

    # ------------------------------------------------------------------
    async def run(self, max_iters: int = 100_000):
        """Drive the engine until every submitted ticket settles.  Yields
        to the event loop after each engine step so clients can stream
        callbacks and submit mid-flight; run it concurrently with the
        submitters (asyncio.gather)."""
        it = 0
        while any(t.state == "pending" for t in self._tickets.values()):
            now = self._clock()
            self._expire_queued(now)
            self._maybe_preempt()
            self.engine.step()
            self._pump(self._clock())
            it += 1
            if it >= max_iters:
                raise RuntimeError(
                    f"frontend did not drain within {max_iters} engine "
                    f"steps; pending="
                    f"{[t.rid for t in self._tickets.values() if t.state == 'pending']}")
            await asyncio.sleep(0)

    # ------------------------------------------------------------------
    def execution_summary(self) -> dict:
        """Engine datapath summary + front-end latency/scheduling terms."""
        s = self.engine.execution_summary()
        s["ttft_ms"] = self.ttft.summary()
        s["itl_ms"] = self.itl.summary()
        s["frontend_preemptions"] = self.preemptions
        s["expired_requests"] = self.expired
        s["requests_done"] = sum(
            1 for t in self._tickets.values() if t.state == "done")
        return s
