"""Serving: continuous batching engine over jit'd prefill/decode."""
from .engine import ServingEngine, Request  # noqa: F401
