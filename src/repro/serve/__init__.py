"""Serving: shared-prefix paged posit-KV runtime — refcounted block-table
cache with copy-on-write prefix sharing, batched cross-slot chunked
prefill, continuous batching (engine.py), an asyncio front end with SLO
classes, deadlines, preemption, and streaming callbacks (frontend.py),
and posit-native speculative decoding (draft policy + one-dispatch
multi-query verify over the same coded pages)."""
from .engine import ServingEngine, Request, PageAllocator  # noqa: F401
from .frontend import (AsyncServingFrontend, SLOClass, Ticket,  # noqa: F401
                       DeadlineExceeded, INTERACTIVE, BATCH)
