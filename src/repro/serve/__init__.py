"""Serving: shared-prefix paged posit-KV runtime — refcounted block-table
cache with copy-on-write prefix sharing, batched cross-slot chunked
prefill, continuous batching (see engine.py)."""
from .engine import ServingEngine, Request, PageAllocator  # noqa: F401
