"""Serving: paged posit-KV runtime — block-table cache, chunked prefill,
continuous batching (see engine.py)."""
from .engine import ServingEngine, Request, PageAllocator  # noqa: F401
