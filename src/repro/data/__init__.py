"""Deterministic, resumable, host-sharded data pipeline."""
from .pipeline import Pipeline, DataConfig  # noqa: F401
