"""Deterministic, resumable, host-sharded synthetic data pipeline.

Production posture without a corpus on disk: batches are generated from a
counter-based RNG keyed by (seed, step, host_shard), which gives

  * determinism       : restart at step k reproduces batch k exactly
                        (the checkpoint only needs to store `step`)
  * elastic resharding: each host materializes only its slice of the global
                        batch; changing host count changes slicing, not
                        content
  * zero-copy skip    : recovering from a failure needs no data rewind

The same interface would back a real tokenized corpus (index arithmetic in
place of RNG); the trainer and checkpoint layers only see `Pipeline`.
Double-buffered prefetch runs generation in a background thread so host
data work overlaps device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2


class Pipeline:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.model_cfg = model_cfg
        self.shape = shape
        self.cfg = data_cfg
        if shape.global_batch % data_cfg.host_count:
            raise ValueError("global batch not divisible by host count")
        self.local_batch = shape.global_batch // data_cfg.host_count

    # -- deterministic batch synthesis ------------------------------------
    def batch_at(self, step: int) -> dict:
        """Materialize this host's slice of global batch `step`."""
        m, s = self.model_cfg, self.shape
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_index]))
        B, S = self.local_batch, s.seq_len
        out = {}
        if m.frontend == "audio_stub":
            out["frames"] = rng.normal(0, 1, (B, S, m.frontend_dim)).astype(np.float32)
            out["labels"] = rng.integers(0, m.vocab_size, (B, S), dtype=np.int32)
            return out
        if m.frontend == "vision_stub":
            out["patches"] = rng.normal(
                0, 1, (B, m.frontend_tokens, m.frontend_dim)).astype(np.float32)
            text = S - m.frontend_tokens
        else:
            text = S
        # zipfian token stream — vaguely language-shaped marginals
        z = rng.zipf(1.3, size=(B, text + 1)).astype(np.int64)
        toks = np.minimum(z - 1, m.vocab_size - 1).astype(np.int32)
        out["tokens"] = toks[:, :-1]
        labels = toks[:, 1:]
        if m.frontend == "vision_stub":
            pad = np.zeros((B, m.frontend_tokens), np.int32)
            labels = np.concatenate([pad, labels], axis=1)
        out["labels"] = labels
        return out

    # -- prefetching iterator ----------------------------------------------
    def iterator(self, start_step: int = 0) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
