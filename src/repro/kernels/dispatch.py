"""Posit GEMM execution-plan dispatch — the one place model matmuls land.

`models/common.qdot` (and therefore every projection in every architecture)
routes here; `QuantPolicy.execution` picks the datapath:

  fake_quant : STE fake-quantization + plain f32 dot.  Differentiable; the
               training default.  Weights may be float masters or packed
               posit codes (a packed checkpoint served with this plan is
               decoded once per use — same values, no Pallas dependency).
  fused      : the Pallas fused GEMM (`ops.fused_matmul`): operands enter as
               posit codes, decode on the VPU inside the kernel, accumulate
               wide on the MXU, encode once.  With float activations
               (policy.activations None) the serving fast path
               `ops.matmul_posit_weights` runs instead — activations stay
               float (an encode would add a rounding), weights decode
               in-kernel.  Inference-only.
  bit_exact  : the chunked-PDPU kernel (`ops.pdpu_matmul`) — the paper's
               S1..S6 integer datapath with the W_m alignment truncation.
               Bit-identical to a silicon PDPU array; O(M*N*K) select
               chains, so use it for validation at small shapes.

Weights arrive either as float arrays (training params) or as packed posit
codes in int8/int16 (see `models/packing.py`); the dispatcher detects the
container dtype, so one model implementation serves both checkpoint kinds.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import posit
from repro.core.quant import QuantPolicy
from . import ops


def is_packed(w) -> bool:
    """True if `w` holds posit codes in an integer storage container."""
    return jnp.issubdtype(jnp.asarray(w).dtype, jnp.integer)


def _as_matrix(x):
    """[..., K] -> ([M, K], leading shape)."""
    return x.reshape(-1, x.shape[-1]), x.shape[:-1]


def qdot(x, w, policy: QuantPolicy, prec_dtype=jnp.float32, out_dtype=None):
    """Policy-dispatched matmul: x [..., K] @ w [K, N] -> [..., N].

    prec_dtype is the HLO output dtype of the fake_quant dot (see
    models/common.qdot: what a TP partial-sum all-reduce ships); the fused
    and bit_exact kernels always produce f32 before the final cast.
    out_dtype=None returns x.dtype.
    """
    if w.ndim != 2:
        raise ValueError(f"qdot weights must be 2-D [K, N], got {w.shape}")
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"contraction mismatch {x.shape} x {w.shape}")
    out_dtype = out_dtype or x.dtype
    packed = is_packed(w)
    if packed and policy.weights is None:
        raise ValueError("packed posit weights need QuantPolicy.weights set")
    plan = policy.execution

    if plan == "fake_quant":
        if packed:
            # codes are one rounding of the float masters; decoding matches
            # maybe_quant_weight exactly when masters were stored in x.dtype
            # precision (bf16 compute skips the master->bf16 pre-rounding)
            wq = posit.unpack(w, policy.weights, dtype=x.dtype)
        else:
            wq = policy.maybe_quant_weight(w.astype(x.dtype))
        xq = policy.maybe_quant_act(x)
        return jnp.dot(xq, wq, preferred_element_type=prec_dtype).astype(out_dtype)

    xf, lead = _as_matrix(x)

    if plan == "fused":
        fmt_w = policy.weights
        w_codes = w if packed else ops.encode(w.astype(jnp.float32), fmt_w)
        if policy.activations is None:
            out = ops.matmul_posit_weights(xf, w_codes, fmt_w)
        else:
            a_codes = ops.encode(xf.astype(jnp.float32), policy.activations)
            out = ops.fused_matmul(a_codes, w_codes, policy.activations, fmt_w,
                                   fmt_out=None)
        return out.reshape(lead + (w.shape[-1],)).astype(out_dtype)

    if plan == "bit_exact":
        cfg = policy.pdpu_config()
        a_codes = posit.encode(xf.astype(jnp.float32), cfg.fmt_in)
        if packed:
            # packed weights are in policy.weights == cfg.fmt_in by
            # construction (pdpu_config derives fmt_in from it)
            w_codes = w.astype(jnp.int32) & cfg.fmt_in.mask
        else:
            w_codes = posit.encode(w.astype(jnp.float32), cfg.fmt_in)
        pad_k = (-xf.shape[1]) % cfg.N  # whole chunks; code 0 is exact zero
        if pad_k:
            a_codes = jnp.pad(a_codes, ((0, 0), (0, pad_k)))
            w_codes = jnp.pad(w_codes, ((0, pad_k), (0, 0)))
        out_codes = ops.pdpu_matmul(a_codes, w_codes, cfg)
        out = posit.decode(out_codes, cfg.fmt_out)
        return out.reshape(lead + (w.shape[-1],)).astype(out_dtype)

    raise ValueError(f"unknown execution plan '{plan}'")
