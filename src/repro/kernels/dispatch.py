"""Posit GEMM execution-plan dispatch — the one place model matmuls land.

`models/common.qdot` (and therefore every projection in every architecture)
routes here; `QuantPolicy.execution` picks the datapath.  The plan table
(mirrored in `core/quant.PLAN_TABLE`):

  plan        trainable  servable  datapath
  ----------  ---------  --------  -------------------------------------------
  fake_quant  yes        yes       STE fake-quantization + plain f32 dot.  The
                                   training default.  Weights may be float
                                   masters or packed posit codes (a packed
                                   checkpoint served with this plan is decoded
                                   once per use — same values, no Pallas
                                   dependency).
  fused       yes        yes       the Pallas fused GEMM (`ops.fused_matmul`):
                                   operands enter as posit codes, decode on
                                   the VPU inside the kernel, accumulate wide
                                   on the MXU, encode once.  With float
                                   activations (policy.activations None) the
                                   serving fast path
                                   `ops.matmul_posit_weights` runs instead —
                                   activations stay float (an encode would add
                                   a rounding), weights decode in-kernel.
                                   Setting policy.activations (e.g. via
                                   `QuantPolicy.with_serving_activations`)
                                   runs the both-operands kernel: activations
                                   travel as codes too — the activation-coded
                                   serving mode, trading one rounding per
                                   element for int8/int16 operand bandwidth.
                                   Float-master weights take the custom_vjp
                                   STE entry points (`ops.*_ste`): forward is
                                   the identical packed kernel, backward is
                                   straight-through w.r.t. float activations
                                   and weight masters — kernel-in-the-loop
                                   QAT.
  bit_exact   no         yes       the chunked-PDPU kernel (`ops.pdpu_matmul`)
                                   — the paper's S1..S6 integer datapath with
                                   the W_m alignment truncation.  Bit-
                                   identical to a silicon PDPU array; O(M*N*K)
                                   select chains, so use it for validation at
                                   small shapes.  `jax.grad` through it raises
                                   a clear error (grad barrier below).

Weights arrive either as float arrays (training params) or as packed posit
codes in int8/int16 (see `models/packing.py`); the dispatcher detects the
container dtype, so one model implementation serves both checkpoint kinds.

Two entry points share the plan table:

  qdot         : x [..., K] @ w [K, N] — every dense projection.
  qdot_grouped : stacked expert weights w [E, K, N] against per-expert
                 activation slabs x [E, C, K] (sort-based dispatch buffers)
                 or [B, E, Cg, K] (GShard grouped dispatch; the batch dim
                 folds onto the per-expert row dim for the kernel and folds
                 back after).  The fused plan runs the batched Pallas kernel
                 (`ops.fused_matmul_grouped` / `matmul_posit_weights_grouped`)
                 with a leading expert grid dimension — per-expert f32
                 scratch accumulate, single encode — so EP serving reads
                 expert stacks as int8/int16 codes straight from HBM.  The
                 bit_exact plan validates expert-by-expert against the
                 chunked-PDPU datapath.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.quant import QuantPolicy, TRAINABLE_PLANS
from . import ops


def is_packed(w) -> bool:
    """True if `w` holds posit codes in an integer storage container."""
    return jnp.issubdtype(jnp.asarray(w).dtype, jnp.integer)


_BIT_EXACT_MSG = (
    f"execution plan 'bit_exact' is not differentiable; trainable plans "
    f"are {TRAINABLE_PLANS}.  Switch the QuantPolicy with "
    f".with_execution(...) for QAT — bit_exact is a forward-only "
    f"validation datapath.")

_PACKED_ACT_MSG = (
    "the activation-coded fused plan over packed int weights is not "
    "differentiable: the float->code activation encode drops tangents, so "
    "gradients would silently be zero.  Unpack the checkpoint to float "
    "masters (models/packing.unpack_params) to differentiate under the "
    "fused plan.")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grad_barrier(reason: str, x):
    """Identity in the primal; raises `reason` when differentiated.

    Applied to float operands whose datapath has no backward.  Without it,
    `jax.grad` through e.g. bit_exact would silently return zeros: the
    operand's tangent is dropped at the float->code encode, so no autodiff
    rule ever fires.  custom_vjp's fwd only runs under differentiation, so
    the forward pass pays nothing.
    """
    return x


def _grad_barrier_fwd(reason, x):
    raise ValueError(reason)


def _grad_barrier_bwd(reason, res, g):
    raise AssertionError("unreachable: fwd always raises")


_grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


def _as_matrix(x):
    """[..., K] -> ([M, K], leading shape)."""
    return x.reshape(-1, x.shape[-1]), x.shape[:-1]


def qdot(x, w, policy: QuantPolicy, prec_dtype=jnp.float32, out_dtype=None):
    """Policy-dispatched matmul: x [..., K] @ w [K, N] -> [..., N].

    prec_dtype is the HLO output dtype of the fake_quant dot (see
    models/common.qdot: what a TP partial-sum all-reduce ships); the fused
    and bit_exact kernels always produce f32 before the final cast.
    out_dtype=None returns x.dtype.
    """
    if w.ndim != 2:
        raise ValueError(f"qdot weights must be 2-D [K, N], got {w.shape}")
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"contraction mismatch {x.shape} x {w.shape}")
    out_dtype = out_dtype or x.dtype
    packed = is_packed(w)
    if packed and policy.weights is None:
        raise ValueError("packed posit weights need QuantPolicy.weights set")
    plan = policy.execution

    if plan == "fake_quant":
        if packed:
            # codes are one rounding of the float masters; decoding matches
            # maybe_quant_weight exactly when masters were stored in x.dtype
            # precision (bf16 compute skips the master->bf16 pre-rounding)
            wq = posit.unpack(w, policy.weights, dtype=x.dtype)
        else:
            wq = policy.maybe_quant_weight(w.astype(x.dtype))
        xq = policy.maybe_quant_act(x)
        return jnp.dot(xq, wq, preferred_element_type=prec_dtype).astype(out_dtype)

    xf, lead = _as_matrix(x)

    if plan == "fused":
        fmt_w = policy.weights
        if packed:
            # serving path: weights already posit codes, forward-only
            if policy.activations is None:
                out = ops.matmul_posit_weights(xf, w, fmt_w)
            else:
                xf = _grad_barrier(_PACKED_ACT_MSG, xf)
                a_codes = ops.encode(xf.astype(jnp.float32),
                                     policy.activations)
                out = ops.fused_matmul(a_codes, w, policy.activations, fmt_w,
                                       fmt_out=None)
        else:
            # float masters: the differentiable STE entry points — the same
            # packed-kernel forward, straight-through backward (QAT)
            if policy.activations is None:
                out = ops.matmul_posit_weights_ste(
                    xf.astype(jnp.float32), w.astype(jnp.float32), fmt_w)
            else:
                out = ops.fused_matmul_ste(xf.astype(jnp.float32),
                                           w.astype(jnp.float32),
                                           policy.activations, fmt_w)
        return out.reshape(lead + (w.shape[-1],)).astype(out_dtype)

    if plan == "bit_exact":
        cfg = policy.pdpu_config()
        xf = _grad_barrier(_BIT_EXACT_MSG, xf)
        a_codes = posit.encode(xf.astype(jnp.float32), cfg.fmt_in)
        if packed:
            # packed weights are in policy.weights == cfg.fmt_in by
            # construction (pdpu_config derives fmt_in from it)
            w_codes = w.astype(jnp.int32) & cfg.fmt_in.mask
        else:
            w = _grad_barrier(_BIT_EXACT_MSG, w)
            w_codes = posit.encode(w.astype(jnp.float32), cfg.fmt_in)
        pad_k = (-xf.shape[1]) % cfg.N  # whole chunks; code 0 is exact zero
        if pad_k:
            a_codes = jnp.pad(a_codes, ((0, 0), (0, pad_k)))
            w_codes = jnp.pad(w_codes, ((0, pad_k), (0, 0)))
        out_codes = ops.pdpu_matmul(a_codes, w_codes, cfg)
        out = posit.decode(out_codes, cfg.fmt_out)
        return out.reshape(lead + (w.shape[-1],)).astype(out_dtype)

    raise ValueError(f"unknown execution plan '{plan}'")


def qdot_grouped(x, w, policy: QuantPolicy, prec_dtype=jnp.float32,
                 out_dtype=None):
    """Policy-dispatched grouped matmul over stacked expert weights.

    x: [E, C, K] or [B, E, Cg, K] activations; w: [E, K, N] stacked weights
    (float masters or packed posit codes) -> [E, C, N] / [B, E, Cg, N].
    Plan semantics match `qdot` exactly, applied per expert; the fused plan
    runs the batched Pallas kernel with a leading expert grid dimension.
    """
    if w.ndim != 3:
        raise ValueError(f"qdot_grouped weights must be 3-D [E, K, N], "
                         f"got {w.shape}")
    if x.ndim not in (3, 4):
        raise ValueError(f"qdot_grouped activations must be [E, C, K] or "
                         f"[B, E, Cg, K], got {x.shape}")
    E, K, N = w.shape
    if x.shape[-3] != E or x.shape[-1] != K:
        raise ValueError(f"grouped contraction mismatch {x.shape} x {w.shape}")
    out_dtype = out_dtype or x.dtype
    packed = is_packed(w)
    if packed and policy.weights is None:
        raise ValueError("packed posit weights need QuantPolicy.weights set")
    plan = policy.execution

    if plan == "fake_quant":
        if packed:
            wq = posit.unpack(w, policy.weights, dtype=x.dtype)
        else:
            wq = policy.maybe_quant_weight(w.astype(x.dtype))
        xq = policy.maybe_quant_act(x)
        eq = "ecd,edf->ecf" if x.ndim == 3 else "becd,edf->becf"
        return jnp.einsum(eq, xq, wq,
                          preferred_element_type=prec_dtype).astype(out_dtype)

    # fold a leading batch dim onto the per-expert row dim: the kernel sees
    # one [E, rows, K] slab; rows unfold after
    batched = x.ndim == 4
    if batched:
        B, _, C, _ = x.shape
        xe = jnp.moveaxis(x, 0, 1).reshape(E, B * C, K)
    else:
        xe = x

    if plan == "fused":
        fmt_w = policy.weights
        if packed:
            # serving path: expert stacks already posit codes, forward-only
            if policy.activations is None:
                out = ops.matmul_posit_weights_grouped(xe, w, fmt_w)
            else:
                xe = _grad_barrier(_PACKED_ACT_MSG, xe)
                a_codes = ops.encode(xe.astype(jnp.float32),
                                     policy.activations)
                out = ops.fused_matmul_grouped(a_codes, w,
                                               policy.activations, fmt_w,
                                               fmt_out=None)
        else:
            # float masters: the grouped STE entry points (QAT datapath)
            if policy.activations is None:
                out = ops.matmul_posit_weights_grouped_ste(
                    xe.astype(jnp.float32), w.astype(jnp.float32), fmt_w)
            else:
                out = ops.fused_matmul_grouped_ste(xe.astype(jnp.float32),
                                                   w.astype(jnp.float32),
                                                   policy.activations, fmt_w)
    elif plan == "bit_exact":
        cfg = policy.pdpu_config()
        xe = _grad_barrier(_BIT_EXACT_MSG, xe)
        a_codes = posit.encode(xe.astype(jnp.float32), cfg.fmt_in)
        if packed:
            w_codes = w.astype(jnp.int32) & cfg.fmt_in.mask
        else:
            w = _grad_barrier(_BIT_EXACT_MSG, w)
            w_codes = posit.encode(w.astype(jnp.float32), cfg.fmt_in)
        pad_k = (-K) % cfg.N  # whole chunks; code 0 is exact zero
        if pad_k:
            a_codes = jnp.pad(a_codes, ((0, 0), (0, 0), (0, pad_k)))
            w_codes = jnp.pad(w_codes, ((0, 0), (0, pad_k), (0, 0)))
        # validation plan: one traced kernel call mapped over the expert
        # dim (trace size stays O(1) in E, unlike a Python unroll)
        out_codes = jax.lax.map(
            lambda aw: ops.pdpu_matmul(aw[0], aw[1], cfg),
            (a_codes, w_codes))
        out = posit.decode(out_codes, cfg.fmt_out)
    else:
        raise ValueError(f"unknown execution plan '{plan}'")

    if batched:
        out = jnp.moveaxis(out.reshape(E, B, C, N), 1, 0)
    return out.astype(out_dtype)
