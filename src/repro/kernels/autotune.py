"""Kernel autotuner: per-(shape-bucket, posit format, backend) tile caches.

Every Pallas kernel in this package ships tile/block constants tuned for the
MXU/VPU geometry (`_BM/_BN/_BK` in posit_matmul.py, `_BLOCK_R/_BLOCK_C` in
posit_codec.py, the query tile of the multi-query paged-attention grid).
Those constants are the *fallback*; this module resolves the actual launch
parameters through a persisted autotune cache at dispatch time (ops.py),
so a sweep run once per host platform (launch/autotune.py) speeds up every
later process without any code change.

Cache JSON schema (version `CACHE_VERSION`)
-------------------------------------------

    {
      "version": 1,
      "backend": "cpu",                     # jax.default_backend() at sweep
      "generated_by": "launch/autotune.py",
      "entries": {
        "<digest>": {
          "kernel": "posit_matmul",         # tunable name (TUNABLES key)
          "key":    {"shape": [256, 512, 256], "fmts": ["P16_2", "P16_2"]},
          "params": {"bm": 128, "bn": 256, "bk": 512},
          "ms":     0.42,                   # best measured wall clock
          "oracle_ms": 0.011                # roofline estimate of the winner
        }, ...
      }
    }

Key digest
----------

`key_digest(kernel, backend, key)` = first 16 hex chars of blake2b over the
canonical (sorted-keys, no-whitespace) JSON of
`{"version", "kernel", "backend", "key"}` — so a cache entry is invalidated
automatically by a schema bump, a backend change, or any change to the key
contents.  The shape component of the key is *bucketed* (`shape_bucket`:
each dim rounded up to the next power of two, min 8) so one sweep covers a
band of problem sizes; kernels clamp/pad internally, which keeps any
bucketed winner correct for every shape in its bucket.

Regenerating the committed cache
--------------------------------

`src/repro/kernels/autotune_cache.json` is the committed cache for the CI
host platform (CPU interpret mode).  Regenerate it with:

    PYTHONPATH=src python -m repro.launch.autotune --commit

which sweeps the serving-representative shape set (see
`launch/autotune.py`), prunes each candidate grid with the roofline cost
oracle (`oracle_cost`, cross-checkable against `launch/hlo_analysis.py`
via `hlo_cost`), wall-clock times the survivors, and rewrites the JSON.
Set `REPRO_AUTOTUNE=off` to disable cache lookups entirely, or
`REPRO_AUTOTUNE_CACHE=/path.json` to point at a different cache file.

Cost oracle
-----------

The sweep is pruned before any timing: `oracle_cost(kernel, shape, params)`
computes the *padded* FLOP and HBM-byte volume a candidate tiling actually
launches (tiles larger than a dim pad it up — real wasted work) and turns
them into a roofline time with the `launch.mesh.HW` constants, exactly the
model `benchmarks/roofline.py` applies to the dryrun sweeps.  Candidates
whose oracle time exceeds `prune_factor` x the best oracle time are never
timed.  `hlo_cost(fn, *args)` lowers + compiles a candidate and runs
`launch.hlo_analysis.analyze_hlo` over the HLO text — the CLI's
`--oracle-check` mode uses it to validate the analytic model against the
compiler's view.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import time

import jax
import jax.numpy as jnp

CACHE_VERSION = 1
DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(__file__),
                                  "autotune_cache.json")

# bytes per element of the posit storage container / f32
_STORE_BYTES = {8: 1, 16: 2, 32: 4}


def _enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "on").lower() not in ("0", "off")


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE_PATH)


def shape_bucket(shape) -> tuple:
    """Round each dim up to the next power of two (min 8): the cache key's
    shape component, so one tuned entry covers a band of problem sizes."""
    out = []
    for d in shape:
        b = 8
        while b < d:
            b *= 2
        out.append(b)
    return tuple(out)


def _fmt_name(fmt) -> str:
    if fmt is None:
        return "f32"
    return f"P{fmt.n}_{fmt.es}"


def make_key(shape, fmts=()) -> dict:
    """Canonical cache key contents: bucketed shape + posit format names."""
    return {"shape": list(shape_bucket(shape)),
            "fmts": [_fmt_name(f) for f in fmts]}


def key_digest(kernel: str, backend: str, key: dict) -> str:
    blob = json.dumps({"version": CACHE_VERSION, "kernel": kernel,
                       "backend": backend, "key": key},
                      sort_keys=True, separators=(",", ":"))
    return hashlib.blake2b(blob.encode(), digest_size=8).hexdigest()


# ---------------------------------------------------------------------------
# tunable spaces: kernel name -> candidate parameter grid
# ---------------------------------------------------------------------------

TUNABLES = {
    "posit_codec.decode": {"block_r": (64, 128, 256, 512),
                           "block_c": (128, 256, 512, 1024)},
    "posit_codec.encode": {"block_r": (64, 128, 256, 512),
                           "block_c": (128, 256, 512, 1024)},
    "posit_matmul": {"bm": (128, 256), "bn": (128, 256), "bk": (256, 512)},
    "posit_matmul_grouped": {"bm": (128, 256), "bn": (128, 256),
                             "bk": (256, 512)},
    "paged_attention": {"t_block": (1, 2, 4, 8)},
    # fused prefill: TPU launch knobs — whether the batch grid dim may run
    # as a parallel (multi-core) dimension, and the Mosaic VMEM budget
    # (None = compiler default).  Neither changes the computed values.
    "prefill_attention": {"dimension_semantics": ("parallel", "arbitrary"),
                          "vmem_limit_mb": (None, 64, 128)},
    # fused decode epilogue: vocab tile width of the streamed logits GEMM +
    # sampler.  0 collapses the vocab grid dimension (whole vocab in one
    # step); any tiling is bitwise identical (rows stay whole).
    "decode_sample": {"v_block": (0, 512, 1024, 2048)},
}


def candidates(kernel: str):
    """Full parameter grid for a tunable kernel (pre-pruning)."""
    space = TUNABLES[kernel]
    names = sorted(space)
    for vals in itertools.product(*(space[n] for n in names)):
        yield dict(zip(names, vals))


# ---------------------------------------------------------------------------
# cost oracle: padded-volume roofline (+ HLO cross-check)
# ---------------------------------------------------------------------------

def _pad_up(d, b):
    b = min(b, d)
    return -(-d // b) * b


def oracle_cost(kernel: str, shape, params: dict, fmts=()) -> float:
    """Roofline seconds for one launch of `kernel` at `shape` under a
    candidate tiling: padded FLOPs / padded HBM bytes through the
    `launch.mesh.HW` constants.  Used to prune the sweep — a tile that pads
    a dim 4x does 4x the work, which the oracle sees without timing it."""
    from repro.launch.mesh import HW

    def elt_bytes(i):
        f = fmts[i] if i < len(fmts) else None
        return 4 if f is None else _STORE_BYTES[f.storage_bits]

    if kernel in ("posit_codec.decode", "posit_codec.encode"):
        R, C = shape
        rp = _pad_up(R, params["block_r"])
        cp = _pad_up(C, params["block_c"])
        n = rp * cp
        flops = 8 * n  # ~bit-ops per element on the VPU
        in_b = 2 if fmts else 4
        bytes_ = n * (in_b + 4)
    elif kernel in ("posit_matmul", "posit_matmul_grouped"):
        if kernel == "posit_matmul_grouped":
            E, M, K, N = shape
        else:
            E, (M, K, N) = 1, shape
        mp = _pad_up(M, params["bm"])
        kp = _pad_up(K, params["bk"])
        np_ = _pad_up(N, params["bn"])
        flops = 2.0 * E * mp * kp * np_
        n_k = kp // min(params["bk"], K)
        # A tile re-read per N block, B tile re-read per M block, one out
        bytes_ = E * (mp * kp * elt_bytes(0) * (np_ // min(params["bn"], N))
                      + kp * np_ * elt_bytes(1) * (mp // min(params["bm"], M))
                      + mp * np_ * 4)
        del n_k
    elif kernel == "paged_attention":
        B, T, M, ps, F = shape
        tb = min(params["t_block"], T)
        tp = _pad_up(T, tb)
        # every (slot, q-tile) sweep re-reads the slot's pages
        bytes_ = B * (tp // tb) * M * ps * F * elt_bytes(0) * 2
        flops = 4.0 * B * tp * M * ps * F
    elif kernel == "prefill_attention":
        # launch knobs (dimension_semantics / VMEM budget) don't change the
        # computed volume — every candidate shares the roofline estimate and
        # all survive pruning into the wall-clock timing stage.
        B, C, M, ps, F = shape
        S = M * ps + C  # worst case: full history + the chunk itself
        bytes_ = B * (C * F * 4 * 3                  # q/k/v chunk reads
                      + M * ps * F * elt_bytes(0) * 2  # history pages (k+v)
                      + C * F * elt_bytes(0) * 2       # encoded page writes
                      + C * F * 4)                     # attention output
        flops = 4.0 * B * C * S * F
    elif kernel == "decode_sample":
        B, D, V = shape
        vb = params["v_block"] or V  # 0 = whole vocab (collapsed grid)
        vp = _pad_up(V, vb)
        # head weights streamed once; x re-read per vocab tile; noise +
        # logits epilogue at f32
        bytes_ = (D * vp * elt_bytes(0)
                  + (vp // min(vb, V)) * B * D * 4 + 2 * B * vp * 4)
        flops = 2.0 * B * D * vp + 8.0 * B * vp
    else:
        raise KeyError(f"no oracle for kernel '{kernel}'")
    return max(flops / HW["peak_flops_bf16"], bytes_ / HW["hbm_bw"])


def hlo_cost(fn, *args) -> dict:
    """Compile a candidate and account its HLO with launch/hlo_analysis —
    the compiler-side cross-check of `oracle_cost` (CLI --oracle-check)."""
    from repro.launch.hlo_analysis import analyze_hlo
    text = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(text)


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------

class AutotuneCache:
    """In-memory view of one cache JSON + hit/miss accounting."""

    def __init__(self, backend: str | None = None, entries: dict | None = None):
        self.backend = backend or jax.default_backend()
        self.entries = dict(entries or {})
        self.hits: dict = {}
        self.misses: dict = {}

    # -- persistence ------------------------------------------------------

    @classmethod
    def load(cls, path: str | None = None) -> "AutotuneCache":
        path = path or cache_path()
        backend = jax.default_backend()
        if not os.path.exists(path):
            return cls(backend)
        with open(path) as f:
            raw = json.load(f)
        if raw.get("version") != CACHE_VERSION:
            return cls(backend)  # schema bump invalidates the file wholesale
        return cls(backend, raw.get("entries", {}))

    def save(self, path: str | None = None) -> str:
        path = path or cache_path()
        payload = {"version": CACHE_VERSION, "backend": self.backend,
                   "generated_by": "launch/autotune.py",
                   "entries": self.entries}
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        return path

    # -- lookup / insert --------------------------------------------------

    def lookup(self, kernel: str, shape, fmts=()) -> dict | None:
        """Tuned params for (kernel, bucketed shape, formats) or None.
        Records per-kernel hit/miss counts for `hit_report`."""
        key = make_key(shape, fmts)
        ent = self.entries.get(key_digest(kernel, self.backend, key))
        if ent is not None and ent.get("kernel") == kernel:
            self.hits[kernel] = self.hits.get(kernel, 0) + 1
            return dict(ent["params"])
        self.misses[kernel] = self.misses.get(kernel, 0) + 1
        return None

    def put(self, kernel: str, shape, params: dict, fmts=(),
            ms: float | None = None, oracle_ms: float | None = None):
        key = make_key(shape, fmts)
        self.entries[key_digest(kernel, self.backend, key)] = {
            "kernel": kernel, "key": key, "params": dict(params),
            "ms": ms, "oracle_ms": oracle_ms}

    def report(self) -> dict:
        """Per-kernel {hits, misses} since load — what the serving example
        prints so tuned-config coverage is visible at a glance."""
        kernels = sorted(set(self.hits) | set(self.misses))
        return {k: {"hits": self.hits.get(k, 0),
                    "misses": self.misses.get(k, 0)} for k in kernels}


_CACHE: AutotuneCache | None = None


def get_cache() -> AutotuneCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = AutotuneCache.load()
    return _CACHE


def reset_cache(cache: AutotuneCache | None = None):
    """Swap/clear the process-wide cache (tests; CLI after a sweep)."""
    global _CACHE
    _CACHE = cache


def lookup(kernel: str, shape, fmts=()) -> dict | None:
    """Dispatch-time resolution hook (ops.py): tuned params or None.
    Honors REPRO_AUTOTUNE=off."""
    if not _enabled():
        return None
    return get_cache().lookup(kernel, shape, fmts)


def hit_report() -> dict:
    cache = _CACHE
    return cache.report() if cache is not None else {}


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def _time_once(fn, reps: int) -> float:
    jax.block_until_ready(fn())  # warm-up/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e3


def sweep(kernel: str, shape, run, fmts=(), reps: int = 3,
          prune_factor: float = 4.0):
    """Tune one (kernel, shape, formats) point.

    `run(params) -> thunk`: builds a zero-arg callable launching the kernel
    with candidate `params`.  Every candidate is scored by the roofline
    oracle first; only candidates within `prune_factor` x the best oracle
    estimate are wall-clock timed (`reps` reps after a warm-up).  Returns
    (best_params, best_ms, table) with the full candidate table for the
    CLI's report.
    """
    scored = [(oracle_cost(kernel, shape, p, fmts), p)
              for p in candidates(kernel)]
    best_oracle = min(c for c, _ in scored)
    table = []
    best = None
    for cost, params in sorted(scored, key=lambda t: t[0]):
        if cost > prune_factor * best_oracle:
            table.append({"params": params, "oracle_ms": cost * 1e3,
                          "ms": None, "pruned": True})
            continue
        try:
            ms = _time_once(run(params), reps)
        except Exception as e:  # an illegal tiling for this shape
            table.append({"params": params, "oracle_ms": cost * 1e3,
                          "ms": None, "pruned": False, "error": str(e)})
            continue
        table.append({"params": params, "oracle_ms": cost * 1e3,
                      "ms": ms, "pruned": False})
        if best is None or ms < best[1]:
            best = (params, ms, cost * 1e3)
    if best is None:
        raise RuntimeError(f"no timeable candidate for {kernel} @ {shape}")
    return best[0], best[1], table
