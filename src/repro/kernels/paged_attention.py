"""Pallas TPU kernel: paged-attention decode over posit-coded KV pages.

The serving KV cache is a pool of fixed-size pages `[n_pages, page_size,
Hkv*Dh]` stored at posit code width (int8/int16); each batch slot owns an
ordered list of page indices (its *block table*), so page j of a slot holds
the keys/values for absolute positions [j*page_size, (j+1)*page_size).

This kernel is the PDPU fused-decode idea applied to attention instead of
GEMM: per (slot, page) grid cell it

  * gathers the page by block table — `PrefetchScalarGridSpec` scalar-
    prefetches the block tables so the BlockSpec index_map DMAs exactly the
    pages the slot owns, HBM->VMEM at code width (the paged cache is never
    materialized densely, and never decoded in HBM),
  * decodes the posit codes to exact f32 on the VPU *inside* the kernel,
    right next to the q·k dot — one decode per element, total,
  * accumulates a streaming softmax (running max / normalizer / weighted
    value sum in f32 VMEM scratch) across the slot's pages — the wide-
    accumulator property held across the page dimension,
  * normalizes and writes the output once on the last page.

Masking: page p covers positions p*ps + [0, ps); entries at positions
>= lengths[b] are dead (beyond the slot's written prefix — freshly
allocated or reclaimed-page garbage) and are masked before the running max,
so page reclamation never leaks stale keys into a new request's attention.
A sliding window is applied as (q_pos - pos) < window with
q_pos = lengths[b] - 1 (the token written immediately before this call).

Shapes here follow the serving decode step (one query token per slot);
tiles are sized by the model's head layout rather than MXU tiles — on CPU
every call runs in interpret mode (like the other kernels in this package),
on TPU the (ps, Hkv*Dh) page is the natural VMEM block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import posit
from repro.core.formats import PositFormat

_NEG = -2.0e38

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def _paged_attention_kernel(bt_ref, len_ref, win_ref, ok_ref, q_ref, k_ref,
                            v_ref, *refs, fmt_kv: PositFormat | None,
                            page_size: int, n_heads: int, n_kv_heads: int,
                            head_dim: int, softcap_val: float, partials: bool):
    if partials:
        out_ref, m_ref, l_ref, m_scr, l_scr, o_scr = refs
    else:
        (out_ref, m_scr, l_scr, o_scr), m_ref, l_ref = refs, None, None
    b = pl.program_id(0)
    p = pl.program_id(1)
    G = n_heads // n_kv_heads

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        o_scr[...] = jnp.zeros_like(o_scr)

    # in-kernel decode: the page travels HBM->VMEM as posit codes and turns
    # into exact f32 only here, next to the dot (fmt_kv=None = float pages)
    if fmt_kv is None:
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
    else:
        k = posit.decode(k_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
        v = posit.decode(v_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
    k = k.reshape(page_size, n_kv_heads, head_dim)
    v = v.reshape(page_size, n_kv_heads, head_dim)

    scale = 1.0 / math.sqrt(head_dim)
    qg = q_ref[0].reshape(n_kv_heads, G, head_dim).astype(jnp.float32) * scale
    s = jnp.einsum("hgd,khd->hgk", qg, k)  # [Hkv, G, ps]
    s = _softcap(s, softcap_val)

    length = len_ref[b]
    pos = p * page_size + jax.lax.iota(jnp.int32, page_size)
    q_pos = length - 1  # the query token sits at the last written position
    mask = (pos < length) & ((q_pos - pos) < win_ref[0]) & (ok_ref[b, p] > 0)
    s = jnp.where(mask[None, None, :], s, _NEG)

    m_prev, l_prev, o_prev = m_scr[...], l_scr[...], o_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    pr = jnp.exp(s - m_new[..., None])
    pr = jnp.where(mask[None, None, :], pr, 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + jnp.sum(pr, axis=-1)
    o_scr[...] = o_prev * corr[..., None] + jnp.einsum("hgk,khd->hgd", pr, v)

    @pl.when(p == pl.num_programs(1) - 1)
    def _finalize():
        if partials:
            # leave the streaming state unnormalized: (o, m, l) per slot, to
            # be log-sum-exp merged across kv_pages shards (ops.
            # merge_attn_partials) before the single final normalization
            out_ref[0] = o_scr[...].reshape(n_heads, head_dim)
            m_ref[0] = m_scr[...].reshape(n_heads)
            l_ref[0] = l_scr[...].reshape(n_heads)
        else:
            o = o_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
            out_ref[0] = o.reshape(n_heads, head_dim)


def _paged_attention_mq_kernel(bt_ref, len_ref, win_ref, ok_ref, q_ref,
                               k_ref, v_ref, *refs,
                               fmt_kv: PositFormat | None, page_size: int,
                               t_total: int, t_block: int, n_heads: int,
                               n_kv_heads: int, head_dim: int,
                               softcap_val: float, partials: bool):
    """Multi-query grid: one launch covers T new tokens per slot.

    Query row i of slot b sits at absolute position lengths[b] - T + i
    (all T tokens already inserted); causality between the new tokens is
    enforced by the same position mask that guards written-prefix reads.
    Rows are independent, so any t_block tiling of T is bitwise identical
    — t_block is the autotuned launch parameter.
    """
    if partials:
        out_ref, m_ref, l_ref, m_scr, l_scr, o_scr = refs
    else:
        (out_ref, m_scr, l_scr, o_scr), m_ref, l_ref = refs, None, None
    b = pl.program_id(0)
    t = pl.program_id(1)
    p = pl.program_id(2)
    G = n_heads // n_kv_heads

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        o_scr[...] = jnp.zeros_like(o_scr)

    if fmt_kv is None:
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
    else:
        k = posit.decode(k_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
        v = posit.decode(v_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
    k = k.reshape(page_size, n_kv_heads, head_dim)
    v = v.reshape(page_size, n_kv_heads, head_dim)

    scale = 1.0 / math.sqrt(head_dim)
    qg = q_ref[0].reshape(t_block, n_kv_heads, G, head_dim) \
                 .astype(jnp.float32) * scale
    s = jnp.einsum("thgd,khd->thgk", qg, k)  # [tb, Hkv, G, ps]
    s = _softcap(s, softcap_val)

    length = len_ref[b]
    pos = p * page_size + jax.lax.iota(jnp.int32, page_size)
    q_pos = length - t_total + t * t_block + jax.lax.iota(jnp.int32, t_block)
    mask = (pos[None, :] <= q_pos[:, None]) \
        & ((q_pos[:, None] - pos[None, :]) < win_ref[0]) \
        & (ok_ref[b, p] > 0)
    s = jnp.where(mask[:, None, None, :], s, _NEG)

    m_prev, l_prev, o_prev = m_scr[...], l_scr[...], o_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    pr = jnp.exp(s - m_new[..., None])
    pr = jnp.where(mask[:, None, None, :], pr, 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + jnp.sum(pr, axis=-1)
    o_scr[...] = o_prev * corr[..., None] \
        + jnp.einsum("thgk,khd->thgd", pr, v)

    @pl.when(p == pl.num_programs(2) - 1)
    def _finalize():
        if partials:
            out_ref[0] = o_scr[...].reshape(t_block, n_heads, head_dim)
            m_ref[0] = m_scr[...].reshape(t_block, n_heads)
            l_ref[0] = l_scr[...].reshape(t_block, n_heads)
        else:
            o = o_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
            out_ref[0] = o.reshape(t_block, n_heads, head_dim)


def _paged_attention_mq(q, k_pages, v_pages, block_tables, lengths, window,
                        fmt_kv, softcap_val, interpret, page_ok, partials,
                        t_block):
    """4-D (multi-query) entry: q [B, T, Hq, Dh], grid (B, T//tb, M)."""
    B, T, Hq, Dh = q.shape
    n_pages, page_size, kvd = k_pages.shape
    Hkv = kvd // Dh
    if Hkv * Dh != kvd or Hq % Hkv:
        raise ValueError(f"page feature dim {kvd} incompatible with "
                         f"q heads {Hq} x head_dim {Dh}")
    M = block_tables.shape[1]
    if page_ok is None:
        page_ok = jnp.ones((B, M), jnp.int32)
    if t_block is None:
        t_block = next(tb for tb in (8, 4, 2, 1) if T % tb == 0)
    if T % t_block:
        raise ValueError(f"t_block={t_block} must divide T={T}")

    def _qmap(b, t, p, bt, ln, wn, ok):
        return (b, t, 0, 0)

    out_spec = pl.BlockSpec((1, t_block, Hq, Dh), _qmap)
    out_shape = jax.ShapeDtypeStruct((B, T, Hq, Dh), jnp.float32)
    if partials:
        ml_spec = pl.BlockSpec((1, t_block, Hq),
                               lambda b, t, p, bt, ln, wn, ok: (b, t, 0))
        ml_shape = jax.ShapeDtypeStruct((B, T, Hq), jnp.float32)
        out_specs = [out_spec, ml_spec, ml_spec]
        out_shapes = [out_shape, ml_shape, ml_shape]
    else:
        out_specs, out_shapes = out_spec, out_shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, T // t_block, M),
        in_specs=[
            out_spec,
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, t, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, t, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((t_block, Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((t_block, Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((t_block, Hkv, Hq // Hkv, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_attention_mq_kernel, fmt_kv=fmt_kv, page_size=page_size,
        t_total=T, t_block=t_block, n_heads=Hq, n_kv_heads=Hkv, head_dim=Dh,
        softcap_val=softcap_val, partials=partials)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      window.astype(jnp.int32), page_ok.astype(jnp.int32),
      q.astype(jnp.float32), k_pages, v_pages)


@functools.partial(
    jax.jit,
    static_argnames=("fmt_kv", "softcap_val", "interpret", "partials",
                     "t_block"),
)
def paged_attention(q, k_pages, v_pages, block_tables, lengths, window,
                    fmt_kv: PositFormat | None = None,
                    softcap_val: float = 0.0, interpret: bool = False,
                    page_ok=None, partials: bool = False,
                    t_block: int | None = None):
    """Single- or multi-token attention over block-table-paged posit KV.

    q            : [B, Hq, Dh] float query (one decode token per slot), or
                   [B, T, Hq, Dh] for the multi-query grid — one launch
                   covers T new tokens per slot (token i of slot b at
                   absolute position lengths[b] - T + i, causally masked
                   against both history and the other new tokens; T=1
                   matches the 3-D path exactly).  `t_block` tiles T
                   (autotuned; rows are independent so any tiling is
                   bitwise identical); the 3-D path ignores it.
    k/v_pages    : [n_pages, page_size, Hkv*Dh] posit codes (int8/int16,
                   decoded in-kernel via fmt_kv) or float (fmt_kv=None).
    block_tables : [B, max_pages] int32 — page j holds the slot's positions
                   [j*page_size, (j+1)*page_size); unallocated entries may
                   point anywhere (they are masked by `lengths`).
    lengths      : [B] int32 valid positions per slot *including* the
                   current token (written by the caller before this call).
    window       : [1] int32 sliding-window size (>= max_seq = unbounded).
    page_ok      : optional [B, max_pages] mask (nonzero = contribute).
                   On a kv_pages-sharded pool each shard passes its
                   ownership mask with block tables pre-localized, so the
                   kernel only attends over the pages it physically holds.
    partials     : return the unnormalized streaming-softmax state
                   `(o [B,Hq,Dh], m [B,Hq], l [B,Hq])` instead of the
                   normalized output — the per-shard contribution merged
                   across shards by `ops.merge_attn_partials` (exactly the
                   kernel's own finalize once merged, so a slot whose pages
                   live on one shard is bitwise identical to partials=False).

    Returns [B, Hq, Dh] f32 (or [B, T, Hq, Dh] for 4-D q), or the
    corresponding (o, m, l) triple when partials=True.
    """
    if v_pages.shape != k_pages.shape:
        raise ValueError(f"k/v page pools differ: {k_pages.shape} vs "
                         f"{v_pages.shape}")
    if q.ndim == 4:
        return _paged_attention_mq(q, k_pages, v_pages, block_tables,
                                   lengths, window, fmt_kv, softcap_val,
                                   interpret, page_ok, partials, t_block)
    B, Hq, Dh = q.shape
    n_pages, page_size, kvd = k_pages.shape
    Hkv = kvd // Dh
    if Hkv * Dh != kvd or Hq % Hkv:
        raise ValueError(f"page feature dim {kvd} incompatible with "
                         f"q heads {Hq} x head_dim {Dh}")
    M = block_tables.shape[1]
    if page_ok is None:
        page_ok = jnp.ones((B, M), jnp.int32)

    out_spec = pl.BlockSpec((1, Hq, Dh),
                            lambda b, p, bt, ln, wn, ok: (b, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, Hq, Dh), jnp.float32)
    if partials:
        ml_spec = pl.BlockSpec((1, Hq), lambda b, p, bt, ln, wn, ok: (b, 0))
        ml_shape = jax.ShapeDtypeStruct((B, Hq), jnp.float32)
        out_specs = [out_spec, ml_spec, ml_spec]
        out_shapes = [out_shape, ml_shape, ml_shape]
    else:
        out_specs, out_shapes = out_spec, out_shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, Hq, Dh), lambda b, p, bt, ln, wn, ok: (b, 0, 0)),
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, Hq // Hkv, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_attention_kernel, fmt_kv=fmt_kv, page_size=page_size,
        n_heads=Hq, n_kv_heads=Hkv, head_dim=Dh, softcap_val=softcap_val,
        partials=partials)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      window.astype(jnp.int32), page_ok.astype(jnp.int32),
      q.astype(jnp.float32), k_pages, v_pages)


# ---------------------------------------------------------------------------
# fused decode epilogue: logits-head posit GEMM + sampling in one program
# ---------------------------------------------------------------------------


def _decode_sample_kernel(x_ref, w_ref, *refs, plan: str,
                          fmt_w: PositFormat | None, transpose: bool,
                          greedy: bool, top_k: int, softcap_val: float,
                          v_block: int, n_vt: int, n_phase: int):
    if greedy:
        t_ref, tok_ref, *scr = refs
        noise_ref = None
    else:
        noise_ref, t_ref, tok_ref, *scr = refs
    if n_phase == 2:
        best_scr, idx_scr, kbuf_scr = scr
    else:
        best_scr, idx_scr = scr
        kbuf_scr = None
    ph = pl.program_id(0)
    t = pl.program_id(1)

    def _logits():
        # replay logits_head's qdot plan on this vocab tile, op-for-op
        w = w_ref[...]
        if transpose:
            w = w.T  # pure relayout: commutes with the elementwise decode
        if fmt_w is not None:
            wq = posit.decode(w.astype(jnp.int32) & fmt_w.mask, fmt_w)
        else:
            wq = w
        x = x_ref[...]
        if plan == "fused":
            # ops.matmul_posit_weights: f32 activations x exact f32 decode
            l = jnp.dot(x.astype(jnp.float32), wq,
                        preferred_element_type=jnp.float32)
        else:
            # fake_quant: unpack to x.dtype, dot in x.dtype, f32 output
            l = jnp.dot(x, wq.astype(x.dtype),
                        preferred_element_type=jnp.float32)
        return _softcap(l.astype(jnp.float32), softcap_val)

    def _scaled():
        return _logits() / jnp.maximum(t_ref[0], 1e-6)

    if n_phase == 2:
        # phase 0: stream the per-row top-k values into kbuf so the argmax
        # phase can read the exact k-th largest (== sort(l)[..., -top_k])
        @pl.when(ph == 0)
        def _topk():
            l = _scaled()

            @pl.when(t == 0)
            def _init_kbuf():
                kbuf_scr[...] = jnp.full_like(kbuf_scr, -jnp.inf)

            cand = jnp.concatenate([kbuf_scr[...], l], axis=1)
            cols = jax.lax.broadcasted_iota(jnp.int32, cand.shape, 1)
            tops = []
            for _ in range(top_k):
                mx = jnp.max(cand, axis=1)
                first = jnp.argmax(cand, axis=1).astype(jnp.int32)
                tops.append(mx)
                # retire one instance so repeated values keep multiset
                # semantics, exactly like a sort
                cand = jnp.where(cols == first[:, None], -jnp.inf, cand)
            kbuf_scr[...] = jnp.stack(tops, axis=1)

    @pl.when(ph == n_phase - 1)
    def _argmax():
        if greedy:
            y = _logits()  # greedy samples the raw (softcapped) logits
        else:
            l = _scaled()
            if kbuf_scr is not None:
                kth = kbuf_scr[...][:, top_k - 1]
                l = jnp.where(l >= kth[:, None], l, -1e30)
            # categorical(key, l) == argmax(gumbel_noise + l)
            y = noise_ref[...] + l

        @pl.when(t == 0)
        def _init_best():
            best_scr[...] = jnp.full_like(best_scr, -jnp.inf)
            idx_scr[...] = jnp.zeros_like(idx_scr)

        vmax = jnp.max(y, axis=1)
        vidx = jnp.argmax(y, axis=1).astype(jnp.int32)
        # strict > keeps the first-occurrence tie-breaking of a full argmax
        upd = vmax > best_scr[0]
        best_scr[0] = jnp.where(upd, vmax, best_scr[0])
        idx_scr[0] = jnp.where(upd, t * v_block + vidx, idx_scr[0])

        @pl.when(t == n_vt - 1)
        def _emit():
            tok_ref[0] = idx_scr[0]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "fmt_w", "transpose", "greedy", "top_k",
                     "softcap_val", "v_block", "interpret"),
)
def decode_sample(x, w, noise=None, temperature=None, *, plan: str = "fused",
                  fmt_w: PositFormat | None = None, transpose: bool = False,
                  greedy: bool = False, top_k: int = 0,
                  softcap_val: float = 0.0, v_block: int | None = None,
                  interpret: bool = False):
    """One-program decode epilogue: posit logits GEMM + sampling.

    Replays `common.logits_head` (the execution plan's head qdot plus the
    logit softcap) and the serving sampler (temperature / top-k /
    `jax.random.categorical`, or greedy argmax) in a single Pallas program,
    streaming the vocab dimension in `v_block` tiles so the [B, V] logits
    row never round-trips through HBM between head GEMM and sampler.

    x           : [B, D] final-norm'd hidden rows (one decode token/slot).
    w           : head weights — [D, V] (or [V, D] with transpose=True, the
                  tied-embedding layout); posit codes (integer container,
                  decoded in-kernel via fmt_w) or float (fmt_w=None).
    noise       : [B, V] f32 standard-gumbel noise, one row per slot — what
                  `jax.random.categorical` draws internally, so
                  argmax(noise + logits/T) replays it bitwise.  Ignored
                  (and may be None) when greedy.
    temperature : scalar f32 (ignored when greedy).
    plan        : "fused" (f32 activations x exact in-kernel decode,
                  matching ops.matmul_posit_weights) or "fake_quant"
                  (unpack to x.dtype, dot in x.dtype) — the two
                  dispatch.qdot decode-head plans, bit-for-bit.
    top_k       : 0 (or >= V) disables the top-k filter; otherwise a
                  streaming k-buffer phase reproduces `sort(l)[..., -k]`
                  exactly before the filtered gumbel argmax.
    v_block     : vocab tile width (must divide V); None = whole vocab in
                  one grid step.  Tiling the vocab axis only (rows stay
                  whole) keeps the f32 dot bitwise identical to the
                  untiled `logits_head` matmul.

    Returns [B] int32 sampled tokens, bit-identical to running
    `logits_head` and the engine sampler as separate device programs.
    """
    B, D = x.shape
    V = w.shape[0] if transpose else w.shape[1]
    vb = V if v_block is None else int(v_block)
    if V % vb:
        raise ValueError(f"v_block {vb} must divide vocab {V}")
    n_vt = V // vb
    topk_active = (not greedy) and 0 < top_k < V
    n_phase = 2 if topk_active else 1
    if temperature is None:
        temperature = jnp.float32(1.0)
    t_arr = jnp.reshape(temperature, (1,)).astype(jnp.float32)

    w_block = (vb, D) if transpose else (D, vb)
    w_map = (lambda ph, t: (t, 0)) if transpose else (lambda ph, t: (0, t))
    in_specs = [pl.BlockSpec((B, D), lambda ph, t: (0, 0)),
                pl.BlockSpec(w_block, w_map)]
    inputs = [x, w]
    if not greedy:
        if noise is None:
            raise ValueError("non-greedy decode_sample requires noise")
        in_specs.append(pl.BlockSpec((B, vb), lambda ph, t: (0, t)))
        inputs.append(noise.astype(jnp.float32))
    in_specs.append(pl.BlockSpec((1,), lambda ph, t: (0,)))
    inputs.append(t_arr)

    scratch = [pltpu.VMEM((1, B), jnp.float32),
               pltpu.VMEM((1, B), jnp.int32)]
    if topk_active:
        scratch.append(pltpu.VMEM((B, int(top_k)), jnp.float32))

    kernel = functools.partial(
        _decode_sample_kernel, plan=plan, fmt_w=fmt_w, transpose=transpose,
        greedy=greedy, top_k=int(top_k), softcap_val=softcap_val,
        v_block=vb, n_vt=n_vt, n_phase=n_phase)
    tok = pl.pallas_call(
        kernel,
        grid=(n_phase, n_vt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, B), lambda ph, t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.int32),
        scratch_shapes=scratch,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(*inputs)
    return tok[0]
