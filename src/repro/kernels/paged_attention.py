"""Pallas TPU kernel: paged-attention decode over posit-coded KV pages.

The serving KV cache is a pool of fixed-size pages `[n_pages, page_size,
Hkv*Dh]` stored at posit code width (int8/int16); each batch slot owns an
ordered list of page indices (its *block table*), so page j of a slot holds
the keys/values for absolute positions [j*page_size, (j+1)*page_size).

This kernel is the PDPU fused-decode idea applied to attention instead of
GEMM: per (slot, page) grid cell it

  * gathers the page by block table — `PrefetchScalarGridSpec` scalar-
    prefetches the block tables so the BlockSpec index_map DMAs exactly the
    pages the slot owns, HBM->VMEM at code width (the paged cache is never
    materialized densely, and never decoded in HBM),
  * decodes the posit codes to exact f32 on the VPU *inside* the kernel,
    right next to the q·k dot — one decode per element, total,
  * accumulates a streaming softmax (running max / normalizer / weighted
    value sum in f32 VMEM scratch) across the slot's pages — the wide-
    accumulator property held across the page dimension,
  * normalizes and writes the output once on the last page.

Masking: page p covers positions p*ps + [0, ps); entries at positions
>= lengths[b] are dead (beyond the slot's written prefix — freshly
allocated or reclaimed-page garbage) and are masked before the running max,
so page reclamation never leaks stale keys into a new request's attention.
A sliding window is applied as (q_pos - pos) < window with
q_pos = lengths[b] - 1 (the token written immediately before this call).

Shapes here follow the serving decode step (one query token per slot);
tiles are sized by the model's head layout rather than MXU tiles — on CPU
every call runs in interpret mode (like the other kernels in this package),
on TPU the (ps, Hkv*Dh) page is the natural VMEM block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import posit
from repro.core.formats import PositFormat

_NEG = -2.0e38

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def _paged_attention_kernel(bt_ref, len_ref, win_ref, ok_ref, q_ref, k_ref,
                            v_ref, *refs, fmt_kv: PositFormat | None,
                            page_size: int, n_heads: int, n_kv_heads: int,
                            head_dim: int, softcap_val: float, partials: bool):
    if partials:
        out_ref, m_ref, l_ref, m_scr, l_scr, o_scr = refs
    else:
        (out_ref, m_scr, l_scr, o_scr), m_ref, l_ref = refs, None, None
    b = pl.program_id(0)
    p = pl.program_id(1)
    G = n_heads // n_kv_heads

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        o_scr[...] = jnp.zeros_like(o_scr)

    # in-kernel decode: the page travels HBM->VMEM as posit codes and turns
    # into exact f32 only here, next to the dot (fmt_kv=None = float pages)
    if fmt_kv is None:
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
    else:
        k = posit.decode(k_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
        v = posit.decode(v_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
    k = k.reshape(page_size, n_kv_heads, head_dim)
    v = v.reshape(page_size, n_kv_heads, head_dim)

    scale = 1.0 / math.sqrt(head_dim)
    qg = q_ref[0].reshape(n_kv_heads, G, head_dim).astype(jnp.float32) * scale
    s = jnp.einsum("hgd,khd->hgk", qg, k)  # [Hkv, G, ps]
    s = _softcap(s, softcap_val)

    length = len_ref[b]
    pos = p * page_size + jax.lax.iota(jnp.int32, page_size)
    q_pos = length - 1  # the query token sits at the last written position
    mask = (pos < length) & ((q_pos - pos) < win_ref[0]) & (ok_ref[b, p] > 0)
    s = jnp.where(mask[None, None, :], s, _NEG)

    m_prev, l_prev, o_prev = m_scr[...], l_scr[...], o_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    pr = jnp.exp(s - m_new[..., None])
    pr = jnp.where(mask[None, None, :], pr, 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + jnp.sum(pr, axis=-1)
    o_scr[...] = o_prev * corr[..., None] + jnp.einsum("hgk,khd->hgd", pr, v)

    @pl.when(p == pl.num_programs(1) - 1)
    def _finalize():
        if partials:
            # leave the streaming state unnormalized: (o, m, l) per slot, to
            # be log-sum-exp merged across kv_pages shards (ops.
            # merge_attn_partials) before the single final normalization
            out_ref[0] = o_scr[...].reshape(n_heads, head_dim)
            m_ref[0] = m_scr[...].reshape(n_heads)
            l_ref[0] = l_scr[...].reshape(n_heads)
        else:
            o = o_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
            out_ref[0] = o.reshape(n_heads, head_dim)


def _paged_attention_mq_kernel(bt_ref, len_ref, win_ref, ok_ref, q_ref,
                               k_ref, v_ref, *refs,
                               fmt_kv: PositFormat | None, page_size: int,
                               t_total: int, t_block: int, n_heads: int,
                               n_kv_heads: int, head_dim: int,
                               softcap_val: float, partials: bool):
    """Multi-query grid: one launch covers T new tokens per slot.

    Query row i of slot b sits at absolute position lengths[b] - T + i
    (all T tokens already inserted); causality between the new tokens is
    enforced by the same position mask that guards written-prefix reads.
    Rows are independent, so any t_block tiling of T is bitwise identical
    — t_block is the autotuned launch parameter.
    """
    if partials:
        out_ref, m_ref, l_ref, m_scr, l_scr, o_scr = refs
    else:
        (out_ref, m_scr, l_scr, o_scr), m_ref, l_ref = refs, None, None
    b = pl.program_id(0)
    t = pl.program_id(1)
    p = pl.program_id(2)
    G = n_heads // n_kv_heads

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        o_scr[...] = jnp.zeros_like(o_scr)

    if fmt_kv is None:
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
    else:
        k = posit.decode(k_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
        v = posit.decode(v_ref[0].astype(jnp.int32) & fmt_kv.mask, fmt_kv)
    k = k.reshape(page_size, n_kv_heads, head_dim)
    v = v.reshape(page_size, n_kv_heads, head_dim)

    scale = 1.0 / math.sqrt(head_dim)
    qg = q_ref[0].reshape(t_block, n_kv_heads, G, head_dim) \
                 .astype(jnp.float32) * scale
    s = jnp.einsum("thgd,khd->thgk", qg, k)  # [tb, Hkv, G, ps]
    s = _softcap(s, softcap_val)

    length = len_ref[b]
    pos = p * page_size + jax.lax.iota(jnp.int32, page_size)
    q_pos = length - t_total + t * t_block + jax.lax.iota(jnp.int32, t_block)
    mask = (pos[None, :] <= q_pos[:, None]) \
        & ((q_pos[:, None] - pos[None, :]) < win_ref[0]) \
        & (ok_ref[b, p] > 0)
    s = jnp.where(mask[:, None, None, :], s, _NEG)

    m_prev, l_prev, o_prev = m_scr[...], l_scr[...], o_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    pr = jnp.exp(s - m_new[..., None])
    pr = jnp.where(mask[:, None, None, :], pr, 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + jnp.sum(pr, axis=-1)
    o_scr[...] = o_prev * corr[..., None] \
        + jnp.einsum("thgk,khd->thgd", pr, v)

    @pl.when(p == pl.num_programs(2) - 1)
    def _finalize():
        if partials:
            out_ref[0] = o_scr[...].reshape(t_block, n_heads, head_dim)
            m_ref[0] = m_scr[...].reshape(t_block, n_heads)
            l_ref[0] = l_scr[...].reshape(t_block, n_heads)
        else:
            o = o_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
            out_ref[0] = o.reshape(t_block, n_heads, head_dim)


def _paged_attention_mq(q, k_pages, v_pages, block_tables, lengths, window,
                        fmt_kv, softcap_val, interpret, page_ok, partials,
                        t_block):
    """4-D (multi-query) entry: q [B, T, Hq, Dh], grid (B, T//tb, M)."""
    B, T, Hq, Dh = q.shape
    n_pages, page_size, kvd = k_pages.shape
    Hkv = kvd // Dh
    if Hkv * Dh != kvd or Hq % Hkv:
        raise ValueError(f"page feature dim {kvd} incompatible with "
                         f"q heads {Hq} x head_dim {Dh}")
    M = block_tables.shape[1]
    if page_ok is None:
        page_ok = jnp.ones((B, M), jnp.int32)
    if t_block is None:
        t_block = next(tb for tb in (8, 4, 2, 1) if T % tb == 0)
    if T % t_block:
        raise ValueError(f"t_block={t_block} must divide T={T}")

    def _qmap(b, t, p, bt, ln, wn, ok):
        return (b, t, 0, 0)

    out_spec = pl.BlockSpec((1, t_block, Hq, Dh), _qmap)
    out_shape = jax.ShapeDtypeStruct((B, T, Hq, Dh), jnp.float32)
    if partials:
        ml_spec = pl.BlockSpec((1, t_block, Hq),
                               lambda b, t, p, bt, ln, wn, ok: (b, t, 0))
        ml_shape = jax.ShapeDtypeStruct((B, T, Hq), jnp.float32)
        out_specs = [out_spec, ml_spec, ml_spec]
        out_shapes = [out_shape, ml_shape, ml_shape]
    else:
        out_specs, out_shapes = out_spec, out_shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, T // t_block, M),
        in_specs=[
            out_spec,
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, t, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, t, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((t_block, Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((t_block, Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((t_block, Hkv, Hq // Hkv, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_attention_mq_kernel, fmt_kv=fmt_kv, page_size=page_size,
        t_total=T, t_block=t_block, n_heads=Hq, n_kv_heads=Hkv, head_dim=Dh,
        softcap_val=softcap_val, partials=partials)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      window.astype(jnp.int32), page_ok.astype(jnp.int32),
      q.astype(jnp.float32), k_pages, v_pages)


@functools.partial(
    jax.jit,
    static_argnames=("fmt_kv", "softcap_val", "interpret", "partials",
                     "t_block"),
)
def paged_attention(q, k_pages, v_pages, block_tables, lengths, window,
                    fmt_kv: PositFormat | None = None,
                    softcap_val: float = 0.0, interpret: bool = False,
                    page_ok=None, partials: bool = False,
                    t_block: int | None = None):
    """Single- or multi-token attention over block-table-paged posit KV.

    q            : [B, Hq, Dh] float query (one decode token per slot), or
                   [B, T, Hq, Dh] for the multi-query grid — one launch
                   covers T new tokens per slot (token i of slot b at
                   absolute position lengths[b] - T + i, causally masked
                   against both history and the other new tokens; T=1
                   matches the 3-D path exactly).  `t_block` tiles T
                   (autotuned; rows are independent so any tiling is
                   bitwise identical); the 3-D path ignores it.
    k/v_pages    : [n_pages, page_size, Hkv*Dh] posit codes (int8/int16,
                   decoded in-kernel via fmt_kv) or float (fmt_kv=None).
    block_tables : [B, max_pages] int32 — page j holds the slot's positions
                   [j*page_size, (j+1)*page_size); unallocated entries may
                   point anywhere (they are masked by `lengths`).
    lengths      : [B] int32 valid positions per slot *including* the
                   current token (written by the caller before this call).
    window       : [1] int32 sliding-window size (>= max_seq = unbounded).
    page_ok      : optional [B, max_pages] mask (nonzero = contribute).
                   On a kv_pages-sharded pool each shard passes its
                   ownership mask with block tables pre-localized, so the
                   kernel only attends over the pages it physically holds.
    partials     : return the unnormalized streaming-softmax state
                   `(o [B,Hq,Dh], m [B,Hq], l [B,Hq])` instead of the
                   normalized output — the per-shard contribution merged
                   across shards by `ops.merge_attn_partials` (exactly the
                   kernel's own finalize once merged, so a slot whose pages
                   live on one shard is bitwise identical to partials=False).

    Returns [B, Hq, Dh] f32 (or [B, T, Hq, Dh] for 4-D q), or the
    corresponding (o, m, l) triple when partials=True.
    """
    if v_pages.shape != k_pages.shape:
        raise ValueError(f"k/v page pools differ: {k_pages.shape} vs "
                         f"{v_pages.shape}")
    if q.ndim == 4:
        return _paged_attention_mq(q, k_pages, v_pages, block_tables,
                                   lengths, window, fmt_kv, softcap_val,
                                   interpret, page_ok, partials, t_block)
    B, Hq, Dh = q.shape
    n_pages, page_size, kvd = k_pages.shape
    Hkv = kvd // Dh
    if Hkv * Dh != kvd or Hq % Hkv:
        raise ValueError(f"page feature dim {kvd} incompatible with "
                         f"q heads {Hq} x head_dim {Dh}")
    M = block_tables.shape[1]
    if page_ok is None:
        page_ok = jnp.ones((B, M), jnp.int32)

    out_spec = pl.BlockSpec((1, Hq, Dh),
                            lambda b, p, bt, ln, wn, ok: (b, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, Hq, Dh), jnp.float32)
    if partials:
        ml_spec = pl.BlockSpec((1, Hq), lambda b, p, bt, ln, wn, ok: (b, 0))
        ml_shape = jax.ShapeDtypeStruct((B, Hq), jnp.float32)
        out_specs = [out_spec, ml_spec, ml_spec]
        out_shapes = [out_shape, ml_shape, ml_shape]
    else:
        out_specs, out_shapes = out_spec, out_shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, Hq, Dh), lambda b, p, bt, ln, wn, ok: (b, 0, 0)),
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, page_size, kvd),
                         lambda b, p, bt, ln, wn, ok: (bt[b, p], 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, Hq // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, Hq // Hkv, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_attention_kernel, fmt_kv=fmt_kv, page_size=page_size,
        n_heads=Hq, n_kv_heads=Hkv, head_dim=Dh, softcap_val=softcap_val,
        partials=partials)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      window.astype(jnp.int32), page_ok.astype(jnp.int32),
      q.astype(jnp.float32), k_pages, v_pages)
