"""Pallas TPU kernel: fused posit matmul — the PDPU's TPU-native form.

The paper's fused architecture does per dot product: decode all inputs once,
accumulate in one wide aligned register, encode the result once.  The
TPU-native realization tiles a GEMM over (M/bm, N/bn, K/bk):

  * decode: posit tiles (int16/int8 in HBM -> VMEM) are decoded to exact
    f32 *inside* the kernel (VPU bit ops) — never materialized in HBM.
    2 decodes per input element, total; no discrete-unit re-decoding.
  * accumulate: the MXU matmul accumulates in an f32 VMEM scratch across
    the K grid dimension — the W_m-wide aligned accumulator analogue.
  * encode: on the last K step the f32 tile is rounded *once* into the
    output posit format — the single-rounding fused property.

Compared with the discrete alternative (decode kernel -> HBM f32 tensor ->
matmul -> encode kernel), this removes 4 bytes/elem of HBM round-trip per
input and 2 roundings per output, which is exactly the paper's
"remove redundant decode/encode + intermediate rounding" claim mapped onto
the TPU memory hierarchy.  The Pallas grid software-pipelines the HBM->VMEM
DMAs of block k+1 against MXU compute of block k — the 6-stage pipeline's
role (§IV-B) played by double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import posit
from repro.core.formats import PositFormat

# MXU-aligned tile defaults (128x128 systolic array; K tiled for VMEM).
_BM, _BN, _BK = 256, 256, 512

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _fused_matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *,
                         fmt_a: PositFormat, fmt_b: PositFormat,
                         fmt_out, n_k: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # S1 (decode) on the VPU — exact f32 values of the posit codes
    a = posit.decode(a_ref[...].astype(jnp.int32) & fmt_a.mask, fmt_a)
    b = posit.decode(b_ref[...].astype(jnp.int32) & fmt_b.mask, fmt_b)
    # S2-S4 (multiply + wide accumulate) on the MXU
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    # S5-S6 (normalize + single rounding/encode) on the final K step
    @pl.when(pl.program_id(2) == n_k - 1)
    def _finalize():
        acc = acc_ref[...]
        if fmt_out is None:
            out_ref[...] = acc.astype(out_dtype)
        else:
            out_ref[...] = posit.encode(acc, fmt_out).astype(out_dtype)


def _grouped_matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *,
                           fmt_a, fmt_b: PositFormat,
                           fmt_out, n_k: int, out_dtype):
    """One (expert, m, n, k) grid cell of the grouped GEMM.

    Identical datapath to `_fused_matmul_kernel`; the leading block dim of
    every ref is the expert (always block size 1).  fmt_a=None means the
    activations arrive as plain f32 (the serving fast path — encoding float
    activations would add a rounding) and skip the in-kernel decode.
    """
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if fmt_a is None:
        a = a_ref[0].astype(jnp.float32)
    else:
        a = posit.decode(a_ref[0].astype(jnp.int32) & fmt_a.mask, fmt_a)
    b = posit.decode(b_ref[0].astype(jnp.int32) & fmt_b.mask, fmt_b)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _finalize():
        acc = acc_ref[...]
        if fmt_out is None:
            out_ref[0] = acc.astype(out_dtype)
        else:
            out_ref[0] = posit.encode(acc, fmt_out).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("fmt_a", "fmt_b", "fmt_out", "bm", "bn", "bk", "interpret"),
)
def posit_matmul_grouped(a, b_codes, fmt_a: PositFormat | None,
                         fmt_b: PositFormat, fmt_out: PositFormat | None = None,
                         bm=None, bn=None, bk=None, interpret=False):
    """Grouped fused GEMM: [E,M,K] x [E,K,N] -> [E,M,N], one expert per
    leading grid dimension.

    The MoE expert-stack shape: E stacked weight matrices, each multiplied
    by its own activation slab.  Each expert reuses the 2-D kernel's tiling
    (bm, bn, bk) with a per-expert f32 VMEM scratch accumulator and a single
    encode on the last K step — the PDPU fused property held per expert.

    fmt_a=None takes `a` as float activations (no decode — the serving fast
    path, where weights are stored as posit codes and decode in-kernel but
    activations stay float); otherwise `a` holds fmt_a posit codes.
    M/N/K pad to tile multiples internally (posit code 0 and f32 0.0 are
    both exact zeros, so padding never perturbs the accumulation).
    """
    bm = _BM if bm is None else bm
    bn = _BN if bn is None else bn
    bk = _BK if bk is None else bk
    E, M, K = a.shape
    Eb, K2, N = b_codes.shape
    if E != Eb or K != K2:
        raise ValueError(f"grouped mismatch {a.shape} x {b_codes.shape}")
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)

    def pad(x, m0, m1):
        p0 = (-x.shape[1]) % m0
        p1 = (-x.shape[2]) % m1
        if p0 or p1:
            x = jnp.pad(x, ((0, 0), (0, p0), (0, p1)))
        return x

    a_p = pad(a, bm_, bk_)
    b_p = pad(b_codes, bk_, bn_)
    _, Mp, Kp = a_p.shape
    _, _, Np = b_p.shape
    n_k = Kp // bk_

    if fmt_out is None:
        out_dtype = jnp.float32
    else:
        out_dtype = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[fmt_out.storage_bits]

    out = pl.pallas_call(
        functools.partial(
            _grouped_matmul_kernel, fmt_a=fmt_a, fmt_b=fmt_b,
            fmt_out=fmt_out, n_k=n_k, out_dtype=out_dtype,
        ),
        grid=(E, Mp // bm_, Np // bn_, n_k),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk_, bn_), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p)
    return out[:, :M, :N]


@functools.partial(
    jax.jit,
    static_argnames=("fmt_a", "fmt_b", "fmt_out", "bm", "bn", "bk", "interpret"),
)
def posit_matmul(a_codes, b_codes, fmt_a: PositFormat, fmt_b: PositFormat,
                 fmt_out: PositFormat | None = None,
                 bm=None, bn=None, bk=None, interpret=False):
    """[M,K] posit codes x [K,N] posit codes -> [M,N].

    fmt_out=None returns f32 (the mixed-precision "higher-precision output"
    path feeding a wider consumer); otherwise returns fmt_out posit codes in
    their storage dtype.  M/N/K are padded to tile multiples internally —
    posit code 0 decodes to 0.0, so zero padding is exact.
    """
    bm = _BM if bm is None else bm
    bn = _BN if bn is None else bn
    bk = _BK if bk is None else bk
    M, K = a_codes.shape
    K2, N = b_codes.shape
    if K != K2:
        raise ValueError(f"contraction mismatch {a_codes.shape} x {b_codes.shape}")
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)

    def pad(x, m0, m1):
        p0 = (-x.shape[0]) % m0
        p1 = (-x.shape[1]) % m1
        if p0 or p1:
            x = jnp.pad(x, ((0, p0), (0, p1)))
        return x

    a_p = pad(a_codes, bm_, bk_)
    b_p = pad(b_codes, bk_, bn_)
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    n_k = Kp // bk_

    if fmt_out is None:
        out_dtype = jnp.float32
    else:
        out_dtype = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[fmt_out.storage_bits]

    out = pl.pallas_call(
        functools.partial(
            _fused_matmul_kernel, fmt_a=fmt_a, fmt_b=fmt_b,
            fmt_out=fmt_out, n_k=n_k, out_dtype=out_dtype,
        ),
        grid=(Mp // bm_, Np // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
