"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert bit-identity (codec,
pdpu_dot) or allclose (fused matmul) against these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core import pdpu as pdpu_core
from repro.core.formats import PDPUConfig, PositFormat


def decode_ref(codes, fmt: PositFormat, dtype=jnp.float32):
    """posit codes -> float values."""
    return posit.decode(codes.astype(jnp.int32) & fmt.mask, fmt, dtype=dtype)


def encode_ref(values, fmt: PositFormat):
    """float values -> posit codes in the storage container dtype."""
    return posit.pack(values, fmt)


def posit_matmul_ref(a_codes, b_codes, fmt_a: PositFormat, fmt_b: PositFormat,
                     fmt_out: PositFormat | None = None, bk: int | None = None):
    """Fused posit matmul semantics: decode once (exact), accumulate wide
    (f32), encode once.  out = encode(decode(A) @ decode(B)) — exactly one
    rounding per output element, the paper's fused property.

    ``bk`` replays the kernel's K-block accumulation order so comparisons
    are bit-identical (f32 addition is order-sensitive)."""
    a = decode_ref(a_codes, fmt_a)
    b = decode_ref(b_codes, fmt_b)
    if bk is None or bk >= a.shape[-1]:
        out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    else:
        K = a.shape[-1]
        out = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
        for k0 in range(0, K, bk):
            out = out + jnp.dot(a[:, k0:k0 + bk], b[k0:k0 + bk, :],
                                preferred_element_type=jnp.float32)
    if fmt_out is None:
        return out
    return posit.pack(out, fmt_out)


def pdpu_matmul_ref(a_codes, b_codes, cfg: PDPUConfig):
    """Bit-exact chunked-PDPU GEMM (hardware-faithful W_m datapath)."""
    return pdpu_core.pdpu_matmul_exact(
        a_codes.astype(jnp.int32) & cfg.fmt_in.mask,
        b_codes.astype(jnp.int32) & cfg.fmt_in.mask,
        cfg,
    )


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, window,
                        fmt_kv: PositFormat | None = None,
                        softcap_val: float = 0.0):
    """Paged-attention decode semantics, densely: gather each slot's pages
    by block table, decode the posit codes, plain masked softmax.

    Same contract as `paged_attention.paged_attention` — q [B, Hq, Dh],
    pages [n_pages, ps, Hkv*Dh], block_tables [B, M], lengths [B] valid
    counts including the current token, window [1].  Returns [B, Hq, Dh]
    f32."""
    B, Hq, Dh = q.shape
    _, ps, kvd = k_pages.shape
    Hkv = kvd // Dh
    G = Hq // Hkv
    M = block_tables.shape[1]
    S = M * ps
    kg = k_pages[block_tables].reshape(B, S, Hkv, Dh)
    vg = v_pages[block_tables].reshape(B, S, Hkv, Dh)
    if fmt_kv is not None:
        kg = decode_ref(kg, fmt_kv)
        vg = decode_ref(vg, fmt_kv)
    scale = 1.0 / (Dh ** 0.5)
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kg.astype(jnp.float32))
    if softcap_val > 0:
        s = softcap_val * jnp.tanh(s / softcap_val)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]          # [1, S]
    q_pos = (lengths - 1)[:, None]
    mask = (pos < lengths[:, None]) & ((q_pos - pos) < window[0])
    s = jnp.where(mask[:, None, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vg.astype(jnp.float32))
    return o.reshape(B, Hq, Dh)


def paged_attention_mq_ref(q, k_pages, v_pages, block_tables, lengths,
                           window, fmt_kv: PositFormat | None = None,
                           softcap_val: float = 0.0):
    """Multi-query paged-attention semantics, densely: q [B, T, Hq, Dh],
    token i of slot b at absolute position lengths[b] - T + i (lengths
    count all T new tokens as written), masked softmax per token over the
    slot's gathered pages.  Returns [B, T, Hq, Dh] f32."""
    B, T, Hq, Dh = q.shape
    _, ps, kvd = k_pages.shape
    Hkv = kvd // Dh
    G = Hq // Hkv
    M = block_tables.shape[1]
    S = M * ps
    kg = k_pages[block_tables].reshape(B, S, Hkv, Dh)
    vg = v_pages[block_tables].reshape(B, S, Hkv, Dh)
    if fmt_kv is not None:
        kg = decode_ref(kg, fmt_kv)
        vg = decode_ref(vg, fmt_kv)
    scale = 1.0 / (Dh ** 0.5)
    qg = q.reshape(B, T, Hkv, G, Dh).astype(jnp.float32) * scale
    s = jnp.einsum("bthgd,bkhd->bthgk", qg, kg.astype(jnp.float32))
    if softcap_val > 0:
        s = softcap_val * jnp.tanh(s / softcap_val)
    pos = jnp.arange(S, dtype=jnp.int32)[None, None, :]        # [1, 1, S]
    q_pos = (lengths[:, None] - T + jnp.arange(T)[None, :])[..., None]
    mask = (pos <= q_pos) & ((q_pos - pos) < window[0])        # [B, T, S]
    s = jnp.where(mask[:, :, None, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bthgk,bkhd->bthgd", p, vg.astype(jnp.float32))
    return o.reshape(B, T, Hq, Dh)
