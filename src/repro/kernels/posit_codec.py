"""Pallas TPU kernels: elementwise posit decode / encode.

These are the S1/S6 stages of the paper as TPU VPU bit-ops over VMEM tiles.
On a real accelerator they run fused into consumers; standalone they serve
(a) the decode-at-load path for posit-stored weights/KV-cache and (b) the
encode-at-store path for posit outputs/checkpoint shards.

Tiling: 2-D grid over (rows/block_r, cols/block_c).  Codes are stored in
int16 (or int8 for n <= 8) — half/quarter the HBM traffic of f32, which is
the memory-roofline win the paper's mixed-precision strategy buys on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import posit
from repro.core.formats import PositFormat

# (sublane, lane)-aligned defaults; int16 native tiling on TPU is (16, 128).
_BLOCK_R = 256
_BLOCK_C = 512


def _decode_kernel(code_ref, out_ref, *, fmt: PositFormat):
    codes = code_ref[...].astype(jnp.int32) & fmt.mask
    out_ref[...] = posit.decode(codes, fmt)


def _encode_kernel(x_ref, out_ref, *, fmt: PositFormat, out_dtype):
    x = x_ref[...]
    out_ref[...] = posit.encode(x, fmt).astype(out_dtype)


def _grid_2d(shape, block_r, block_c):
    r = pl.cdiv(shape[0], block_r)
    c = pl.cdiv(shape[1], block_c)
    return (r, c)


def _as_2d(x):
    """Collapse leading dims; pad is handled by pallas masking semantics
    (block tails are garbage-in/garbage-out and sliced away by pallas)."""
    if x.ndim == 1:
        return x.reshape(1, -1), x.shape
    if x.ndim == 2:
        return x, x.shape
    return x.reshape(-1, x.shape[-1]), x.shape


@functools.partial(jax.jit, static_argnames=("fmt", "block_r", "block_c", "interpret"))
def decode(codes, fmt: PositFormat, block_r=None, block_c=None,
           interpret=False):
    """posit codes (int8/int16/int32, any shape) -> float32 values.

    block_r/block_c default to the module constants; ops.decode resolves
    them through the autotune cache per (shape bucket, fmt, backend)."""
    block_r = _BLOCK_R if block_r is None else block_r
    block_c = _BLOCK_C if block_c is None else block_c
    x2, orig_shape = _as_2d(codes)
    R, C = x2.shape
    br, bc = min(block_r, R), min(block_c, C)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, fmt=fmt),
        grid=_grid_2d(x2.shape, br, bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("fmt", "block_r", "block_c", "interpret"))
def encode(values, fmt: PositFormat, block_r=None, block_c=None,
           interpret=False):
    """float values (any shape) -> posit codes in the storage dtype.

    block_r/block_c default to the module constants; ops.encode resolves
    them through the autotune cache per (shape bucket, fmt, backend)."""
    block_r = _BLOCK_R if block_r is None else block_r
    block_c = _BLOCK_C if block_c is None else block_c
    out_dtype = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[fmt.storage_bits]
    x2, orig_shape = _as_2d(values.astype(jnp.float32))
    R, C = x2.shape
    br, bc = min(block_r, R), min(block_c, C)
    out = pl.pallas_call(
        functools.partial(_encode_kernel, fmt=fmt, out_dtype=out_dtype),
        grid=_grid_2d(x2.shape, br, bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)
