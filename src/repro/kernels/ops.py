"""Public jit'd wrappers around the Pallas kernels.

Central switch: on non-TPU backends every kernel runs in interpret mode
(Pallas executes the kernel body with jnp on CPU), so the whole framework —
models, tests, benchmarks — exercises the identical kernel code paths that
compile to Mosaic on a real TPU.

Two layers of entry points:

  * raw kernels (`fused_matmul`, `matmul_posit_weights`, the grouped
    variants, `pdpu_matmul`): operate on posit *codes*; forward-only —
    Pallas calls have no autodiff rules and integer codes carry no tangents.
  * STE entry points (`fused_matmul_ste`, `fused_matmul_grouped_ste` and
    the `matmul_posit_weights*_ste` aliases): operate on *float masters*,
    run the identical raw kernel forward (encode -> in-kernel decode GEMM)
    and attach a `jax.custom_vjp` straight-through backward, so `jax.grad`
    flows through the real fused datapath.  This is what lets QAT train on
    the packed-kernel forward instead of the fake_quant stand-in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import PDPUConfig, PositFormat
from . import autotune
from . import posit_codec, posit_matmul, pdpu_dot
from . import paged_attention as paged_attention_mod
from . import prefill_attention as prefill_attention_mod
from . import ref  # noqa: F401  (re-exported for tests/benchmarks)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(kernel: str, shape, fmts, kw: dict, names) -> dict:
    """Dispatch-time autotune resolution: fill launch params the caller did
    not pass explicitly from the tuned cache (kernels/autotune.py).  Shapes
    are static at trace time, so this is pure host-side lookup; a cache
    miss leaves the params absent and the kernel's module constants apply
    (the no-cache fallback)."""
    missing = [n for n in names if kw.get(n) is None]
    if not missing:
        return kw
    tuned = autotune.lookup(kernel, shape, fmts)
    if tuned:
        for n in missing:
            if tuned.get(n) is not None:
                kw[n] = tuned[n]
    return kw


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>= 1)."""
    for d in range(min(int(cap), int(n)), 0, -1):
        if n % d == 0:
            return d
    return 1


# Smallest tile a cached launch param may degrade to before the resolver
# gives up on it.  A cached tile that doesn't divide the live dim degrades
# to the largest divisor below it (any tiling is value-neutral), but for a
# prime live dim that collapses to 1 — a one-element-per-program launch
# grid that is strictly worse than the kernel's own untuned default.
_TILE_FLOOR = 2


def _degrade_tile(n: int, cap: int | None) -> int | None:
    """Resolve a cached tile `cap` against live dim `n`.

    Returns a divisor of n to launch with, or None to mean "drop the cached
    value and let the kernel's untuned default apply".  The cached tile is
    kept when it divides n; otherwise it degrades to the largest divisor of
    n below it, unless that divisor falls under `_TILE_FLOOR` (while n
    itself is larger) — the pathological prime-dim collapse."""
    if cap is None:
        return None
    d = _largest_divisor(n, cap)
    if d < min(_TILE_FLOOR, int(n)):
        return None
    return d


def _flat2d(shape):
    """The codec kernels collapse leading dims: lookup on the (R, C) the
    kernel actually launches."""
    if len(shape) < 2:
        return (1, int(shape[0]) if shape else 1)
    r = 1
    for d in shape[:-1]:
        r *= int(d)
    return (r, int(shape[-1]))


def decode(codes, fmt: PositFormat, **kw):
    """posit codes -> f32 (Pallas elementwise kernel)."""
    kw = _resolve("posit_codec.decode", _flat2d(codes.shape), (fmt,), kw,
                  ("block_r", "block_c"))
    return posit_codec.decode(codes, fmt, interpret=_interpret(), **kw)


def encode(values, fmt: PositFormat, **kw):
    """float -> posit codes in storage dtype (Pallas elementwise kernel)."""
    kw = _resolve("posit_codec.encode", _flat2d(values.shape), (fmt,), kw,
                  ("block_r", "block_c"))
    return posit_codec.encode(values, fmt, interpret=_interpret(), **kw)


def fused_matmul(a_codes, b_codes, fmt_a: PositFormat, fmt_b: PositFormat,
                 fmt_out: PositFormat | None = None, **kw):
    """Fused posit GEMM: in-kernel decode -> MXU f32 -> single encode."""
    kw = _resolve("posit_matmul",
                  (a_codes.shape[0], a_codes.shape[1], b_codes.shape[1]),
                  (fmt_a, fmt_b), kw, ("bm", "bn", "bk"))
    return posit_matmul.posit_matmul(
        a_codes, b_codes, fmt_a, fmt_b, fmt_out,
        interpret=_interpret(), **kw)


def fused_matmul_grouped(a_codes, b_codes, fmt_a: PositFormat,
                         fmt_b: PositFormat,
                         fmt_out: PositFormat | None = None, **kw):
    """Grouped fused posit GEMM: [E,M,K] x [E,K,N] codes -> [E,M,N].

    One expert per leading grid dimension; per-expert in-kernel decode,
    f32 MXU accumulate, single encode (fmt_out=None returns f32)."""
    kw = _resolve("posit_matmul_grouped",
                  (a_codes.shape[0], a_codes.shape[1], a_codes.shape[2],
                   b_codes.shape[2]),
                  (fmt_a, fmt_b), kw, ("bm", "bn", "bk"))
    return posit_matmul.posit_matmul_grouped(
        a_codes, b_codes, fmt_a, fmt_b, fmt_out,
        interpret=_interpret(), **kw)


def matmul_posit_weights_grouped(x, w_codes, fmt_w: PositFormat, **kw):
    """Float activations x stacked posit weights — grouped serving fast path.

    x: [E, M, K] float; w_codes: [E, K, N] posit codes.  Activations stay
    float (an encode would add a rounding); the expert weight stacks travel
    HBM->VMEM as int8/int16 codes and decode on the VPU inside the grouped
    kernel.  Returns f32.
    """
    kw = _resolve("posit_matmul_grouped",
                  (x.shape[0], x.shape[1], x.shape[2], w_codes.shape[2]),
                  (None, fmt_w), kw, ("bm", "bn", "bk"))
    return posit_matmul.posit_matmul_grouped(
        x.astype(jnp.float32), w_codes, None, fmt_w, None,
        interpret=_interpret(), **kw)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, window,
                    fmt_kv: PositFormat | None = None,
                    softcap_val: float = 0.0, page_ok=None,
                    partials: bool = False, t_block: int | None = None):
    """Paged-attention decode: gather KV pages by block table, decode posit
    codes in-kernel next to the q·k dot, streaming softmax across pages.
    See kernels/paged_attention.py; forward-only (decode hot path).

    q may be [B, Hq, Dh] (one token per slot) or [B, T, Hq, Dh] — the
    multi-query grid covering T new tokens per slot in one launch, with
    the query tile `t_block` resolved through the autotune cache when not
    given (any tiling is bitwise identical; T=1 takes the 3-D path).

    page_ok masks pages out of the streaming softmax (a kv_pages shard
    passes its ownership mask); partials=True returns the unnormalized
    (o, m, l) state for cross-shard merging via `merge_attn_partials`."""
    if q.ndim == 4 and t_block is None:
        kw = _resolve(
            "paged_attention",
            (q.shape[0], q.shape[1], block_tables.shape[1],
             k_pages.shape[1], k_pages.shape[2]),
            (fmt_kv,), {}, ("t_block",))
        # a cached t_block that doesn't divide this launch's T degrades to
        # the largest divisor of T below it (any tiling is value-neutral);
        # if that collapses below _TILE_FLOOR (prime T), the cached value is
        # dropped and the kernel's untuned default applies instead
        t_block = _degrade_tile(q.shape[1], kw.get("t_block"))
    return paged_attention_mod.paged_attention(
        q, k_pages, v_pages, block_tables, lengths, window,
        fmt_kv=fmt_kv, softcap_val=softcap_val, interpret=_interpret(),
        page_ok=page_ok, partials=partials, t_block=t_block)


def prefill_attention_paged(q, k, v, k_pages, v_pages, block_tables, starts,
                            window, fmt_kv: PositFormat | None = None,
                            compute_dtype=jnp.float32,
                            softcap_val: float = 0.0, flash_chunk: int = 1024,
                            hist_pool_k=None, hist_pool_v=None, hist_bt=None,
                            page_ok=None, **kw):
    """Fused prefill: chunk attention + posit KV encode + page insert in a
    single device program (kernels/prefill_attention.py) — bit-identical
    to the decomposed flash_attention -> kv_encode -> insert_chunk path
    for any span admitted by `paged.fused_prefill_span_ok` (history beyond
    one flash chunk streams through the kernel's running flash softmax).

    Sharded pools pass the all-gathered global pool (hist_pool_k/v), the
    global block tables as hist_bt, the localized block tables, and their
    ownership mask as page_ok."""
    kw = _resolve("prefill_attention",
                  (q.shape[0], q.shape[1], block_tables.shape[1],
                   k_pages.shape[1], k_pages.shape[2]),
                  (fmt_kv,), kw, ("dimension_semantics", "vmem_limit_mb"))
    return prefill_attention_mod.prefill_attention_paged(
        q, k, v, k_pages, v_pages, block_tables, starts, window,
        fmt_kv=fmt_kv, compute_dtype=compute_dtype, softcap_val=softcap_val,
        flash_chunk=flash_chunk, interpret=_interpret(),
        hist_pool_k=hist_pool_k, hist_pool_v=hist_pool_v, hist_bt=hist_bt,
        page_ok=page_ok, **kw)


def decode_sample(x, w, noise=None, temperature=None, *, plan: str = "fused",
                  fmt_w: PositFormat | None = None, transpose: bool = False,
                  greedy: bool = False, top_k: int = 0,
                  softcap_val: float = 0.0, v_block: int | None = None):
    """One-program decode epilogue: logits-head GEMM + sampling fused.

    Streams the head weights through the sampler in vocab tiles
    (kernels/paged_attention.py:decode_sample) so a decode step's logits
    never round-trip through HBM — bit-identical to running `logits_head`
    and the engine sampler as separate device programs.  `v_block` resolves
    through the autotune cache (0 = whole vocab / collapsed grid); a cached
    tile that doesn't divide this vocab degrades to the largest divisor
    below it, like `paged_attention`'s t_block, and is dropped in favour of
    the untuned whole-vocab default when that collapses below the tile
    floor (prime vocab)."""
    V = w.shape[0] if transpose else w.shape[1]
    if v_block is None:
        kw = _resolve("decode_sample", (x.shape[0], x.shape[1], V),
                      (fmt_w,), {}, ("v_block",))
        vb = kw.get("v_block")
        if vb is not None:
            v_block = V if vb == 0 else _degrade_tile(V, vb)
    return paged_attention_mod.decode_sample(
        x, w, noise, temperature, plan=plan, fmt_w=fmt_w,
        transpose=transpose, greedy=greedy, top_k=top_k,
        softcap_val=softcap_val, v_block=v_block, interpret=_interpret())


def merge_attn_partials(o, m, l, axis_name: str):
    """Log-sum-exp merge of per-shard paged-attention partials.

    Each kv_pages shard runs `paged_attention(..., partials=True)` over the
    pages it owns, producing unnormalized output `o` [B,Hq,Dh], running max
    `m` [B,Hq] and normalizer `l` [B,Hq].  Inside the serving shard_map this
    rescales every shard's state to the global max and psums — algebraically
    the kernel's own finalize, so when all of a slot's pages live on one
    shard the result is bitwise identical to the unsharded kernel (the other
    shards contribute w*l = 0).  Must run inside a shard_map binding
    `axis_name`."""
    m_max = jax.lax.pmax(m, axis_name)
    w = jnp.exp(m - m_max)
    l_tot = jax.lax.psum(l * w, axis_name)
    o_tot = jax.lax.psum(o * w[..., None], axis_name)
    return o_tot / jnp.maximum(l_tot, 1e-30)[..., None]


def pdpu_matmul(a_codes, b_codes, cfg: PDPUConfig, **kw):
    """Bit-exact chunked-PDPU GEMM (hardware-faithful W_m datapath)."""
    return pdpu_dot.pdpu_matmul(a_codes, b_codes, cfg,
                                interpret=_interpret(), **kw)


def matmul_posit_weights(x, w_codes, fmt_w: PositFormat, **kw):
    """float activations x posit-stored weights — the serving fast path.

    Activations stay float (encoding them would add a rounding); the posit
    weights decode exactly in-kernel and the dot accumulates f32.  Returns
    f32.  (Used by the dispatch layer for posit-weight checkpoints when
    QuantPolicy.activations is None.)
    """
    a = x.astype(jnp.float32)
    w = posit_codec.decode(w_codes, fmt_w, interpret=_interpret(), **kw)
    return jnp.dot(a, w, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# differentiable (STE) entry points over float masters
# ---------------------------------------------------------------------------
#
# Forward runs the real fused datapath: the float masters are encoded to
# posit codes and the Pallas kernel decodes them in-kernel, accumulates f32
# on the MXU and returns f32 — exactly what serving executes.  Backward is
# straight-through w.r.t. the float operands, using the *quantized* operand
# values (the same values the kernel computed on), which is bit-for-bit the
# gradient the fake_quant STE plan produces.  Residuals are kept minimal:
# the posit codes of each quantized operand, saved once (int8/int16/int32 —
# narrower than an f32 copy), decoded exactly in the backward pass; a
# float-activation operand (fmt_a=None) is saved as-is.
#
# All STE entry points take and return float32 — the dispatch layer casts;
# custom_vjp then only ever has to produce f32 cotangents.


def _ste_primal(x, w, fmt_a, fmt_w):
    """Shared fwd: encode masters, run the raw fused kernel, return the
    f32 product plus the minimal residuals for the STE backward."""
    w_codes = encode(w, fmt_w)
    if fmt_a is None:  # float activations: the serving fast path
        return matmul_posit_weights(x, w_codes, fmt_w), (x, w_codes)
    a_codes = encode(x, fmt_a)
    out = fused_matmul(a_codes, w_codes, fmt_a, fmt_w, fmt_out=None)
    return out, (a_codes, w_codes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fused_matmul_ste(x, w, fmt_a: PositFormat | None, fmt_w: PositFormat):
    """Differentiable fused GEMM over float masters: x [M,K] @ w [K,N].

    fmt_a=None keeps activations float (the `matmul_posit_weights` fast
    path); otherwise both operands travel as codes through `fused_matmul`.
    Backward: dx = g @ wq^T, dw = xq^T @ g with xq/wq the decoded quantized
    operands — the straight-through gradients of the fake_quant plan.
    """
    return _ste_primal(x, w, fmt_a, fmt_w)[0]


def _fused_ste_fwd(x, w, fmt_a, fmt_w):
    return _ste_primal(x, w, fmt_a, fmt_w)


def _fused_ste_bwd(fmt_a, fmt_w, res, g):
    a_res, w_codes = res
    aq = a_res if fmt_a is None else decode(a_res, fmt_a)
    wq = decode(w_codes, fmt_w)
    g = g.astype(jnp.float32)
    dx = jnp.dot(g, wq.T, preferred_element_type=jnp.float32)
    dw = jnp.dot(aq.astype(jnp.float32).T, g,
                 preferred_element_type=jnp.float32)
    return dx, dw


fused_matmul_ste.defvjp(_fused_ste_fwd, _fused_ste_bwd)


def matmul_posit_weights_ste(x, w, fmt_w: PositFormat):
    """Differentiable serving fast path: float activations, posit weights
    encoded from float masters in the forward, STE weight gradients."""
    return fused_matmul_ste(x, w, None, fmt_w)


def _ste_grouped_primal(x, w, fmt_a, fmt_w):
    w_codes = encode(w, fmt_w)
    if fmt_a is None:
        return matmul_posit_weights_grouped(x, w_codes, fmt_w), (x, w_codes)
    a_codes = encode(x, fmt_a)
    out = fused_matmul_grouped(a_codes, w_codes, fmt_a, fmt_w, fmt_out=None)
    return out, (a_codes, w_codes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fused_matmul_grouped_ste(x, w, fmt_a: PositFormat | None,
                             fmt_w: PositFormat):
    """Differentiable grouped fused GEMM over float masters:
    x [E,C,K] @ w [E,K,N] -> [E,C,N], same STE semantics as
    `fused_matmul_ste` applied per expert (one batched backward einsum)."""
    return _ste_grouped_primal(x, w, fmt_a, fmt_w)[0]


def _grouped_ste_fwd(x, w, fmt_a, fmt_w):
    return _ste_grouped_primal(x, w, fmt_a, fmt_w)


def _grouped_ste_bwd(fmt_a, fmt_w, res, g):
    a_res, w_codes = res
    aq = a_res if fmt_a is None else decode(a_res, fmt_a)
    wq = decode(w_codes, fmt_w)
    g = g.astype(jnp.float32)
    dx = jnp.einsum("ecf,edf->ecd", g, wq,
                    preferred_element_type=jnp.float32)
    dw = jnp.einsum("ecd,ecf->edf", aq.astype(jnp.float32), g,
                    preferred_element_type=jnp.float32)
    return dx, dw


fused_matmul_grouped_ste.defvjp(_grouped_ste_fwd, _grouped_ste_bwd)


def matmul_posit_weights_grouped_ste(x, w, fmt_w: PositFormat):
    """Differentiable grouped serving fast path (float activations)."""
    return fused_matmul_grouped_ste(x, w, None, fmt_w)
