"""Public jit'd wrappers around the Pallas kernels.

Central switch: on non-TPU backends every kernel runs in interpret mode
(Pallas executes the kernel body with jnp on CPU), so the whole framework —
models, tests, benchmarks — exercises the identical kernel code paths that
compile to Mosaic on a real TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import PDPUConfig, PositFormat
from . import posit_codec, posit_matmul, pdpu_dot
from . import ref  # noqa: F401  (re-exported for tests/benchmarks)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode(codes, fmt: PositFormat, **kw):
    """posit codes -> f32 (Pallas elementwise kernel)."""
    return posit_codec.decode(codes, fmt, interpret=_interpret(), **kw)


def encode(values, fmt: PositFormat, **kw):
    """float -> posit codes in storage dtype (Pallas elementwise kernel)."""
    return posit_codec.encode(values, fmt, interpret=_interpret(), **kw)


def fused_matmul(a_codes, b_codes, fmt_a: PositFormat, fmt_b: PositFormat,
                 fmt_out: PositFormat | None = None, **kw):
    """Fused posit GEMM: in-kernel decode -> MXU f32 -> single encode."""
    return posit_matmul.posit_matmul(
        a_codes, b_codes, fmt_a, fmt_b, fmt_out,
        interpret=_interpret(), **kw)


def fused_matmul_grouped(a_codes, b_codes, fmt_a: PositFormat,
                         fmt_b: PositFormat,
                         fmt_out: PositFormat | None = None, **kw):
    """Grouped fused posit GEMM: [E,M,K] x [E,K,N] codes -> [E,M,N].

    One expert per leading grid dimension; per-expert in-kernel decode,
    f32 MXU accumulate, single encode (fmt_out=None returns f32)."""
    return posit_matmul.posit_matmul_grouped(
        a_codes, b_codes, fmt_a, fmt_b, fmt_out,
        interpret=_interpret(), **kw)


def matmul_posit_weights_grouped(x, w_codes, fmt_w: PositFormat, **kw):
    """Float activations x stacked posit weights — grouped serving fast path.

    x: [E, M, K] float; w_codes: [E, K, N] posit codes.  Activations stay
    float (an encode would add a rounding); the expert weight stacks travel
    HBM->VMEM as int8/int16 codes and decode on the VPU inside the grouped
    kernel.  Returns f32.
    """
    return posit_matmul.posit_matmul_grouped(
        x.astype(jnp.float32), w_codes, None, fmt_w, None,
        interpret=_interpret(), **kw)


def pdpu_matmul(a_codes, b_codes, cfg: PDPUConfig, **kw):
    """Bit-exact chunked-PDPU GEMM (hardware-faithful W_m datapath)."""
    return pdpu_dot.pdpu_matmul(a_codes, b_codes, cfg,
                                interpret=_interpret(), **kw)


def matmul_posit_weights(x, w_codes, fmt_w: PositFormat, **kw):
    """float activations x posit-stored weights — the serving fast path.

    Activations stay float (encoding them would add a rounding); the posit
    weights decode exactly in-kernel and the dot accumulates f32.  Returns
    f32.  (Used by the dispatch layer for posit-weight checkpoints when
    QuantPolicy.activations is None.)
    """
    a = x.astype(jnp.float32)
    w = posit_codec.decode(w_codes, fmt_w, interpret=_interpret(), **kw)
    return jnp.dot(a, w, preferred_element_type=jnp.float32)
