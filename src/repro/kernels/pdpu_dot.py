"""Pallas TPU kernel: bit-exact chunked-PDPU GEMM (hardware-faithful path).

Runs the paper's S1..S6 integer datapath — including the W_m alignment
truncation and the fmt_out accumulator between chunks — over (bm, bn) output
tiles.  Every output element is bit-identical to what a silicon PDPU array
with chunk size N and alignment width W_m would produce.

This is the *fidelity* kernel: it exists so a TPU deployment can (a) serve
accuracy-critical layers with accelerator-exact semantics and (b) validate
the fast fused kernel (`posit_matmul`) / study W_m sensitivity at speed.
It is VPU-bound by design (integer select-chains, no MXU), so its roofline
is the vector unit, not the matrix unit — see benchmarks/bench_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import pdpu as pdpu_core
from repro.core.formats import PDPUConfig

_BM, _BN = 64, 128


def _pdpu_gemm_kernel(a_ref, b_ref, out_ref, *, cfg: PDPUConfig, n_chunks: int):
    a = a_ref[...].astype(jnp.int32) & cfg.fmt_in.mask  # [bm, K]
    b = b_ref[...].astype(jnp.int32) & cfg.fmt_in.mask  # [K, bn]
    bm, K = a.shape
    _, bn = b.shape
    N = cfg.N

    def body(j, acc):
        a_ch = jax.lax.dynamic_slice(a, (0, j * N), (bm, N))  # [bm, N]
        b_ch = jax.lax.dynamic_slice(b, (j * N, 0), (N, bn))  # [N, bn]
        va = jnp.broadcast_to(a_ch[:, None, :], (bm, bn, N))
        vb = jnp.broadcast_to(jnp.transpose(b_ch)[None, :, :], (bm, bn, N))
        return pdpu_core.pdpu_dot(va, vb, acc, cfg)

    acc0 = jnp.zeros((bm, bn), jnp.int32)
    out_ref[...] = jax.lax.fori_loop(0, n_chunks, body, acc0)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "interpret"))
def pdpu_matmul(a_codes, b_codes, cfg: PDPUConfig, bm=_BM, bn=_BN,
                interpret=False):
    """[M,K] x [K,N] posit-code GEMM through chunk-size-N PDPUs.

    K must be divisible by cfg.N (hardware constraint: whole chunks).
    M/N are padded to tile multiples (code 0 == posit zero, exact).
    Output: int32 posit codes in cfg.fmt_out.
    """
    M, K = a_codes.shape
    K2, N_out = b_codes.shape
    if K != K2:
        raise ValueError("contraction mismatch")
    if K % cfg.N:
        raise ValueError(f"K={K} not divisible by PDPU chunk size N={cfg.N}")
    bm_, bn_ = min(bm, M), min(bn, N_out)

    def pad(x, m0, m1):
        p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
        return jnp.pad(x, ((0, p0), (0, p1))) if (p0 or p1) else x

    a_p = pad(a_codes, bm_, 1)
    b_p = pad(b_codes, 1, bn_)
    Mp, Np = a_p.shape[0], b_p.shape[1]

    out = pl.pallas_call(
        functools.partial(_pdpu_gemm_kernel, cfg=cfg, n_chunks=K // cfg.N),
        grid=(Mp // bm_, Np // bn_),
        in_specs=[
            pl.BlockSpec((bm_, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N_out]
