"""Pallas TPU kernels for the posit/PDPU hot spots.

  posit_codec  : elementwise decode/encode (S1/S6 on the VPU)
  posit_matmul : fused posit GEMM — in-kernel decode, MXU f32 wide
                 accumulate, single encode (the PDPU's TPU-native form)
  pdpu_dot     : bit-exact chunked-PDPU GEMM (hardware-faithful W_m path)
  ops          : public jit'd wrappers (auto-interpret off-TPU)
  dispatch     : execution-plan dispatch (fake_quant | fused | bit_exact)
                 consulted by every model matmul via models/common.qdot
  ref          : pure-jnp oracles for the allclose/bit-identity sweeps
"""
from . import dispatch, ops, ref  # noqa: F401
