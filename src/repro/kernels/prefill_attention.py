"""Pallas kernel: fused prefill-attention that writes posit KV pages.

Chunked prefill used to be three device stages per chunk (models/
transformer.py `_chunk_attn`): flash attention over [gathered history |
raw chunk], a posit `kv_encode` of the chunk's K/V, and a
`paged.insert_chunk(_batched)` scatter into the page pool.  This kernel
collapses them into ONE device program per chunk — the PDPU argument
(fuse the datapath instead of composing discrete units) applied to the
serving prefill hot path:

  * per (slot, page) grid cell the slot's page arrives HBM->VMEM at posit
    code width via the scalar-prefetched block table (no dense gather in
    HBM), is decoded in-kernel, and is staged into a VMEM ring buffer one
    flash chunk wide,
  * the same cell posit-encodes the chunk rows that land in this page and
    merge-writes them back into the pool *in place*
    (`input_output_aliases` + a block-table-driven output index_map:
    pages outside the chunk span — or not owned by this shard — redirect
    to the trash page 0, so untouched pages pass through unchanged),
  * each time the staging buffer completes a full flash chunk of history,
    one running flash-softmax step folds it into VMEM state scratch
    (m/l/o); on the slot's last page step the remaining staged rows, the
    raw chunk, and the zero pad replay flash's tail chunks and the
    attention output is written.

Bit-exactness contract
----------------------

For ANY span the kernel replays `common.flash_attention`'s chunked
streaming scan op-for-op at the caller's `flash_chunk` — same chunk
boundaries over [decoded history | raw chunk | pad], same masking, same
running-max/correction arithmetic including the `o * corr + pv` step
(dropping it flips -0.0 signs), same finalize — so the fused path is
bit-identical to the three-program decomposed path.  The only geometry
requirement is that spans beyond one flash chunk need `page_size` to
divide `flash_chunk` (pages must tile the per-chunk staging buffer);
callers gate on `paged.fused_prefill_span_ok` and fall back to the
decomposed path otherwise.

Intra-chunk attention uses the *raw* (pre-encode) k/v and only history
reads see decoded codes, exactly like `_chunk_attn`; history decode
replays the `kv_decode` dtype chain (f32 -> compute dtype -> k dtype).

Sharded pools (`hist_pool_k/v` + `hist_bt` given): history cannot be
staged from the local sub-pool (other shards hold part of it), so the
caller passes the all-gathered global pool and the kernel stages history
pages from it via the globally-addressed `hist_bt` block table —
attention is then computed identically on every shard while `page_ok`
restricts the page writes to owned pages (non-owned chunk pages redirect
to the local trash page, the `insert_chunk(shard=...)` contract).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import posit
from repro.core.formats import PositFormat

_NEG = -2.0e38

_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def _decode_hist(x, fmt_kv, compute_dtype, out_dtype):
    """Replay common.kv_decode + the `.astype(k.dtype)` chain bit-exactly."""
    if fmt_kv is None:
        return x.astype(out_dtype)
    val = posit.decode(x.astype(jnp.int32) & fmt_kv.mask, fmt_kv)
    return val.astype(compute_dtype).astype(out_dtype)


def _fused_prefill_kernel(bt_ref, st_ref, win_ref, ok_ref, hbt_ref, q_ref,
                          k_ref, v_ref, *refs, fmt_kv: PositFormat | None,
                          compute_dtype, page_size: int, chunk: int,
                          n_pages_per_slot: int, n_heads: int,
                          n_kv_heads: int, head_dim: int, softcap_val: float,
                          flash_chunk: int, global_hist: bool):
    if global_hist:
        hkp_ref, hvp_ref, *refs = refs
    else:
        hkp_ref = hvp_ref = None
    kp_ref, vp_ref, attn_ref, kp_out, vp_out, hk_scr, hv_scr, *state = refs
    b = pl.program_id(0)
    p = pl.program_id(1)
    ps, C, M = page_size, chunk, n_pages_per_slot
    F = n_kv_heads * head_dim
    G = n_heads // n_kv_heads
    Dh = head_dim
    # flash_attention's chunk geometry, replayed statically: the key span
    # [history S_h | chunk C | pad] is scanned in ck-row chunks; the first
    # n_hist_full chunks are pure history and stream through the staging
    # ring, the tail (history remainder + raw chunk + pad) runs on the
    # slot's last page step.
    S_h = M * ps
    ck = min(flash_chunk, S_h + C)
    n_hist_full = S_h // ck
    h_rem = S_h - n_hist_full * ck
    n_tail = -(-(h_rem + C) // ck)
    tail_pad = n_tail * ck - (h_rem + C)
    R = min(S_h, ck) // ps  # staging ring size, in pages
    start = st_ref[b]

    if state:
        m_scr, l_scr, o_scr = state

        @pl.when(p == 0)
        def _init_state():
            m_scr[...] = jnp.full((n_kv_heads, G, C), _NEG, jnp.float32)
            l_scr[...] = jnp.zeros((n_kv_heads, G, C), jnp.float32)
            o_scr[...] = jnp.zeros((n_kv_heads, G, C, Dh), jnp.float32)

    # Snapshot the page before any aliased output write: history staging
    # and the read side of the merge must see pre-insert pool content
    # (exactly what paged.gather_slot would have gathered).
    old_k = kp_ref[0]
    old_v = vp_ref[0]
    src_k = hkp_ref[0] if global_hist else old_k
    src_v = hvp_ref[0] if global_hist else old_v
    stage = (p % R) * ps
    hk_scr[pl.ds(stage, ps)] = _decode_hist(src_k, fmt_kv, compute_dtype,
                                            hk_scr.dtype)
    hv_scr[pl.ds(stage, ps)] = _decode_hist(src_v, fmt_kv, compute_dtype,
                                            hv_scr.dtype)

    # ---- in-kernel encode + page write ------------------------------------
    # rows r of page p hold absolute positions p*ps + r; the chunk occupies
    # [start, start + C).  Select each covered row's raw chunk k/v with a
    # 0/1 matmul (exact: one surviving term per row), encode, merge with the
    # old page content, write.  The output index_map redirects pages outside
    # the chunk span (or not owned by this shard) to the trash page.
    rpos = p * ps + jax.lax.iota(jnp.int32, ps)
    j = rpos - start
    in_chunk = (j >= 0) & (j < C)
    sel = (j[:, None] == jax.lax.broadcasted_iota(jnp.int32, (ps, C), 1))
    sel_f = sel.astype(jnp.float32)
    kc = k_ref[0].reshape(C, F)
    vc = v_ref[0].reshape(C, F)
    k_rows = jnp.dot(sel_f, kc.astype(jnp.float32)).astype(kc.dtype)
    v_rows = jnp.dot(sel_f, vc.astype(jnp.float32)).astype(vc.dtype)
    if fmt_kv is None:
        k_codes = k_rows.astype(compute_dtype)
        v_codes = v_rows.astype(compute_dtype)
    else:
        k_codes = posit.encode(k_rows, fmt_kv)
        v_codes = posit.encode(v_rows, fmt_kv)
    wm = in_chunk[:, None]
    kp_out[0] = jnp.where(wm, k_codes.astype(old_k.dtype), old_k)
    vp_out[0] = jnp.where(wm, v_codes.astype(old_v.dtype), old_v)

    # ---- running flash softmax --------------------------------------------
    scale = 1.0 / math.sqrt(Dh)
    q_pos = start + jax.lax.iota(jnp.int32, C)

    def _qg():
        return q_ref[0].reshape(C, n_kv_heads, G, Dh) \
                       .astype(jnp.float32) * scale

    def _flash_step(m, l, o, kb, vb, kv_pos, qg):
        # one chunk of flash_attention's streaming scan, replayed verbatim
        # (B=1 blocks)
        s = jnp.einsum("qhgd,khd->hgqk", qg, kb.astype(jnp.float32))
        s = _softcap(s, softcap_val)
        mask = kv_pos[None, :] >= 0
        mask &= q_pos[:, None] >= kv_pos[None, :]
        mask &= (q_pos[:, None] - kv_pos[None, :]) < win_ref[0]
        s = jnp.where(mask[None, None, :, :], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        pr = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pr, axis=-1)
        pv = jnp.einsum("hgqk,khd->hgqd", pr, vb.astype(jnp.float32))
        # keep the o*corr term: 0.0*corr + (-0.0) is +0.0, matching flash;
        # writing `pv` alone would flip those signs
        o_new = o * corr[..., None] + pv
        return m_new, l_new, o_new

    if n_hist_full:
        # the staging ring just completed a full flash chunk of history:
        # fold it into the running state
        @pl.when(((p + 1) % R == 0) & (p + 1 <= n_hist_full * R))
        def _hist_step():
            qg = _qg()
            kb = hk_scr[...].reshape(ck, n_kv_heads, Dh)
            vb = hv_scr[...].reshape(ck, n_kv_heads, Dh)
            base = (p + 1) * ps - ck
            pos = base + jax.lax.iota(jnp.int32, ck)
            pos = jnp.where(pos < start, pos, -1)
            m_new, l_new, o_new = _flash_step(
                m_scr[...], l_scr[...], o_scr[...], kb, vb, pos, qg)
            m_scr[...] = m_new
            l_scr[...] = l_new
            o_scr[...] = o_new

    # ---- tail chunks + finalize on the slot's last page step --------------
    @pl.when(p == M - 1)
    def _attend():
        kdt = k_ref.dtype
        qg = _qg()
        if state:
            m = m_scr[...]
            l = l_scr[...]
            o = o_scr[...]
        else:
            m = jnp.full((n_kv_heads, G, C), _NEG, jnp.float32)
            l = jnp.zeros((n_kv_heads, G, C), jnp.float32)
            o = jnp.zeros((n_kv_heads, G, C, Dh), jnp.float32)
        parts_k, parts_v, parts_pos = [], [], []
        if h_rem:
            hk = hk_scr[...][:h_rem].reshape(h_rem, n_kv_heads, Dh)
            hv = hv_scr[...][:h_rem].reshape(h_rem, n_kv_heads, Dh)
            hp = n_hist_full * ck + jax.lax.iota(jnp.int32, h_rem)
            parts_k.append(hk)
            parts_v.append(hv)
            parts_pos.append(jnp.where(hp < start, hp, -1))
        parts_k.append(k_ref[0])
        parts_v.append(v_ref[0])
        parts_pos.append(q_pos)
        if tail_pad:
            parts_k.append(jnp.zeros((tail_pad, n_kv_heads, Dh), kdt))
            parts_v.append(jnp.zeros((tail_pad, n_kv_heads, Dh), kdt))
            parts_pos.append(jnp.full((tail_pad,), -1, jnp.int32))
        k_tail = jnp.concatenate(parts_k, axis=0)
        v_tail = jnp.concatenate(parts_v, axis=0)
        pos_tail = jnp.concatenate(parts_pos)
        for jt in range(n_tail):
            sl = slice(jt * ck, (jt + 1) * ck)
            m, l, o = _flash_step(m, l, o, k_tail[sl], v_tail[sl],
                                  pos_tail[sl], qg)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(o, 2, 0).reshape(C, n_heads, Dh)
        attn_ref[0] = out.astype(q_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("fmt_kv", "compute_dtype", "softcap_val", "flash_chunk",
                     "interpret", "dimension_semantics", "vmem_limit_mb"),
)
def prefill_attention_paged(q, k, v, k_pages, v_pages, block_tables, starts,
                            window, fmt_kv: PositFormat | None = None,
                            compute_dtype=jnp.float32, softcap_val: float = 0.0,
                            flash_chunk: int = 1024, interpret: bool = False,
                            hist_pool_k=None, hist_pool_v=None, hist_bt=None,
                            page_ok=None, dimension_semantics: str | None = None,
                            vmem_limit_mb: int | None = None):
    """Fused prefill: chunk attention + posit KV encode + paged insert.

    q            : [B, C, Hq, Dh] post-rope queries (chunk positions
                   starts[b] + [0, C)).
    k, v         : [B, C, Hkv, Dh] raw post-rope chunk keys/values — the
                   kernel encodes them to the pool's code width itself.
    k/v_pages    : [n_pages, page_size, Hkv*Dh] pool (the local sub-pool
                   under a kv_pages shard).
    block_tables : [B, M] page ids (pre-localized under a shard); rows of
                   inactive slots zeroed -> writes land on the trash page.
    starts       : [B] int32 chunk start position per slot.
    window       : [1] int32 sliding window (>= max_seq = unbounded).
    flash_chunk  : flash_attention key-chunk length the kernel replays
                   (spans beyond it require page_size | flash_chunk).
    hist_pool_k/v: optional [n_pages_global, page_size, Hkv*Dh] all-
                   gathered global pool (kv_pages-sharded pools) history
                   pages are staged from; `hist_bt` then carries the
                   *global* page ids.  When omitted, history is staged
                   from the local pool via `block_tables`.
    page_ok      : optional [B, M] write-ownership mask (sharded pools).
    dimension_semantics / vmem_limit_mb : TPU launch knobs (autotuned);
                   value-neutral by construction.

    Returns (attn [B, C, Hq, Dh] in q.dtype, k_pages', v_pages') with the
    pools updated in place (donated/aliased) exactly as
    `paged.insert_chunk_batched` would have written them.
    """
    B, C, Hq, Dh = q.shape
    n_pages, ps, kvd = k_pages.shape
    Hkv = kvd // Dh
    if Hkv * Dh != kvd or Hq % Hkv:
        raise ValueError(f"page feature dim {kvd} incompatible with "
                         f"q heads {Hq} x head_dim {Dh}")
    if k.shape != (B, C, Hkv, Dh) or v.shape != (B, C, Hkv, Dh):
        raise ValueError(f"chunk k/v shape {k.shape} != {(B, C, Hkv, Dh)}")
    M = block_tables.shape[1]
    global_hist = hist_pool_k is not None
    if global_hist:
        if hist_bt is None:
            raise ValueError("hist_pool_k/v require the global hist_bt")
        if hist_pool_k.shape[1:] != (ps, kvd):
            raise ValueError(f"hist pool page shape {hist_pool_k.shape[1:]} "
                             f"!= {(ps, kvd)}")
    if page_ok is None:
        page_ok = jnp.ones((B, M), jnp.int32)
    S_h = M * ps
    ck = min(int(flash_chunk), S_h + C)
    n_hist_full = S_h // ck
    if n_hist_full and ck % ps:
        raise ValueError(f"span {S_h}+{C} needs page_size {ps} to divide "
                         f"flash_chunk {ck} (see fused_prefill_span_ok)")

    def _qmap(b, p, bt, st, wn, ok, hbt):
        return (b, 0, 0, 0)

    def _pmap(b, p, bt, st, wn, ok, hbt):
        return (bt[b, p], 0, 0)

    def _hmap(b, p, bt, st, wn, ok, hbt):
        return (hbt[b, p], 0, 0)

    def _wmap(b, p, bt, st, wn, ok, hbt):
        pstart = p * ps
        w = (pstart < st[b] + C) & (pstart + ps > st[b]) & (ok[b, p] > 0)
        return (jnp.where(w, bt[b, p], 0), 0, 0)

    chunk_spec = pl.BlockSpec((1, C, Hkv, Dh), _qmap)
    page_spec = pl.BlockSpec((1, ps, kvd), _pmap)
    in_specs = [pl.BlockSpec((1, C, Hq, Dh), _qmap), chunk_spec, chunk_spec]
    inputs = [q, k, v]
    if global_hist:
        hist_spec = pl.BlockSpec((1, ps, kvd), _hmap)
        in_specs += [hist_spec, hist_spec]
        inputs += [hist_pool_k, hist_pool_v]
    in_specs += [page_spec, page_spec]
    inputs += [k_pages, v_pages]
    # flattened input index of k_pages/v_pages, counting the 5 scalar-
    # prefetch operands first — aliased onto pool outputs 1 and 2
    kp_idx = 5 + len(in_specs) - 2
    aliases = {kp_idx: 1, kp_idx + 1: 2}

    buf_rows = min(S_h, ck)
    scratch = [pltpu.VMEM((buf_rows, kvd), k.dtype),
               pltpu.VMEM((buf_rows, kvd), v.dtype)]
    if n_hist_full:
        G = Hq // Hkv
        scratch += [pltpu.VMEM((Hkv, G, C), jnp.float32),
                    pltpu.VMEM((Hkv, G, C), jnp.float32),
                    pltpu.VMEM((Hkv, G, C, Dh), jnp.float32)]

    out_specs = [
        pl.BlockSpec((1, C, Hq, Dh), _qmap),
        pl.BlockSpec((1, ps, kvd), _wmap),
        pl.BlockSpec((1, ps, kvd), _wmap),
    ]
    out_shapes = [
        jax.ShapeDtypeStruct((B, C, Hq, Dh), q.dtype),
        jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
        jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
    ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(B, M),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    kernel = functools.partial(
        _fused_prefill_kernel, fmt_kv=fmt_kv, compute_dtype=compute_dtype,
        page_size=ps, chunk=C, n_pages_per_slot=M, n_heads=Hq,
        n_kv_heads=Hkv, head_dim=Dh, softcap_val=softcap_val,
        flash_chunk=int(flash_chunk), global_hist=global_hist)
    cp_kwargs = {"dimension_semantics":
                 (dimension_semantics or "parallel", "arbitrary")}
    if vmem_limit_mb is not None:
        cp_kwargs["vmem_limit_bytes"] = int(vmem_limit_mb) << 20
    hbt = hist_bt if global_hist else block_tables
    attn, k_new, v_new = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        input_output_aliases=aliases,
        interpret=interpret,
        compiler_params=_CompilerParams(**cp_kwargs),
    )(block_tables.astype(jnp.int32), starts.astype(jnp.int32),
      window.astype(jnp.int32), page_ok.astype(jnp.int32),
      hbt.astype(jnp.int32), *inputs)
    return attn, k_new, v_new
