"""Pallas kernel: fused prefill-attention that writes posit KV pages.

Chunked prefill used to be three device stages per chunk (models/
transformer.py `_chunk_attn`): flash attention over [gathered history |
raw chunk], a posit `kv_encode` of the chunk's K/V, and a
`paged.insert_chunk(_batched)` scatter into the page pool.  This kernel
collapses them into ONE device program per chunk — the PDPU argument
(fuse the datapath instead of composing discrete units) applied to the
serving prefill hot path:

  * per (slot, page) grid cell the slot's page arrives HBM->VMEM at posit
    code width via the scalar-prefetched block table (no dense gather in
    HBM), is decoded in-kernel, and is staged into a VMEM history scratch,
  * the same cell posit-encodes the chunk rows that land in this page and
    merge-writes them back into the pool *in place*
    (`input_output_aliases` + a block-table-driven output index_map:
    pages outside the chunk span — or not owned by this shard — redirect
    to the trash page 0, so untouched pages pass through unchanged),
  * on the slot's last page step the full-span softmax runs over
    [staged history | raw chunk] and the attention output is written.

Bit-exactness contract
----------------------

The attention here is NOT the page-streamed softmax of
kernels/paged_attention.py: accumulating page-by-page changes the
floating-point grouping and cannot reproduce `common.flash_attention`
bit-for-bit.  Instead, for spans that fit one flash chunk
(history + chunk <= models.paged.FLASH_CHUNK, every serving config), the
kernel replays flash_attention's single-chunk degenerate pass op-for-op —
same masking, same running-max/correction arithmetic including the
`o0 * corr + pv` step (dropping it flips -0.0 signs), same finalize —
so the fused path is bit-identical to the three-program path.  Callers
(models/transformer.py) gate on `paged.fused_prefill_span_ok` and fall
back to the decomposed path for longer spans.

Intra-chunk attention uses the *raw* (pre-encode) k/v and only history
reads see decoded codes, exactly like `_chunk_attn`; history decode
replays the `kv_decode` dtype chain (f32 -> compute dtype -> k dtype).

Sharded pools (`hist_k/hist_v` given): history cannot be staged from the
local sub-pool (other shards hold part of it), so the caller passes the
exact psum-gathered code rows (`paged.gather_slots(..., shard)`) and the
kernel reads history from that dense input instead of scratch — attention
is then computed identically on every shard while `page_ok` restricts the
page writes to owned pages (non-owned chunk pages redirect to the local
trash page, the `insert_chunk(shard=...)` contract).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import posit
from repro.core.formats import PositFormat

_NEG = -2.0e38

_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def _decode_hist(x, fmt_kv, compute_dtype, out_dtype):
    """Replay common.kv_decode + the `.astype(k.dtype)` chain bit-exactly."""
    if fmt_kv is None:
        return x.astype(out_dtype)
    val = posit.decode(x.astype(jnp.int32) & fmt_kv.mask, fmt_kv)
    return val.astype(compute_dtype).astype(out_dtype)


def _fused_prefill_kernel(bt_ref, st_ref, win_ref, ok_ref, q_ref, k_ref,
                          v_ref, *refs, fmt_kv: PositFormat | None,
                          compute_dtype, page_size: int, chunk: int,
                          n_pages_per_slot: int, n_heads: int,
                          n_kv_heads: int, head_dim: int, softcap_val: float,
                          dense_hist: bool):
    if dense_hist:
        hk_ref, hv_ref, kp_ref, vp_ref, attn_ref, kp_out, vp_out = refs
        hk_scr = hv_scr = None
    else:
        kp_ref, vp_ref, attn_ref, kp_out, vp_out, hk_scr, hv_scr = refs
    b = pl.program_id(0)
    p = pl.program_id(1)
    ps, C, M = page_size, chunk, n_pages_per_slot
    F = n_kv_heads * head_dim
    start = st_ref[b]

    # Snapshot the page before any aliased output write: history staging
    # and the read side of the merge must see pre-insert pool content
    # (exactly what paged.gather_slot would have gathered).
    old_k = kp_ref[0]
    old_v = vp_ref[0]

    if not dense_hist:
        hk_scr[pl.ds(p * ps, ps)] = _decode_hist(old_k, fmt_kv, compute_dtype,
                                                 hk_scr.dtype)
        hv_scr[pl.ds(p * ps, ps)] = _decode_hist(old_v, fmt_kv, compute_dtype,
                                                 hv_scr.dtype)

    # ---- in-kernel encode + page write ------------------------------------
    # rows r of page p hold absolute positions p*ps + r; the chunk occupies
    # [start, start + C).  Select each covered row's raw chunk k/v with a
    # 0/1 matmul (exact: one surviving term per row), encode, merge with the
    # old page content, write.  The output index_map redirects pages outside
    # the chunk span (or not owned by this shard) to the trash page.
    rpos = p * ps + jax.lax.iota(jnp.int32, ps)
    j = rpos - start
    in_chunk = (j >= 0) & (j < C)
    sel = (j[:, None] == jax.lax.broadcasted_iota(jnp.int32, (ps, C), 1))
    sel_f = sel.astype(jnp.float32)
    kc = k_ref[0].reshape(C, F)
    vc = v_ref[0].reshape(C, F)
    k_rows = jnp.dot(sel_f, kc.astype(jnp.float32)).astype(kc.dtype)
    v_rows = jnp.dot(sel_f, vc.astype(jnp.float32)).astype(vc.dtype)
    if fmt_kv is None:
        k_codes = k_rows.astype(compute_dtype)
        v_codes = v_rows.astype(compute_dtype)
    else:
        k_codes = posit.encode(k_rows, fmt_kv)
        v_codes = posit.encode(v_rows, fmt_kv)
    wm = in_chunk[:, None]
    kp_out[0] = jnp.where(wm, k_codes.astype(old_k.dtype), old_k)
    vp_out[0] = jnp.where(wm, v_codes.astype(old_v.dtype), old_v)

    # ---- attention on the slot's last page step ---------------------------
    @pl.when(p == M - 1)
    def _attend():
        S_h = M * ps
        kdt = k_ref.dtype
        if dense_hist:
            hk = _decode_hist(hk_ref[0], fmt_kv, compute_dtype, kdt)
            hv = _decode_hist(hv_ref[0], fmt_kv, compute_dtype, kdt)
        else:
            hk = hk_scr[...]
            hv = hv_scr[...]
        G = n_heads // n_kv_heads
        scale = 1.0 / math.sqrt(head_dim)
        qg = q_ref[0].reshape(C, n_kv_heads, G, head_dim) \
                     .astype(jnp.float32) * scale
        k_all = jnp.concatenate(
            [hk.reshape(S_h, n_kv_heads, head_dim), k_ref[0]], axis=0)
        v_all = jnp.concatenate(
            [hv.reshape(S_h, n_kv_heads, head_dim), v_ref[0]], axis=0)
        hist_pos = jax.lax.iota(jnp.int32, S_h)
        hist_pos = jnp.where(hist_pos < start, hist_pos, -1)
        q_pos = start + jax.lax.iota(jnp.int32, C)
        kv_pos = jnp.concatenate([hist_pos, q_pos])
        # flash_attention's single-chunk pass, replayed verbatim (B=1 blocks)
        s = jnp.einsum("qhgd,khd->hgqk", qg, k_all.astype(jnp.float32))
        s = _softcap(s, softcap_val)
        mask = kv_pos[None, :] >= 0
        mask &= q_pos[:, None] >= kv_pos[None, :]
        mask &= (q_pos[:, None] - kv_pos[None, :]) < win_ref[0]
        s = jnp.where(mask[None, None, :, :], s, _NEG)
        m0 = jnp.full((n_kv_heads, G, C), _NEG, jnp.float32)
        l0 = jnp.zeros((n_kv_heads, G, C), jnp.float32)
        o0 = jnp.zeros((n_kv_heads, G, C, head_dim), jnp.float32)
        m_new = jnp.maximum(m0, jnp.max(s, axis=-1))
        pr = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m0 - m_new)
        l_new = l0 * corr + jnp.sum(pr, axis=-1)
        pv = jnp.einsum("hgqk,khd->hgqd", pr, v_all.astype(jnp.float32))
        # keep the o0*corr term: 0.0*corr + (-0.0) is +0.0, matching flash;
        # writing `pv` alone would flip those signs
        o_new = o0 * corr[..., None] + pv
        o = o_new / jnp.maximum(l_new, 1e-30)[..., None]
        out = jnp.moveaxis(o, 2, 0).reshape(C, n_heads, head_dim)
        attn_ref[0] = out.astype(q_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("fmt_kv", "compute_dtype", "softcap_val", "interpret"),
)
def prefill_attention_paged(q, k, v, k_pages, v_pages, block_tables, starts,
                            window, fmt_kv: PositFormat | None = None,
                            compute_dtype=jnp.float32, softcap_val: float = 0.0,
                            interpret: bool = False, hist_k=None, hist_v=None,
                            page_ok=None):
    """Fused prefill: chunk attention + posit KV encode + paged insert.

    q            : [B, C, Hq, Dh] post-rope queries (chunk positions
                   starts[b] + [0, C)).
    k, v         : [B, C, Hkv, Dh] raw post-rope chunk keys/values — the
                   kernel encodes them to the pool's code width itself.
    k/v_pages    : [n_pages, page_size, Hkv*Dh] pool (the local sub-pool
                   under a kv_pages shard).
    block_tables : [B, M] page ids (pre-localized under a shard); rows of
                   inactive slots zeroed -> writes land on the trash page.
    starts       : [B] int32 chunk start position per slot.
    window       : [1] int32 sliding window (>= max_seq = unbounded).
    hist_k/v     : optional [B, M*page_size, Hkv*Dh] pre-gathered history
                   codes (kv_pages-sharded pools: the exact psum gather).
                   When omitted, history is staged from the pool in-kernel.
    page_ok      : optional [B, M] write-ownership mask (sharded pools).

    Returns (attn [B, C, Hq, Dh] in q.dtype, k_pages', v_pages') with the
    pools updated in place (donated/aliased) exactly as
    `paged.insert_chunk_batched` would have written them.
    """
    B, C, Hq, Dh = q.shape
    n_pages, ps, kvd = k_pages.shape
    Hkv = kvd // Dh
    if Hkv * Dh != kvd or Hq % Hkv:
        raise ValueError(f"page feature dim {kvd} incompatible with "
                         f"q heads {Hq} x head_dim {Dh}")
    if k.shape != (B, C, Hkv, Dh) or v.shape != (B, C, Hkv, Dh):
        raise ValueError(f"chunk k/v shape {k.shape} != {(B, C, Hkv, Dh)}")
    M = block_tables.shape[1]
    dense_hist = hist_k is not None
    if dense_hist and hist_k.shape != (B, M * ps, kvd):
        raise ValueError(f"hist shape {hist_k.shape} != {(B, M * ps, kvd)}")
    if page_ok is None:
        page_ok = jnp.ones((B, M), jnp.int32)

    def _qmap(b, p, bt, st, wn, ok):
        return (b, 0, 0, 0)

    def _pmap(b, p, bt, st, wn, ok):
        return (bt[b, p], 0, 0)

    def _wmap(b, p, bt, st, wn, ok):
        pstart = p * ps
        w = (pstart < st[b] + C) & (pstart + ps > st[b]) & (ok[b, p] > 0)
        return (jnp.where(w, bt[b, p], 0), 0, 0)

    chunk_spec = pl.BlockSpec((1, C, Hkv, Dh), _qmap)
    page_spec = pl.BlockSpec((1, ps, kvd), _pmap)
    in_specs = [pl.BlockSpec((1, C, Hq, Dh), _qmap), chunk_spec, chunk_spec]
    inputs = [q, k, v]
    if dense_hist:
        hist_spec = pl.BlockSpec((1, M * ps, kvd),
                                 lambda b, p, bt, st, wn, ok: (b, 0, 0))
        in_specs += [hist_spec, hist_spec]
        inputs += [hist_k, hist_v]
        scratch = []
    else:
        scratch = [pltpu.VMEM((M * ps, kvd), k.dtype),
                   pltpu.VMEM((M * ps, kvd), v.dtype)]
    in_specs += [page_spec, page_spec]
    inputs += [k_pages, v_pages]
    # flattened input index of k_pages/v_pages, counting the 4 scalar-
    # prefetch operands first — aliased onto pool outputs 1 and 2
    kp_idx = 4 + len(in_specs) - 2
    aliases = {kp_idx: 1, kp_idx + 1: 2}

    out_specs = [
        pl.BlockSpec((1, C, Hq, Dh), _qmap),
        pl.BlockSpec((1, ps, kvd), _wmap),
        pl.BlockSpec((1, ps, kvd), _wmap),
    ]
    out_shapes = [
        jax.ShapeDtypeStruct((B, C, Hq, Dh), q.dtype),
        jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
        jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
    ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, M),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    kernel = functools.partial(
        _fused_prefill_kernel, fmt_kv=fmt_kv, compute_dtype=compute_dtype,
        page_size=ps, chunk=C, n_pages_per_slot=M, n_heads=Hq,
        n_kv_heads=Hkv, head_dim=Dh, softcap_val=softcap_val,
        dense_hist=dense_hist)
    attn, k_new, v_new = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        input_output_aliases=aliases,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(block_tables.astype(jnp.int32), starts.astype(jnp.int32),
      window.astype(jnp.int32), page_ok.astype(jnp.int32), *inputs)
    return attn, k_new, v_new
