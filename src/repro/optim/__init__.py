"""Optimizers + schedules + posit-compressed gradient collectives."""
from .optimizers import (  # noqa: F401
    Optimizer, adamw, adafactor, sgdm, by_name,
    cosine_schedule, constant_schedule, clip_by_global_norm, global_norm,
)
from . import compress  # noqa: F401
