"""Posit-compressed gradient all-reduce with error feedback.

The paper's thesis — posit formats keep accuracy at much lower bit-width —
applied to the *distributed-optimization* layer: cross-pod gradient
reduction is the bandwidth-starved collective at 1000+-node scale (DCN or
long ICI hops), so we ship P(8,2) codes (4x fewer bytes than f32) over the
slow axis and keep full-precision reductions on the fast in-pod axis.

Algorithm (ring reduce-scatter + all-gather, both on int8 wire):
    e      <- error-feedback residual (persistent, same tree as grads)
    q      = posit8_encode(g + e)            # one rounding
    e'     = (g + e) - posit8_decode(q)      # residual stays local
    shards = all_to_all(q)                   # int8 wire
    s      = sum(posit8_decode(shards))      # exact f32 accumulate (PDPU rule)
    out    = all_gather(posit8_encode(s))    # int8 wire, one more rounding
    return posit8_decode(out) / axis_size

Error feedback makes the scheme unbiased over steps; the wide f32 local
accumulation mirrors the PDPU contract (narrow operands, wide accumulator).

These functions use collective primitives with axis names, so they run
inside `shard_map` (see train.train_step_compressed) — that is where the
int8 wire traffic becomes visible to the compiler/HLO (verified by the
collective-bytes parser in benchmarks/roofline.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import PositFormat, P8_2


def _pad_to(x, mult):
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x


def compressed_psum_leaf(g, err, axis_name: str, fmt: PositFormat = P8_2):
    """One leaf: returns (mean-reduced g, new error residual)."""
    n = jax.lax.psum(1, axis_name)
    shape = g.shape
    gf = g.reshape(-1).astype(jnp.float32) + err.reshape(-1)
    L = gf.shape[0]

    codes = posit.pack(gf, fmt)                       # int8 codes
    new_err = gf - posit.unpack(codes, fmt)           # stage-1 residual

    padded = _pad_to(codes, n)
    Ls = padded.shape[0] // n
    # ring reduce-scatter on int8 wire: each device receives every peer's
    # shard of its segment
    shards = jax.lax.all_to_all(padded.reshape(n, Ls), axis_name, 0, 0,
                                tiled=False)          # [n, Ls] int8
    local_sum = jnp.sum(posit.unpack(shards, fmt), axis=0)  # exact f32 acc
    out_codes = posit.pack(local_sum, fmt)            # second (final) rounding
    # stage-2 residual: the segment owner feeds the sum-space rounding error
    # back into its own next gradient (debiases the all-gather rounding too)
    seg_err = local_sum - posit.unpack(out_codes, fmt)
    idx = jax.lax.axis_index(axis_name)
    err_flat = _pad_to(new_err, n)
    err_flat = jax.lax.dynamic_update_slice(
        err_flat, jax.lax.dynamic_slice(err_flat, (idx * Ls,), (Ls,)) + seg_err,
        (idx * Ls,))
    new_err = err_flat[:L]
    full = jax.lax.all_gather(out_codes, axis_name)   # [n, Ls] int8 wire
    total = posit.unpack(full.reshape(-1)[:L], fmt)
    return (total / n).reshape(shape), new_err.reshape(shape)


def compressed_psum(grads, err_tree, axis_name: str, fmt: PositFormat = P8_2):
    """Tree version. Returns (reduced grads, new error tree)."""
    pairs = jax.tree.map(
        lambda g, e: compressed_psum_leaf(g, e, axis_name, fmt), grads, err_tree)
    red = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    err = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    return red, err


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes(params, n_devices: int, fmt: PositFormat = P8_2) -> dict:
    """Analytical wire-traffic comparison for one gradient reduction."""
    n_elems = sum(x.size for x in jax.tree.leaves(params))
    f32 = 2 * n_elems * 4 * (n_devices - 1) / n_devices  # ring AR bytes/dev
    comp = 2 * n_elems * (fmt.storage_bits // 8) * (n_devices - 1) / n_devices
    return {"f32_allreduce_bytes": f32, "posit_bytes": comp,
            "ratio": f32 / comp}
