"""From-scratch optimizers (no optax in this environment).

Interface mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``; updates are added
to params by the caller.  All states are pytrees shardable like params
(FSDP-friendly: moments inherit the parameters' logical axes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def constant_schedule(lr_val: float):
    return lambda step: jnp.float32(lr_val)


# ---------------------------------------------------------------------------
# gradient transforms
# ---------------------------------------------------------------------------

def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adamw(lr: Callable, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr(step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — frees one full-size state tensor;
# the memory-side companion to the paper's "lower precision, wider reach")
# ---------------------------------------------------------------------------

class FactorState(NamedTuple):
    step: jnp.ndarray
    vr: object  # row stats (or full v for <2D leaves)
    vc: object  # col stats (or None sentinel)


def adafactor(lr: Callable, eps=1e-30, clip_thresh=1.0,
              weight_decay=0.0) -> Optimizer:
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros_like(p, dtype=jnp.float32))

        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((), jnp.float32))

        return FactorState(jnp.zeros((), jnp.int32),
                           jax.tree.map(vr, params), jax.tree.map(vc, params))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** -0.8
        lr_t = lr(step)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr_n = beta * vr + (1 - beta) * g2.mean(-1)
                vc_n = beta * vc + (1 - beta) * g2.mean(-2)
                denom = (vr_n[..., None] * vc_n[..., None, :]
                         / jnp.maximum(vr_n.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                u = g * jax.lax.rsqrt(vr_n + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), vr_n, vc_n

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        vr = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        vc = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        return updates, FactorState(step, vr, vc)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD + momentum (baseline)
# ---------------------------------------------------------------------------

class SgdState(NamedTuple):
    step: jnp.ndarray
    mom: object


def sgdm(lr: Callable, momentum=0.9) -> Optimizer:
    def init(params):
        return SgdState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(grads, state, params):
        step = state.step + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                           state.mom, grads)
        lr_t = lr(step)
        updates = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype), mom, params)
        return updates, SgdState(step, mom)

    return Optimizer(init, update)


def by_name(name: str, lr_fn) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn)
    if name == "adafactor":
        return adafactor(lr_fn)
    if name == "sgdm":
        return sgdm(lr_fn)
    raise KeyError(f"unknown optimizer '{name}'")
