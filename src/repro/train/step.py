"""Training step: microbatched gradient accumulation, posit-aware loss,
optimizer update — one jit'd function, shardable on any mesh.

The global batch is reshaped [accum, B/accum, S] and scanned; each
microbatch's fwd+bwd runs under layer remat, so live activation memory is
O(B/accum x S x D) while arithmetic stays identical.  XLA overlaps the
per-microbatch reduce-scatters with the next microbatch's compute — the
standard accumulation/communication overlap at pod scale.

`make_train_step_compressed` wraps the same step in shard_map and reduces
gradients across the slow 'pod' axis with the posit-compressed ring
(optim.compress) — the paper's format as a distributed-optimization tool.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.models import common
from repro.optim.optimizers import Optimizer
from repro.parallel import sharding


# compat shim now lives in parallel/sharding.py (also used by serving)
_shard_map = sharding.shard_map


class TrainState(NamedTuple):
    params: object
    opt_state: object
    step: jnp.ndarray


def init_state(rng, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    params = api.init(rng, cfg)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))


def loss_fn(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    if cfg.cast_params_early:
        # cast the f32 master weights to the compute dtype up front: the
        # sharded cast output is what downstream matmuls consume, so XLA's
        # FSDP all-gathers ship bf16 instead of f32 (2x collective bytes).
        cd = cfg.compute_dtype
        params = jax.tree.map(
            lambda p: p.astype(cd) if p.dtype == jnp.float32 else p, params)
    needs_aux = cfg.family in ("moe", "hybrid")
    if needs_aux:
        logits, aux = api.apply(params, batch, cfg, with_aux=True)
    else:
        logits = api.apply(params, batch, cfg)
        aux = 0.0
    loss = common.cross_entropy(logits, batch["labels"])
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, opt: Optimizer, accum: int = 1):
    """Returns train_step(state, batch) -> (state', metrics).

    The execution plan in cfg.quant decides which GEMM datapath the
    fwd+bwd runs: fake_quant (STE on float dots) or fused (the packed
    Pallas kernel forward with a custom_vjp STE backward — QAT on the
    real serving datapath).  Non-trainable plans are rejected up front.
    """
    cfg.quant.require_trainable()

    def train_step(state: TrainState, batch):
        B = batch["labels"].shape[0]
        assert B % accum == 0, (B, accum)

        def reshape(x):
            x = x.reshape((accum, B // accum) + x.shape[1:])
            return sharding.constrain(
                x, (None, "batch") + (None,) * (x.ndim - 2))

        mb = jax.tree.map(reshape, batch)
        grad_fn = jax.value_and_grad(
            lambda p, b: loss_fn(p, b, cfg), has_aux=True)

        def micro(carry, b):
            gsum, msum = carry
            (loss, metrics), g = grad_fn(state.params, b)
            gsum = jax.tree.map(jnp.add, gsum, g)
            msum = jax.tree.map(jnp.add, msum, {"loss": loss, **metrics})
            return (gsum, msum), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        m0 = {"loss": jnp.float32(0), "ce": jnp.float32(0), "aux": jnp.float32(0)}
        (gsum, msum), _ = jax.lax.scan(micro, (g0, m0), mb)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        metrics = jax.tree.map(lambda m: m / accum, msum)

        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = jax.tree.map(jnp.add, state.params, updates)
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg)
        return {"loss": loss, **metrics}
    return eval_step


# ---------------------------------------------------------------------------
# posit-compressed cross-pod gradient reduction (shard_map path)
# ---------------------------------------------------------------------------


def make_train_step_compressed(cfg: ModelConfig, opt: Optimizer, mesh,
                               fmt=None, accum: int = 1):
    """Train step with P(8,2)-compressed gradient all-reduce over 'pod'.

    Fully-manual shard_map data parallelism: the batch is split over the
    (pod, data) axes, gradients are pmean'd in full precision over the fast
    in-pod 'data' axis, and the cross-pod reduction over the slow 'pod'
    axis ships int8 posit codes with persistent error feedback carried in
    the state.  The 'model' axis runs replicated compute inside this step
    (tensor parallelism is an auto-SPMD concern; this path isolates the
    compressed-collective wire format).
    """
    from jax.sharding import PartitionSpec as P
    from repro.core.formats import P8_2
    from repro.optim import compress

    cfg.quant.require_trainable()
    fmt = fmt or P8_2

    def local_grads(params, batch):
        grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg), has_aux=True)
        (loss, metrics), g = grad_fn(params, batch)
        return g, {"loss": loss, **metrics}

    def step(params, opt_state, err_tree, step_no, batch):
        # err_tree arrives with a leading pod dim sliced to [1, ...] locally
        err_local = jax.tree.map(lambda e: e[0], err_tree)
        g, metrics = local_grads(params, batch)
        # full-precision mean over the fast in-pod axis first ...
        g = jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)
        # ... then the posit-compressed reduction over the slow pod axis
        g, err_local = compress.compressed_psum(g, err_local, "pod", fmt)
        metrics = jax.tree.map(
            lambda m: jax.lax.pmean(m, ("pod", "data")), metrics)
        updates, opt_state = opt.update(g, opt_state, params)
        params = jax.tree.map(jnp.add, params, updates)
        err_tree = jax.tree.map(lambda e: e[None], err_local)
        return params, opt_state, err_tree, step_no + 1, metrics

    def init_err(params):
        """Per-pod persistent error-feedback residuals, stacked on a pod dim."""
        n_pods = mesh.shape["pod"]
        return jax.tree.map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)

    def train_step(state_and_err, batch):
        (state, err_tree) = state_and_err
        rep = P()  # params/opt state replicated across every axis here
        dp = P(("pod", "data"))
        err_specs = jax.tree.map(lambda _: P("pod"), state.params)
        batch_specs = jax.tree.map(lambda _: dp, batch)
        params, opt_state, err_tree, step_no, metrics = _shard_map(
            step, mesh,
            in_specs=(rep, rep, err_specs, rep, batch_specs),
            out_specs=(rep, rep, err_specs, rep, rep),
        )(state.params, state.opt_state, err_tree, state.step, batch)
        return (TrainState(params, opt_state, step_no), err_tree), metrics

    train_step.init_err = init_err
    return train_step
