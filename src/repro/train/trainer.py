"""Training loop with the full production control plane wired in:
checkpoint/restart, NaN guard, straggler detection, async saves,
deterministic data resume, throughput accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import Pipeline
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.optimizers import Optimizer
from repro.runtime.fault_tolerance import NaNGuard, StragglerDetector
from . import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    accum: int = 1
    async_ckpt: bool = True
    keep_last: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, opt: Optimizer,
                 pipeline: Pipeline, tcfg: TrainerConfig = TrainerConfig()):
        self.cfg = cfg
        self.shape = shape
        self.opt = opt
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.train_step = jax.jit(step_lib.make_train_step(cfg, opt, tcfg.accum))
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, tcfg.keep_last)
                     if tcfg.ckpt_dir else None)
        self.straggler = StragglerDetector()
        self.nan_guard = NaNGuard()
        self.history: list = []

    # -- state lifecycle -----------------------------------------------------
    def init_or_restore(self, rng) -> step_lib.TrainState:
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                like = jax.eval_shape(
                    lambda: step_lib.init_state(rng, self.cfg, self.opt))
                state = self.ckpt.restore(latest, like)
                print(f"[trainer] restored step {latest}")
                return state
        return step_lib.init_state(rng, self.cfg, self.opt)

    # -- main loop -------------------------------------------------------------
    def run(self, rng, steps: Optional[int] = None):
        state = self.init_or_restore(rng)
        start = int(state.step)
        steps = steps if steps is not None else self.tcfg.total_steps
        last_good = start
        it = map(self._to_device, self._batches(start))
        t_tokens = self.shape.tokens
        for s in range(start, steps):
            batch = next(it)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(metrics["loss"])  # blocks: also our step barrier
            dt = time.perf_counter() - t0

            verdict = self.nan_guard.observe(loss)
            if verdict == "restore" and self.ckpt is not None and self.ckpt.all_steps():
                print(f"[trainer] non-finite loss x{self.nan_guard.consecutive}; "
                      f"rolling back to step {last_good}")
                state = self.init_or_restore(rng)
                it = map(self._to_device, self._batches(int(state.step)))
                continue
            if self.straggler.observe(dt):
                print(f"[trainer] straggler step {s}: {dt:.3f}s "
                      f"(median {self.straggler.stats().get('median_s', 0):.3f}s)")

            self.history.append({"step": s, "loss": loss, "time_s": dt,
                                 "tokens_per_s": t_tokens / max(dt, 1e-9)})
            if (s + 1) % self.tcfg.log_every == 0:
                print(f"[trainer] step {s+1} loss {loss:.4f} "
                      f"ce {float(metrics['ce']):.4f} {dt*1e3:.0f}ms")
            if self.ckpt is not None and (s + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(s + 1, state, blocking=not self.tcfg.async_ckpt)
                last_good = s + 1
        if self.ckpt is not None:
            self.ckpt.save(steps, state, blocking=True)
        return state

    def _batches(self, start_step: int):
        return self.pipeline.iterator(start_step)

    @staticmethod
    def _to_device(batch):
        return jax.tree.map(jax.numpy.asarray, batch)
