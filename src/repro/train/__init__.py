"""Training: microbatched step, compressed-gradient step, trainer loop."""
from .step import TrainState, init_state, make_train_step, make_train_step_compressed, loss_fn  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
