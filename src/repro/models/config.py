"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.quant import QuantPolicy, NONE as QUANT_NONE


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention variants
    causal: bool = True             # False => bidirectional encoder
    sliding_window: Optional[int] = None   # local attention window
    global_interval: int = 0        # gemma3: every k-th layer is global
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    qk_norm: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    moe_interval: int = 1           # MoE FFN every k-th layer (1 = all)
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_interval: int = 0          # hybrid: every k-th layer is attention

    # modality frontend (STUB per assignment: precomputed embeddings in)
    frontend: Optional[str] = None  # 'audio_stub' | 'vision_stub'
    frontend_tokens: int = 0        # prefix length contributed by frontend
    frontend_dim: int = 0           # embedding dim delivered by the stub

    # numerics / technique
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    quant: QuantPolicy = QUANT_NONE
    remat: str = "layer"            # none | layer
    scan_layers: bool = True
    tie_embeddings: bool = True

    # perf knobs (EXPERIMENTS.md §Perf hillclimb)
    cast_params_early: bool = False  # cast f32 master->compute dtype before
                                     # use so FSDP all-gathers ship bf16/f16
    shard_expert_cap: bool = False   # shard the MoE [E, C, D] dispatch
                                     # buffer's capacity dim over 'data'
    tp_bf16_reduce: bool = False     # dot outputs in compute dtype so the
                                     # TP partial-sum all-reduces ship bf16
                                     # (on-device MXU accumulation stays
                                     # wide; cross-shard sums round per
                                     # shard — the PDPU "acc in fmt_out"
                                     # contract applied across devices)
    fsdp_gather_weights: bool = False  # constrain weights to drop the FSDP
                                       # shard before each matmul: XLA then
                                       # all-gathers (bf16) weight shards
                                       # instead of partial-summing f32
                                       # activation tensors over 'data'
    moe_grouped_dispatch: bool = False  # GShard-style per-sequence routing
                                        # groups: sort/scatter are local to
                                        # each batch shard instead of one
                                        # global [T*k, D] gather/scatter
                                        # that SPMD resolves by replicate+
                                        # all-reduce (see EXPERIMENTS §Perf)

    # derived ---------------------------------------------------------------
    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_is_global(self, idx: int) -> bool:
        """gemma3-style 5 local : 1 global pattern."""
        if self.sliding_window is None or self.global_interval == 0:
            return True
        return (idx + 1) % self.global_interval == 0

    def layer_is_attn(self, idx: int) -> bool:
        """jamba-style 1 attention : 7 mamba pattern."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return self.attn_interval > 0 and idx % self.attn_interval == 0

    def layer_is_moe(self, idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return (idx % self.moe_interval) == (self.moe_interval - 1)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape '{name}'")
