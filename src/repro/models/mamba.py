"""Mamba2 (SSD — state-space duality) — mamba2-1.3b, and the Mamba sub-layers
of jamba-1.5-large.

Training/prefill runs the chunked SSD matmul form (MXU-friendly: intra-chunk
attention-like matmuls + an inter-chunk state scan).  Decode carries an O(1)
recurrent state per layer — this is why the 500k-context cell is assigned to
the SSM/hybrid families.

Per the paper's mixed-precision principle (DESIGN.md §Arch-applicability):
posit quantization applies to the in/out projections (dot products); the
recurrent state and the SSD scan stay f32 — a long dependent accumulation is
exactly the repeated-rounding failure mode PDPU's fused design eliminates,
so we keep the accumulator wide, as the paper keeps `acc` in fmt_out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from . import common
from .config import ModelConfig
from .module import ParamSpec

_G = 1  # B/C groups (mamba2 default ngroups=1)


def layer_param_specs(cfg: ModelConfig, L: int, prefix_axis="layers"):
    D = cfg.d_model
    Di = cfg.ssm_d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    conv_ch = Di + 2 * _G * N
    proj_out = 2 * Di + 2 * _G * N + H
    return {
        "ln": ParamSpec((L, D), (prefix_axis, None), "zeros"),
        "in_proj": ParamSpec((L, D, proj_out), (prefix_axis, "embed", "ssm_heads"), "fan_in"),
        "conv_w": ParamSpec((L, cfg.ssm_conv, conv_ch), (prefix_axis, None, "ssm_heads"), "fan_in"),
        "conv_b": ParamSpec((L, conv_ch), (prefix_axis, "ssm_heads"), "zeros"),
        "A_log": ParamSpec((L, H), (prefix_axis, None), "arange1"),
        "D_skip": ParamSpec((L, H), (prefix_axis, None), "ones"),
        "dt_bias": ParamSpec((L, H), (prefix_axis, None), "zeros"),
        "norm": ParamSpec((L, Di), (prefix_axis, "ssm_heads"), "zeros"),
        "out_proj": ParamSpec((L, Di, D), (prefix_axis, "ssm_heads", "embed"), "fan_in"),
    }


def param_specs(cfg: ModelConfig):
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    return {
        "embed": ParamSpec((V, D), ("vocab", "embed"), "embed"),
        "layers": layer_param_specs(cfg, L),
        "final_norm": ParamSpec((D,), (None,), "zeros"),
    }


# ---------------------------------------------------------------------------
# core SSD math
# ---------------------------------------------------------------------------


def _split_proj(cfg: ModelConfig, zxbcdt):
    Di, N = cfg.ssm_d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    z = zxbcdt[..., :Di]
    xs = zxbcdt[..., Di:2 * Di]
    B_ = zxbcdt[..., 2 * Di:2 * Di + _G * N]
    C_ = zxbcdt[..., 2 * Di + _G * N:2 * Di + 2 * _G * N]
    dt = zxbcdt[..., 2 * Di + 2 * _G * N:]
    return z, xs, B_, C_, dt


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv, taps K = w.shape[0]. xBC: [B, S, C].

    state: [B, K-1, C] trailing context (decode); returns (out, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # [B, S+K-1, C]
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    out = jax.nn.silu((out + b).astype(jnp.float32)).astype(xBC.dtype)
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out, new_state


def ssd_forward(cfg: ModelConfig, xs, B_, C_, dt, A_log, dt_bias,
                init_state=None):
    """Chunked SSD. xs: [B,S,Di]; B_/C_: [B,S,G*N]; dt: [B,S,H].

    Returns (y [B,S,Di], final_state [B,H,P,N]). f32 internal.
    """
    Bb, S, Di = xs.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by ssd chunk {Q}"
    nc = S // Q
    x = xs.reshape(Bb, nc, Q, H, P).astype(jnp.float32)
    Bm = B_.reshape(Bb, nc, Q, _G, N).astype(jnp.float32)
    Cm = C_.reshape(Bb, nc, Q, _G, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)  # [B,S,H]
    dt = dt.reshape(Bb, nc, Q, H)
    A = -jnp.exp(A_log.astype(jnp.float32))  # [H], negative
    dA = dt * A  # [B,nc,Q,H]
    cums = jnp.cumsum(dA, axis=2)  # inclusive cumulative decay within chunk

    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    idx = jnp.arange(Q)
    tri = idx[:, None] >= idx[None, :]  # [Q, Q] causal within chunk

    def chunk_step(state, blk):
        xq, Bq, Cq, dtq, cq, dAq = blk
        # xq [B,Q,H,P]; Bq/Cq [B,Q,G,N]; dtq/cq [B,Q,H]
        # ---- intra-chunk (attention-like) ----
        CB = jnp.einsum("bqgn,bkgn->bqk", Cq, Bq)  # G=1
        # clamp the masked (i<j) entries BEFORE exp: exp(+large) would be a
        # finite-forward/NaN-backward through the where (0 * inf in the vjp)
        dlt = jnp.minimum(cq[:, :, None, :] - cq[:, None, :, :], 0.0)
        decay = jnp.exp(dlt)  # [B,Q,K,H]
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        att = CB[..., None] * decay * dtq[:, None, :, :]  # [B,Q,K,H]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", att, xq)
        # ---- contribution from the carried state ----
        y_inter = jnp.einsum("bqgn,bhpn->bqhp", Cq, state) * \
            jnp.exp(cq)[..., None]
        # ---- update state ----
        decay_last = jnp.exp(jnp.minimum(cq[:, -1:, :] - cq, 0.0))  # [B,Q,H]
        dB = Bq[:, :, 0, :]  # G=1 -> [B,Q,N]
        s_local = jnp.einsum("bqh,bqn,bqhp->bhpn", decay_last * dtq, dB, xq)
        chunk_decay = jnp.exp(cq[:, -1, :])  # [B,H]
        state = state * chunk_decay[:, :, None, None] + s_local
        return state, y_intra + y_inter

    blks = tuple(jnp.moveaxis(t, 1, 0) for t in (x, Bm, Cm, dt, cums, dA))
    final_state, ys = jax.lax.scan(chunk_step, init_state, blks)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, final_state


def ssd_decode_step(cfg: ModelConfig, x1, B1, C1, dt1, A_log, dt_bias, state):
    """Single-token SSD recurrence. x1: [B,H,P]; B1/C1: [B,G*N]; dt1: [B,H].
    state: [B,H,P,N] -> (y [B,H,P], state')."""
    N = cfg.ssm_state
    dt = jax.nn.softplus(dt1.astype(jnp.float32) + dt_bias)  # [B,H]
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = jnp.exp(dt * A)  # [B,H]
    Bv = B1.reshape(-1, _G, N)[:, 0].astype(jnp.float32)  # [B,N]
    Cv = C1.reshape(-1, _G, N)[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv, x1.astype(jnp.float32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cv)
    return y, state


# ---------------------------------------------------------------------------
# full layer (+ model wrappers)
# ---------------------------------------------------------------------------


def mamba_block(p, x, cfg: ModelConfig, conv_state=None, ssm_state=None,
                single_step=False):
    """x: [B, S, D] (S==1 with single_step) -> (out, conv_state', ssm_state')."""
    B, S, D = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    h = common.rms_norm(x, p["ln"], upcast=not cfg.tp_bf16_reduce)
    zxbcdt = common.qdot(h, p["in_proj"], cfg.quant)
    z, xs, B_, C_, dt = _split_proj(cfg, zxbcdt)
    xBC = jnp.concatenate([xs, B_, C_], axis=-1)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    Di = cfg.ssm_d_inner
    xs, B_, C_ = (xBC[..., :Di], xBC[..., Di:Di + _G * cfg.ssm_state],
                  xBC[..., Di + _G * cfg.ssm_state:])
    if single_step:
        y, ssm_state = ssd_decode_step(
            cfg, xs[:, 0].reshape(B, H, P), B_[:, 0], C_[:, 0], dt[:, 0],
            p["A_log"], p["dt_bias"], ssm_state)
        y = y[:, None]  # [B,1,H,P]
        dskip = xs.reshape(B, S, H, P).astype(jnp.float32)
    else:
        y, ssm_state = ssd_forward(cfg, xs, B_, C_, dt, p["A_log"],
                                   p["dt_bias"], ssm_state)
        dskip = xs.reshape(B, S, H, P).astype(jnp.float32)
    y = y + dskip * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, Di).astype(x.dtype)
    y = common.rms_norm(y, p["norm"]) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = common.qdot(y, p["out_proj"], cfg.quant, prec_dtype=common.tp_prec(cfg))
    return out, conv_state, ssm_state


def apply(params, batch, cfg: ModelConfig):
    """Training/prefill forward -> logits [B, S, V]."""
    x = common.embed_tokens(params["embed"], batch["tokens"], cfg)

    def body(carry, layer_params):
        x = carry
        out, _, _ = mamba_block(layer_params, x, cfg)
        x = x + out
        x = sharding.constrain(x, ("batch", None, "embed_act"))
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "layer" else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"])
    return common.logits_head(x, params["embed"], cfg, transpose=True)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, layout=None):
    """SSM decode state: O(1) in sequence length (no KV cache).

    `layout` (a PagedLayout) is accepted for API uniformity and ignored:
    there are no KV pages to page — the recurrent state is already the
    minimal per-slot footprint, so paged and dense serving coincide."""
    L = cfg.n_layers
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.ssm_d_inner + 2 * _G * N
    return {
        "ssm": ParamSpec((L, batch, H, P, N), ("layers", "batch", "ssm_heads", None, None), "zeros"),
        "conv": ParamSpec((L, batch, cfg.ssm_conv - 1, conv_ch),
                          ("layers", "batch", None, "ssm_heads"), "zeros", jnp.float32),
        "length": ParamSpec((batch,), ("batch",), "zeros", jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, layout=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_seq, layout),
                        is_leaf=lambda s: isinstance(s, ParamSpec))


def prefill(params, batch, cfg: ModelConfig, max_seq=None):
    x = common.embed_tokens(params["embed"], batch["tokens"], cfg)

    def body(x, layer_params):
        out, conv_s, ssm_s = mamba_block(layer_params, x, cfg)
        x = x + out
        return x, (conv_s, ssm_s)

    x, (conv_s, ssm_s) = jax.lax.scan(body, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    B, S = batch["tokens"].shape
    cache = {"ssm": ssm_s, "conv": conv_s.astype(jnp.float32),
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def prefill_chunk(params, tokens, cache, slot, cfg: ModelConfig,
                  shard=None):
    """Chunked prefill for one slot: run the SSD forward over chunk
    `tokens` [1, C] seeded with the slot's carried conv/SSM states (the
    recurrence is exact under chunking — state in, state out).  Returns
    the last position's logits [1, 1, V] only.  Chunk sizes
    C > cfg.ssm_chunk must be multiples of it (the serving engine's
    bucket table guarantees this)."""
    if shard is not None:
        raise ValueError("ssm state is replicated; kv_pages sharding does "
                         "not apply to the mamba family")
    C = tokens.shape[1]
    x = common.embed_tokens(params["embed"], tokens, cfg)
    conv_s = jax.lax.dynamic_slice_in_dim(cache["conv"], slot, 1, axis=1)
    ssm_s = jax.lax.dynamic_slice_in_dim(cache["ssm"], slot, 1, axis=1)

    def body(x, xs):
        p, cs, ss = xs
        out, cs2, ss2 = mamba_block(p, x, cfg, conv_state=cs, ssm_state=ss)
        return x + out, (cs2, ss2)

    x, (conv2, ssm2) = jax.lax.scan(
        body, x, (params["layers"], conv_s, ssm_s))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    start = cache["length"][slot]
    new_cache = dict(cache)
    new_cache.update(
        conv=cache["conv"].at[:, slot].set(conv2[:, 0].astype(jnp.float32)),
        ssm=cache["ssm"].at[:, slot].set(ssm2[:, 0]),
        length=cache["length"].at[slot].set(start + C))
    return logits, new_cache


def prefill_chunk_batched(params, tokens, cache, active, cfg: ModelConfig,
                          shard=None):
    """Cross-slot batched chunked prefill: every active slot advances one
    chunk [B, C] through the SSD forward seeded with its own carried
    conv/SSM state; inactive rows compute on padding and are reverted
    against the input cache.  Returns (last-position logits [B, V],
    cache')."""
    if shard is not None:
        raise ValueError("ssm state is replicated; kv_pages sharding does "
                         "not apply to the mamba family")
    B, C = tokens.shape
    x = common.embed_tokens(params["embed"], tokens, cfg)

    def body(x, xs):
        p, cs, ss = xs
        out, cs2, ss2 = mamba_block(p, x, cfg, conv_state=cs, ssm_state=ss)
        return x + out, (cs2, ss2)

    x, (conv2, ssm2) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    new_cache = dict(cache)
    new_cache.update(
        conv=jnp.where(active[None, :, None, None],
                       conv2.astype(jnp.float32), cache["conv"]),
        ssm=jnp.where(active[None, :, None, None, None], ssm2, cache["ssm"]),
        length=cache["length"] + jnp.where(active, C, 0).astype(jnp.int32))
    return logits[:, 0], new_cache


def decode_step(params, tokens, cache, cfg: ModelConfig, shard=None,
                sample=None):
    if shard is not None:
        raise ValueError("ssm state is replicated; kv_pages sharding does "
                         "not apply to the mamba family")
    B = tokens.shape[0]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)

    def body(x, xs):
        layer_params, conv_s, ssm_s = xs
        out, conv_s, ssm_s = mamba_block(
            layer_params, x, cfg, conv_state=conv_s, ssm_state=ssm_s,
            single_step=True)
        return x + out, (conv_s, ssm_s)

    x, (conv_s, ssm_s) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"ssm": ssm_s, "conv": conv_s, "length": cache["length"] + 1}
    if sample is not None:
        return common.sample_head(x[:, 0], params["embed"], cfg, sample,
                                  transpose=True), new_cache
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    return logits[:, 0], new_cache
