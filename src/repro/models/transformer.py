"""Dense GQA transformer LM — command-r-35b / command-r-plus-104b /
minitron-8b / gemma3-4b (5:1 local:global) / hubert-xlarge (encoder) /
paligemma-3b (vlm backbone + stub frontend).

Functional style: `param_specs` / `init` / `apply` (train-prefill) /
`prefill` / `decode_step` (serving).  Layers are stacked on a leading
'layers' dim and scanned (compile time O(1) in depth); heterogeneous
attention patterns (gemma3 local/global) ride along as per-layer scanned
flags so the stack stays homogeneous.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import sharding
from . import common
from .config import ModelConfig
from .module import ParamSpec

# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    Hq, Hkv, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    layers = {
        "ln1": ParamSpec((L, D), ("layers", None), "zeros"),
        "ln2": ParamSpec((L, D), ("layers", None), "zeros"),
        "wq": ParamSpec((L, D, Hq * Dh), ("layers", "embed", "heads"), "fan_in"),
        "wk": ParamSpec((L, D, Hkv * Dh), ("layers", "embed", "heads"), "fan_in"),
        "wv": ParamSpec((L, D, Hkv * Dh), ("layers", "embed", "heads"), "fan_in"),
        "wo": ParamSpec((L, Hq * Dh, D), ("layers", "heads", "embed"), "fan_in"),
        "wi_gate": ParamSpec((L, D, F), ("layers", "embed", "mlp"), "fan_in"),
        "wi_up": ParamSpec((L, D, F), ("layers", "embed", "mlp"), "fan_in"),
        "wo_mlp": ParamSpec((L, F, D), ("layers", "mlp", "embed"), "fan_in"),
    }
    if cfg.qk_norm:
        layers["q_norm"] = ParamSpec((L, Dh), ("layers", None), "zeros")
        layers["k_norm"] = ParamSpec((L, Dh), ("layers", None), "zeros")
    specs = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), "embed"),
        "layers": layers,
        "final_norm": ParamSpec((D,), (None,), "zeros"),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((D, V), ("embed", "vocab"), "fan_in")
    if cfg.frontend is not None:
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, D), (None, "embed"), "fan_in")
    return specs


def layer_flags(cfg: ModelConfig):
    """Per-layer scanned metadata: is_global (full attention) flag."""
    return jnp.asarray(
        np.array([cfg.layer_is_global(i) for i in range(cfg.n_layers)]),
        jnp.bool_)


# ---------------------------------------------------------------------------
# one transformer layer (scanned)
# ---------------------------------------------------------------------------


def _attn_block(p, x, cfg: ModelConfig, q_pos, kv_pos, is_global):
    """Self-attention sub-block; returns (out, k, v) (k/v for cache)."""
    B, S, D = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
    q = common.qdot(h, common.wgather(cfg, p["wq"], (None, "heads")),
                    cfg.quant).reshape(B, S, Hq, Dh)
    k = common.qdot(h, common.wgather(cfg, p["wk"], (None, "heads")),
                    cfg.quant).reshape(B, S, Hkv, Dh)
    v = common.qdot(h, common.wgather(cfg, p["wv"], (None, "heads")),
                    cfg.quant).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    q = common.rope(q, q_pos, cfg.rope_theta)
    k = common.rope(k, kv_pos, cfg.rope_theta)
    q = sharding.constrain(q, ("batch", None, "heads", None))
    k = sharding.constrain(k, ("batch", None, "kv_heads", None))

    if cfg.sliding_window is not None:
        # dynamic per-layer window: global layers get an unbounded window
        window = jnp.where(is_global, jnp.int32(2**30),
                           jnp.int32(cfg.sliding_window))
    else:
        window = None
    attn = common.flash_attention(
        q, k, v, q_pos, kv_pos, causal=cfg.causal, window=window,
        softcap_val=cfg.logit_softcap)
    out = common.qdot(attn.reshape(B, S, Hq * Dh),
                      common.wgather(cfg, p["wo"], ("heads", None)),
                      cfg.quant, prec_dtype=common.tp_prec(cfg))
    return out, k, v


def _mlp_block(p, x, cfg: ModelConfig):
    h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
    g = common.qdot(h, common.wgather(cfg, p["wi_gate"], (None, "mlp")), cfg.quant)
    u = common.qdot(h, common.wgather(cfg, p["wi_up"], (None, "mlp")), cfg.quant)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = sharding.constrain(h, ("batch", None, "mlp"))
    return common.qdot(h, common.wgather(cfg, p["wo_mlp"], ("mlp", None)),
                       cfg.quant, prec_dtype=common.tp_prec(cfg))


def _layer(p, x, cfg: ModelConfig, q_pos, kv_pos, is_global):
    attn, k, v = _attn_block(p, x, cfg, q_pos, kv_pos, is_global)
    x = x + attn
    x = x + _mlp_block(p, x, cfg)
    x = sharding.constrain(x, ("batch", None, "embed_act"))
    return x, (k, v)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (+ optional stub-frontend embeddings) -> [B, S, D]."""
    if cfg.frontend is None:
        x = common.embed_tokens(params["embed"], batch["tokens"], cfg)
    elif cfg.frontend == "audio_stub":
        # encoder consumes precomputed frame embeddings only
        x = jnp.dot(batch["frames"].astype(cfg.compute_dtype),
                    params["frontend_proj"].astype(cfg.compute_dtype))
    elif cfg.frontend == "vision_stub":
        patches = jnp.dot(batch["patches"].astype(cfg.compute_dtype),
                          params["frontend_proj"].astype(cfg.compute_dtype))
        text = common.embed_tokens(params["embed"], batch["tokens"], cfg)
        x = jnp.concatenate([patches, text], axis=1)
    else:
        raise ValueError(cfg.frontend)
    return sharding.constrain(x, ("batch", None, "embed_act"))


def apply(params, batch, cfg: ModelConfig, collect_cache: bool = False):
    """Training/prefill forward. batch: {tokens[B,S], (frames|patches)}.

    Returns logits [B, S, V] (and the per-layer KV stack if collect_cache).
    """
    x = _embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    flags = layer_flags(cfg)

    def body(carry, xs):
        layer_params, is_global = xs
        x = carry
        x, kv = _layer(layer_params, x, cfg, pos, pos, is_global)
        return x, kv if collect_cache else None

    body_fn = body
    if cfg.remat == "layer":
        body_fn = jax.checkpoint(body, prevent_cse=False)
    x, kvs = jax.lax.scan(body_fn, x, (params["layers"], flags))
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    if collect_cache:
        return logits, kvs
    return logits


# ---------------------------------------------------------------------------
# serving: cache container + prefill + decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """Abstract KV cache: [L, B, S, Hkv*Dh] for k and v (possibly posit)."""
    dt = common.kv_store_dtype(cfg)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads * cfg.head_dim)
    axes = ("layers", "batch", "kv_seq", "kv_heads")
    return {
        "k": ParamSpec(shape, axes, "zeros", dt),
        "v": ParamSpec(shape, axes, "zeros", dt),
        "length": ParamSpec((batch,), ("batch",), "zeros", jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_specs(cfg, batch, max_seq),
        is_leaf=lambda s: isinstance(s, ParamSpec))


def prefill(params, batch, cfg: ModelConfig, max_seq: Optional[int] = None):
    """Full-sequence forward that also builds the KV cache."""
    logits, (ks, vs) = apply(params, batch, cfg, collect_cache=True)
    B, S = ks.shape[1], ks.shape[2]
    max_seq = max_seq or S
    fold = lambda t: common.kv_encode(cfg, t.reshape(cfg.n_layers, B, S, -1))
    k_cache, v_cache = fold(ks), fold(vs)
    if max_seq > S:
        pad = ((0, 0), (0, 0), (0, max_seq - S), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    cache = {"k": k_cache, "v": v_cache,
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    """One autoregressive step. tokens: [B] int32. Returns (logits, cache')."""
    B = tokens.shape[0]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    S_max = cache["k"].shape[2]
    length = cache["length"]
    q_pos = length[:, None]  # [B, 1]
    kv_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None], (B, S_max))
    flags = layer_flags(cfg)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
        q = common.qdot(h, p["wq"], cfg.quant).reshape(B, 1, cfg.n_heads, Dh)
        k = common.qdot(h, p["wk"], cfg.quant).reshape(B, 1, Hkv, Dh)
        v = common.qdot(h, p["wv"], cfg.quant).reshape(B, 1, Hkv, Dh)
        if cfg.qk_norm:
            q = common.rms_norm(q, p["q_norm"])
            k = common.rms_norm(k, p["k_norm"])
        q = common.rope(q, q_pos, cfg.rope_theta)
        k = common.rope(k, q_pos, cfg.rope_theta)
        # append to cache at position `length` (per batch row)
        k_new = _cache_insert(k_l, common.kv_encode(cfg, k.reshape(B, 1, -1)), length)
        v_new = _cache_insert(v_l, common.kv_encode(cfg, v.reshape(B, 1, -1)), length)
        kc = common.kv_decode(cfg, k_new).reshape(B, S_max, Hkv, Dh)
        vc = common.kv_decode(cfg, v_new).reshape(B, S_max, Hkv, Dh)
        if cfg.sliding_window is not None:
            window = jnp.where(is_global, jnp.int32(2**30),
                               jnp.int32(cfg.sliding_window))
        else:
            window = None
        attn = common.decode_attention(
            q, kc, vc, length + 1, kv_pos, window=window,
            softcap_val=cfg.logit_softcap)
        out = common.qdot(attn.reshape(B, 1, cfg.n_heads * Dh), p["wo"], cfg.quant)
        x = x + out
        x = x + _mlp_block(p, x, cfg)
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    new_cache = {"k": k_c, "v": v_c, "length": length + 1}
    return logits[:, 0], new_cache


def _cache_insert(cache_l, new_kv, length):
    """cache_l: [B, S, F]; new_kv: [B, 1, F]; write row b at length[b].

    Scatter (not a one-hot rewrite): only the touched rows hit HBM, so
    decode cache traffic is read-dominated — matters for the memory
    roofline at 32k/500k contexts."""
    B = cache_l.shape[0]
    return cache_l.at[jnp.arange(B), length].set(new_kv[:, 0].astype(cache_l.dtype))
