"""Dense GQA transformer LM — command-r-35b / command-r-plus-104b /
minitron-8b / gemma3-4b (5:1 local:global) / hubert-xlarge (encoder) /
paligemma-3b (vlm backbone + stub frontend).

Functional style: `param_specs` / `init` / `apply` (train-prefill) /
`prefill` / `decode_step` (serving).  Layers are stacked on a leading
'layers' dim and scanned (compile time O(1) in depth); heterogeneous
attention patterns (gemma3 local/global) ride along as per-layer scanned
flags so the stack stays homogeneous.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.parallel import sharding
from . import common, paged
from .config import ModelConfig
from .module import ParamSpec
from .paged import PagedLayout

# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    Hq, Hkv, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    layers = {
        "ln1": ParamSpec((L, D), ("layers", None), "zeros"),
        "ln2": ParamSpec((L, D), ("layers", None), "zeros"),
        "wq": ParamSpec((L, D, Hq * Dh), ("layers", "embed", "heads"), "fan_in"),
        "wk": ParamSpec((L, D, Hkv * Dh), ("layers", "embed", "heads"), "fan_in"),
        "wv": ParamSpec((L, D, Hkv * Dh), ("layers", "embed", "heads"), "fan_in"),
        "wo": ParamSpec((L, Hq * Dh, D), ("layers", "heads", "embed"), "fan_in"),
        "wi_gate": ParamSpec((L, D, F), ("layers", "embed", "mlp"), "fan_in"),
        "wi_up": ParamSpec((L, D, F), ("layers", "embed", "mlp"), "fan_in"),
        "wo_mlp": ParamSpec((L, F, D), ("layers", "mlp", "embed"), "fan_in"),
    }
    if cfg.qk_norm:
        layers["q_norm"] = ParamSpec((L, Dh), ("layers", None), "zeros")
        layers["k_norm"] = ParamSpec((L, Dh), ("layers", None), "zeros")
    specs = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), "embed"),
        "layers": layers,
        "final_norm": ParamSpec((D,), (None,), "zeros"),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((D, V), ("embed", "vocab"), "fan_in")
    if cfg.frontend is not None:
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, D), (None, "embed"), "fan_in")
    return specs


def layer_flags(cfg: ModelConfig):
    """Per-layer scanned metadata: is_global (full attention) flag."""
    return jnp.asarray(
        np.array([cfg.layer_is_global(i) for i in range(cfg.n_layers)]),
        jnp.bool_)


# ---------------------------------------------------------------------------
# one transformer layer (scanned)
# ---------------------------------------------------------------------------


def _attn_block(p, x, cfg: ModelConfig, q_pos, kv_pos, is_global):
    """Self-attention sub-block; returns (out, k, v) (k/v for cache)."""
    B, S, D = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
    q = common.qdot(h, common.wgather(cfg, p["wq"], (None, "heads")),
                    cfg.quant).reshape(B, S, Hq, Dh)
    k = common.qdot(h, common.wgather(cfg, p["wk"], (None, "heads")),
                    cfg.quant).reshape(B, S, Hkv, Dh)
    v = common.qdot(h, common.wgather(cfg, p["wv"], (None, "heads")),
                    cfg.quant).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    q = common.rope(q, q_pos, cfg.rope_theta)
    k = common.rope(k, kv_pos, cfg.rope_theta)
    q = sharding.constrain(q, ("batch", None, "heads", None))
    k = sharding.constrain(k, ("batch", None, "kv_heads", None))

    if cfg.sliding_window is not None:
        # dynamic per-layer window: global layers get an unbounded window
        window = jnp.where(is_global, jnp.int32(2**30),
                           jnp.int32(cfg.sliding_window))
    else:
        window = None
    attn = common.flash_attention(
        q, k, v, q_pos, kv_pos, causal=cfg.causal, window=window,
        softcap_val=cfg.logit_softcap)
    out = common.qdot(attn.reshape(B, S, Hq * Dh),
                      common.wgather(cfg, p["wo"], ("heads", None)),
                      cfg.quant, prec_dtype=common.tp_prec(cfg))
    return out, k, v


def _mlp_block(p, x, cfg: ModelConfig):
    h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
    g = common.qdot(h, common.wgather(cfg, p["wi_gate"], (None, "mlp")), cfg.quant)
    u = common.qdot(h, common.wgather(cfg, p["wi_up"], (None, "mlp")), cfg.quant)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = sharding.constrain(h, ("batch", None, "mlp"))
    return common.qdot(h, common.wgather(cfg, p["wo_mlp"], ("mlp", None)),
                       cfg.quant, prec_dtype=common.tp_prec(cfg))


def _layer(p, x, cfg: ModelConfig, q_pos, kv_pos, is_global):
    attn, k, v = _attn_block(p, x, cfg, q_pos, kv_pos, is_global)
    x = x + attn
    x = x + _mlp_block(p, x, cfg)
    x = sharding.constrain(x, ("batch", None, "embed_act"))
    return x, (k, v)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (+ optional stub-frontend embeddings) -> [B, S, D]."""
    if cfg.frontend is None:
        x = common.embed_tokens(params["embed"], batch["tokens"], cfg)
    elif cfg.frontend == "audio_stub":
        # encoder consumes precomputed frame embeddings only
        x = jnp.dot(batch["frames"].astype(cfg.compute_dtype),
                    params["frontend_proj"].astype(cfg.compute_dtype))
    elif cfg.frontend == "vision_stub":
        patches = jnp.dot(batch["patches"].astype(cfg.compute_dtype),
                          params["frontend_proj"].astype(cfg.compute_dtype))
        text = common.embed_tokens(params["embed"], batch["tokens"], cfg)
        x = jnp.concatenate([patches, text], axis=1)
    else:
        raise ValueError(cfg.frontend)
    return sharding.constrain(x, ("batch", None, "embed_act"))


def apply(params, batch, cfg: ModelConfig, collect_cache: bool = False):
    """Training/prefill forward. batch: {tokens[B,S], (frames|patches)}.

    Returns logits [B, S, V] (and the per-layer KV stack if collect_cache).
    """
    x = _embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    flags = layer_flags(cfg)

    def body(carry, xs):
        layer_params, is_global = xs
        x = carry
        x, kv = _layer(layer_params, x, cfg, pos, pos, is_global)
        return x, kv if collect_cache else None

    body_fn = body
    if cfg.remat == "layer":
        body_fn = jax.checkpoint(body, prevent_cse=False)
    x, kvs = jax.lax.scan(body_fn, x, (params["layers"], flags))
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    if collect_cache:
        return logits, kvs
    return logits


# ---------------------------------------------------------------------------
# serving: cache container + prefill + decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                layout: Optional[PagedLayout] = None):
    """Abstract KV cache.  layout=None: dense [L, B, S, Hkv*Dh] per k/v.
    With a PagedLayout: a page pool [L, n_pages, page_size, Hkv*Dh] at KV
    code width plus per-slot block tables (see models/paged.py)."""
    dt = common.kv_store_dtype(cfg)
    if layout is None:
        shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads * cfg.head_dim)
        axes = ("layers", "batch", "kv_seq", "kv_heads")
        return {
            "k": ParamSpec(shape, axes, "zeros", dt),
            "v": ParamSpec(shape, axes, "zeros", dt),
            "length": ParamSpec((batch,), ("batch",), "zeros", jnp.int32),
        }
    shape = (cfg.n_layers, layout.n_pages, layout.page_size,
             cfg.n_kv_heads * cfg.head_dim)
    axes = ("layers", "kv_pages", None, "kv_heads")
    return {
        "k": ParamSpec(shape, axes, "zeros", dt),
        "v": ParamSpec(shape, axes, "zeros", dt),
        "block_table": ParamSpec((batch, layout.pages_per_slot(max_seq)),
                                 ("batch", None), "zeros", jnp.int32),
        "length": ParamSpec((batch,), ("batch",), "zeros", jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               layout: Optional[PagedLayout] = None):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_specs(cfg, batch, max_seq, layout),
        is_leaf=lambda s: isinstance(s, ParamSpec))


def prefill(params, batch, cfg: ModelConfig, max_seq: Optional[int] = None):
    """Full-sequence forward that also builds the KV cache."""
    logits, (ks, vs) = apply(params, batch, cfg, collect_cache=True)
    B, S = ks.shape[1], ks.shape[2]
    max_seq = max_seq or S
    fold = lambda t: common.kv_encode(cfg, t.reshape(cfg.n_layers, B, S, -1))
    k_cache, v_cache = fold(ks), fold(vs)
    if max_seq > S:
        pad = ((0, 0), (0, 0), (0, max_seq - S), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    cache = {"k": k_cache, "v": v_cache,
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(params, tokens, cache, cfg: ModelConfig, shard=None,
                sample=None):
    """One autoregressive step. tokens: [B] int32. Returns (logits, cache').

    shard: optional paged.PageShard — the paged KV pool is kv_pages-sharded
    and this call runs inside a shard_map over that axis (block tables hold
    global page ids; see models/paged.py).

    sample: optional common.SampleSpec — fuse the logits head and the
    sampling epilogue into one device program (common.sample_head) and
    return ([B] int32 tokens, cache') instead of logits."""
    if "block_table" in cache:
        return _decode_step_paged(params, tokens, cache, cfg, shard=shard,
                                  sample=sample)
    if shard is not None:
        raise ValueError("kv_pages sharding requires a paged cache")
    B = tokens.shape[0]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    S_max = cache["k"].shape[2]
    length = cache["length"]
    q_pos = length[:, None]  # [B, 1]
    kv_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None], (B, S_max))
    flags = layer_flags(cfg)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
        q = common.qdot(h, p["wq"], cfg.quant).reshape(B, 1, cfg.n_heads, Dh)
        k = common.qdot(h, p["wk"], cfg.quant).reshape(B, 1, Hkv, Dh)
        v = common.qdot(h, p["wv"], cfg.quant).reshape(B, 1, Hkv, Dh)
        if cfg.qk_norm:
            q = common.rms_norm(q, p["q_norm"])
            k = common.rms_norm(k, p["k_norm"])
        q = common.rope(q, q_pos, cfg.rope_theta)
        k = common.rope(k, q_pos, cfg.rope_theta)
        # append to cache at position `length` (per batch row)
        k_new = _cache_insert(k_l, common.kv_encode(cfg, k.reshape(B, 1, -1)), length)
        v_new = _cache_insert(v_l, common.kv_encode(cfg, v.reshape(B, 1, -1)), length)
        kc = common.kv_decode(cfg, k_new).reshape(B, S_max, Hkv, Dh)
        vc = common.kv_decode(cfg, v_new).reshape(B, S_max, Hkv, Dh)
        if cfg.sliding_window is not None:
            window = jnp.where(is_global, jnp.int32(2**30),
                               jnp.int32(cfg.sliding_window))
        else:
            window = None
        attn = common.decode_attention(
            q, kc, vc, length + 1, kv_pos, window=window,
            softcap_val=cfg.logit_softcap)
        out = common.qdot(attn.reshape(B, 1, cfg.n_heads * Dh), p["wo"], cfg.quant)
        x = x + out
        x = x + _mlp_block(p, x, cfg)
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "length": length + 1}
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if sample is not None:
        return common.sample_head(x[:, 0], head, cfg, sample,
                                  transpose=cfg.tie_embeddings), new_cache
    logits = common.logits_head(x, head, cfg, transpose=cfg.tie_embeddings)
    return logits[:, 0], new_cache


def _cache_insert(cache_l, new_kv, length):
    """cache_l: [B, S, F]; new_kv: [B, 1, F]; write row b at length[b].

    Scatter (not a one-hot rewrite): only the touched rows hit HBM, so
    decode cache traffic is read-dominated — matters for the memory
    roofline at 32k/500k contexts."""
    B = cache_l.shape[0]
    return cache_l.at[jnp.arange(B), length].set(new_kv[:, 0].astype(cache_l.dtype))


# ---------------------------------------------------------------------------
# paged serving: block-table decode + chunked prefill
# (shared by the moe and hybrid families, which import these helpers)
# ---------------------------------------------------------------------------


def _window_arr(cfg: ModelConfig, is_global):
    """Per-layer sliding window as a [1] i32 array for the paged kernel."""
    if cfg.sliding_window is None:
        return jnp.full((1,), 2**30, jnp.int32)
    return jnp.where(is_global, jnp.int32(2**30),
                     jnp.int32(cfg.sliding_window)).reshape(1)


def _paged_attn_token(p, x, cfg: ModelConfig, k_l, v_l, bt, length, is_global,
                      shard=None):
    """One-token attention sub-block over paged KV (decode hot path).

    x: [B, 1, D]; k_l/v_l: [n_pages, ps, Hkv*Dh] page pools; bt: [B, M];
    length: [B] pre-insert valid counts.  Writes the new token's KV codes
    at position `length`, then runs the Pallas paged-attention kernel
    (block-table gather + in-kernel posit decode).  Returns
    (post-wo output [B, 1, D], k_pool', v_pool').

    Under a kv_pages shard each device runs the kernel over only the pages
    it owns (block table localized, non-owned pages masked via page_ok,
    partials=True) and the per-shard streaming-softmax states are log-sum-
    exp merged — sequence-parallel paged attention, bitwise identical to
    the single pool whenever a slot's pages live on one shard.
    """
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
    q = common.qdot(h, p["wq"], cfg.quant).reshape(B, 1, Hq, Dh)
    k = common.qdot(h, p["wk"], cfg.quant).reshape(B, 1, Hkv, Dh)
    v = common.qdot(h, p["wv"], cfg.quant).reshape(B, 1, Hkv, Dh)
    if cfg.qk_norm and "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    q_pos = length[:, None]
    q = common.rope(q, q_pos, cfg.rope_theta)
    k = common.rope(k, q_pos, cfg.rope_theta)
    k_new = paged.insert_tokens(k_l, bt, length,
                                common.kv_encode(cfg, k.reshape(B, -1)),
                                shard=shard)
    v_new = paged.insert_tokens(v_l, bt, length,
                                common.kv_encode(cfg, v.reshape(B, -1)),
                                shard=shard)
    if shard is None:
        attn = ops.paged_attention(
            q.reshape(B, Hq, Dh), k_new, v_new, bt, length + 1,
            _window_arr(cfg, is_global), fmt_kv=cfg.quant.kv_cache,
            softcap_val=cfg.logit_softcap)
    else:
        lbt, owned = paged.localize_ids(bt, k_l.shape[0], shard)
        o, m, l = ops.paged_attention(
            q.reshape(B, Hq, Dh), k_new, v_new, lbt, length + 1,
            _window_arr(cfg, is_global), fmt_kv=cfg.quant.kv_cache,
            softcap_val=cfg.logit_softcap,
            page_ok=owned.astype(jnp.int32), partials=True)
        attn = ops.merge_attn_partials(o, m, l, shard.axis)
    out = common.qdot(attn.reshape(B, 1, Hq * Dh).astype(x.dtype),
                      p["wo"], cfg.quant)
    return out, k_new, v_new


def _chunk_attn(p, x, cfg: ModelConfig, k_l, v_l, start, *,
                bt_row=None, slot=None, is_global=None, shard=None):
    """Prefill-chunk attention for one slot: queries at positions
    start + [0, C) attend the slot's cached history plus themselves.

    x: [1, C, D].  Paged mode (bt_row [M]): history is gathered by block
    table and chunk KV codes are scattered into the page pool.  Dense mode
    (slot scalar): history is the slot's cache row, codes land at
    [slot, start:start+C].  Intra-chunk attention uses the *raw* (pre-
    encode) k/v — matching dense whole-prompt prefill semantics, where
    only re-reads of the cache see quantized values.  Returns
    (post-wo output [1, C, D], k_cache', v_cache').

    When `cfg.quant.fused_prefill` is on and the geometry passes
    `paged.fused_prefill_span_ok`, the paged branch runs the fused Pallas
    program (ops.prefill_attention_paged): attention + KV encode + page
    scatter in one device call, bit-identical to the decomposed path
    below at any span (history beyond one flash chunk streams through
    the kernel's running softmax).  Under a kv_pages shard the exact
    global pool is all-gathered for history staging and page writes are
    masked to owned pages.
    """
    _, C, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
    q = common.qdot(h, p["wq"], cfg.quant).reshape(1, C, Hq, Dh)
    k = common.qdot(h, p["wk"], cfg.quant).reshape(1, C, Hkv, Dh)
    v = common.qdot(h, p["wv"], cfg.quant).reshape(1, C, Hkv, Dh)
    if cfg.qk_norm and "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    pos = start + jnp.arange(C, dtype=jnp.int32)
    q_pos = pos[None]
    q = common.rope(q, q_pos, cfg.rope_theta)
    k = common.rope(k, q_pos, cfg.rope_theta)
    if (bt_row is not None and cfg.quant.fused_prefill
            and paged.fused_prefill_span_ok(bt_row.shape[0], k_l.shape[1], C)):
        win = _window_arr(cfg, is_global)
        starts1 = jnp.reshape(start, (1,)).astype(jnp.int32)
        if shard is None:
            attn, k_new, v_new = ops.prefill_attention_paged(
                q, k, v, k_l, v_l, bt_row[None], starts1, win,
                fmt_kv=cfg.quant.kv_cache, compute_dtype=cfg.compute_dtype,
                softcap_val=cfg.logit_softcap,
                flash_chunk=paged.FLASH_CHUNK)
        else:
            # history pages can live on any shard: all-gather the code-
            # width pool so each shard stages identical history (and thus
            # computes bit-identical attention); writes stay masked to
            # owned pages via the localized table + page_ok
            gk = jax.lax.all_gather(k_l, shard.axis, axis=0, tiled=True)
            gv = jax.lax.all_gather(v_l, shard.axis, axis=0, tiled=True)
            lbt, owned = paged.localize_ids(bt_row[None], k_l.shape[0], shard)
            attn, k_new, v_new = ops.prefill_attention_paged(
                q, k, v, k_l, v_l, lbt, starts1, win,
                fmt_kv=cfg.quant.kv_cache, compute_dtype=cfg.compute_dtype,
                softcap_val=cfg.logit_softcap,
                flash_chunk=paged.FLASH_CHUNK,
                hist_pool_k=gk, hist_pool_v=gv, hist_bt=bt_row[None],
                page_ok=owned.astype(jnp.int32))
        out = common.qdot(attn.reshape(1, C, Hq * Dh), p["wo"], cfg.quant,
                          prec_dtype=common.tp_prec(cfg))
        return out, k_new, v_new
    k_codes = common.kv_encode(cfg, k.reshape(C, -1))
    v_codes = common.kv_encode(cfg, v.reshape(C, -1))
    if bt_row is not None:
        # under a kv_pages shard the gather is a psum over owned pages —
        # exact, so chunked prefill stays bit-identical to the single pool
        hist_k, hist_v = (paged.gather_slot(k_l, bt_row, shard=shard),
                          paged.gather_slot(v_l, bt_row, shard=shard))
        k_new = paged.insert_chunk(k_l, bt_row, start, k_codes, shard=shard)
        v_new = paged.insert_chunk(v_l, bt_row, start, v_codes, shard=shard)
    else:
        hist_k, hist_v = k_l[slot], v_l[slot]
        k_new = k_l.at[slot, pos].set(k_codes.astype(k_l.dtype))
        v_new = v_l.at[slot, pos].set(v_codes.astype(v_l.dtype))
    S_h = hist_k.shape[0]
    hist_pos = jnp.arange(S_h, dtype=jnp.int32)
    hist_pos = jnp.where(hist_pos < start, hist_pos, -1)[None]  # unwritten
    kd = common.kv_decode(cfg, hist_k).reshape(1, S_h, Hkv, Dh).astype(k.dtype)
    vd = common.kv_decode(cfg, hist_v).reshape(1, S_h, Hkv, Dh).astype(v.dtype)
    k_all = jnp.concatenate([kd, k], axis=1)
    v_all = jnp.concatenate([vd, v], axis=1)
    kv_pos = jnp.concatenate([hist_pos, q_pos], axis=1)
    if cfg.sliding_window is not None:
        window = jnp.where(is_global, jnp.int32(2**30),
                           jnp.int32(cfg.sliding_window))
    else:
        window = None
    attn = common.flash_attention(
        q, k_all, v_all, q_pos, kv_pos, causal=True, window=window,
        chunk_k=paged.FLASH_CHUNK, softcap_val=cfg.logit_softcap)
    out = common.qdot(attn.reshape(1, C, Hq * Dh), p["wo"], cfg.quant,
                      prec_dtype=common.tp_prec(cfg))
    return out, k_new, v_new


def _chunk_attn_batched(p, x, cfg: ModelConfig, k_l, v_l, starts, *,
                        bt=None, is_global=None, shard=None):
    """Cross-slot batched prefill-chunk attention: queries of slot b sit at
    positions starts[b] + [0, C) and attend that slot's cached history plus
    themselves.  x: [B, C, D]; starts: [B] (0 for inactive rows).  Paged
    mode (bt [B, M], inactive rows zeroed -> trash page): history is
    gathered per slot by block table and chunk KV codes scatter into the
    shared page pool in one batched write.  Dense mode (k_l [B, S, F]):
    codes land at [b, starts[b] + j] — callers revert inactive rows.  Rows
    are computationally independent, so each active row is bit-identical
    to the per-slot `_chunk_attn` path.  Returns
    (post-wo output [B, C, D], k_cache', v_cache').

    Fuses like `_chunk_attn`: with `cfg.quant.fused_prefill` and a
    geometry passing `paged.fused_prefill_span_ok`, the whole paged
    branch is one Pallas program per chunk group
    (ops.prefill_attention_paged) at any history span."""
    B, C, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
    q = common.qdot(h, p["wq"], cfg.quant).reshape(B, C, Hq, Dh)
    k = common.qdot(h, p["wk"], cfg.quant).reshape(B, C, Hkv, Dh)
    v = common.qdot(h, p["wv"], cfg.quant).reshape(B, C, Hkv, Dh)
    if cfg.qk_norm and "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    pos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None]    # [B, C]
    q = common.rope(q, pos, cfg.rope_theta)
    k = common.rope(k, pos, cfg.rope_theta)
    if (bt is not None and cfg.quant.fused_prefill
            and paged.fused_prefill_span_ok(bt.shape[1], k_l.shape[1], C)):
        win = _window_arr(cfg, is_global)
        if shard is None:
            attn, k_new, v_new = ops.prefill_attention_paged(
                q, k, v, k_l, v_l, bt, starts.astype(jnp.int32), win,
                fmt_kv=cfg.quant.kv_cache, compute_dtype=cfg.compute_dtype,
                softcap_val=cfg.logit_softcap,
                flash_chunk=paged.FLASH_CHUNK)
        else:
            gk = jax.lax.all_gather(k_l, shard.axis, axis=0, tiled=True)
            gv = jax.lax.all_gather(v_l, shard.axis, axis=0, tiled=True)
            lbt, owned = paged.localize_ids(bt, k_l.shape[0], shard)
            attn, k_new, v_new = ops.prefill_attention_paged(
                q, k, v, k_l, v_l, lbt, starts.astype(jnp.int32), win,
                fmt_kv=cfg.quant.kv_cache, compute_dtype=cfg.compute_dtype,
                softcap_val=cfg.logit_softcap,
                flash_chunk=paged.FLASH_CHUNK,
                hist_pool_k=gk, hist_pool_v=gv, hist_bt=bt,
                page_ok=owned.astype(jnp.int32))
        out = common.qdot(attn.reshape(B, C, Hq * Dh), p["wo"], cfg.quant,
                          prec_dtype=common.tp_prec(cfg))
        return out, k_new, v_new
    k_codes = common.kv_encode(cfg, k.reshape(B, C, -1))
    v_codes = common.kv_encode(cfg, v.reshape(B, C, -1))
    if bt is not None:
        hist_k, hist_v = (paged.gather_slots(k_l, bt, shard=shard),
                          paged.gather_slots(v_l, bt, shard=shard))
        k_new = paged.insert_chunk_batched(k_l, bt, starts, k_codes,
                                           shard=shard)
        v_new = paged.insert_chunk_batched(v_l, bt, starts, v_codes,
                                           shard=shard)
    else:
        rows = jnp.arange(B, dtype=jnp.int32)[:, None]
        hist_k, hist_v = k_l, v_l
        k_new = k_l.at[rows, pos].set(k_codes.astype(k_l.dtype))
        v_new = v_l.at[rows, pos].set(v_codes.astype(v_l.dtype))
    S_h = hist_k.shape[1]
    hist_pos = jnp.broadcast_to(jnp.arange(S_h, dtype=jnp.int32)[None],
                                (B, S_h))
    hist_pos = jnp.where(hist_pos < starts[:, None], hist_pos, -1)
    kd = common.kv_decode(cfg, hist_k).reshape(B, S_h, Hkv, Dh).astype(k.dtype)
    vd = common.kv_decode(cfg, hist_v).reshape(B, S_h, Hkv, Dh).astype(v.dtype)
    k_all = jnp.concatenate([kd, k], axis=1)
    v_all = jnp.concatenate([vd, v], axis=1)
    kv_pos = jnp.concatenate([hist_pos, pos], axis=1)
    if cfg.sliding_window is not None:
        window = jnp.where(is_global, jnp.int32(2**30),
                           jnp.int32(cfg.sliding_window))
    else:
        window = None
    attn = common.flash_attention(
        q, k_all, v_all, pos, kv_pos, causal=True, window=window,
        chunk_k=paged.FLASH_CHUNK, softcap_val=cfg.logit_softcap)
    out = common.qdot(attn.reshape(B, C, Hq * Dh), p["wo"], cfg.quant,
                      prec_dtype=common.tp_prec(cfg))
    return out, k_new, v_new


def _decode_step_paged(params, tokens, cache, cfg: ModelConfig, shard=None,
                       sample=None):
    """decode_step over the paged cache: per layer, scatter the token's KV
    codes into the slot's current page and attend via the paged-attention
    kernel — decode memory traffic scales with tokens in flight."""
    B = tokens.shape[0]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    length = cache["length"]
    bt = cache["block_table"]
    flags = layer_flags(cfg)

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        attn, k_new, v_new = _paged_attn_token(p, x, cfg, k_l, v_l, bt,
                                               length, is_global, shard=shard)
        x = x + attn
        x = x + _mlp_block(p, x, cfg)
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "block_table": bt, "length": length + 1}
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if sample is not None:
        return common.sample_head(x[:, 0], head, cfg, sample,
                                  transpose=cfg.tie_embeddings), new_cache
    logits = common.logits_head(x, head, cfg, transpose=cfg.tie_embeddings)
    return logits[:, 0], new_cache


def decode_verify(params, tokens, cache, cfg: ModelConfig, shard=None,
                  sample=None):
    """k-token speculative verify over the paged cache.

    tokens: [B, T] int32 — per slot the already-committed next token
    followed by T-1 draft proposals.  Per layer the T tokens' KV codes are
    scattered into the slot's pages (positions length + [0, T), exactly
    what T sequential decode steps would write) and ONE multi-query
    paged-attention launch attends all T query rows.  This is bitwise
    identical to T sequential `decode_step` calls over the same tokens:
    the MQ kernel masks pos <= q_pos, and each inserted key is the
    *quantized* code the sequential step would have written — and read —
    itself (decode semantics: a token always attends its own coded KV,
    unlike prefill-chunk intra-chunk attention which sees raw values).

    Returns ([B, T] int32 target tokens when `sample` is set, else
    [B, T, V] logits, and cache' with length advanced by T).  Callers
    commit the accepted prefix and roll `length` back on the host;
    positions past the committed count hold rejected-draft codes but sit
    at/after the new length, so no later read ever sees them before the
    next write."""
    if "block_table" not in cache:
        raise ValueError("decode_verify requires a paged cache")
    if shard is not None:
        raise NotImplementedError(
            "speculative verify over a sharded page pool is not wired up")
    B, T = tokens.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = common.embed_tokens(params["embed"], tokens, cfg)
    length = cache["length"]
    bt = cache["block_table"]
    flags = layer_flags(cfg)
    pos = length[:, None] + jnp.arange(T, dtype=jnp.int32)[None]

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        h = common.rms_norm(x, p["ln1"], upcast=not cfg.tp_bf16_reduce)
        q = common.qdot(h, p["wq"], cfg.quant).reshape(B, T, Hq, Dh)
        k = common.qdot(h, p["wk"], cfg.quant).reshape(B, T, Hkv, Dh)
        v = common.qdot(h, p["wv"], cfg.quant).reshape(B, T, Hkv, Dh)
        if cfg.qk_norm and "q_norm" in p:
            q = common.rms_norm(q, p["q_norm"])
            k = common.rms_norm(k, p["k_norm"])
        q = common.rope(q, pos, cfg.rope_theta)
        k = common.rope(k, pos, cfg.rope_theta)
        k_new = paged.insert_chunk_batched(
            k_l, bt, length, common.kv_encode(cfg, k.reshape(B, T, -1)))
        v_new = paged.insert_chunk_batched(
            v_l, bt, length, common.kv_encode(cfg, v.reshape(B, T, -1)))
        attn = ops.paged_attention(
            q, k_new, v_new, bt, length + T, _window_arr(cfg, is_global),
            fmt_kv=cfg.quant.kv_cache, softcap_val=cfg.logit_softcap)
        out = common.qdot(attn.reshape(B, T, Hq * Dh).astype(x.dtype),
                          p["wo"], cfg.quant)
        x = x + out
        x = x + _mlp_block(p, x, cfg)
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "block_table": bt, "length": length + T}
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if sample is not None:
        toks = common.sample_head(x.reshape(B * T, -1), head, cfg, sample,
                                  transpose=cfg.tie_embeddings)
        return toks.reshape(B, T), new_cache
    logits = common.logits_head(x, head, cfg, transpose=cfg.tie_embeddings)
    return logits, new_cache


def prefill_chunk(params, tokens, cache, slot, cfg: ModelConfig, shard=None):
    """Chunked prefill: process prompt chunk `tokens` [1, C] for one slot.

    The chunk lands at positions length[slot] + [0, C); works on both the
    dense and the paged cache (detected by the block_table leaf).  Returns
    (logits [1, 1, V] — the last position only, all the engine ever
    samples from, so the vocab head GEMM runs on one row per chunk —
    cache') with length[slot] advanced by C.  Chunks carry no padding
    (the serving engine decomposes prompts into bucketed chunk sizes
    exactly), so every processed token is real.
    """
    C = tokens.shape[1]
    x = common.embed_tokens(params["embed"], tokens, cfg)
    start = cache["length"][slot]
    flags = layer_flags(cfg)
    bt_row = cache["block_table"][slot] if "block_table" in cache else None

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        attn, k_new, v_new = _chunk_attn(
            p, x, cfg, k_l, v_l, start, bt_row=bt_row,
            slot=None if bt_row is not None else slot, is_global=is_global,
            shard=shard)
        x = x + attn
        x = x + _mlp_block(p, x, cfg)
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    new_cache = dict(cache)
    new_cache.update(k=k_c, v=v_c,
                     length=cache["length"].at[slot].set(start + C))
    return logits, new_cache


def prefill_chunk_batched(params, tokens, cache, active, cfg: ModelConfig,
                          shard=None):
    """Cross-slot batched chunked prefill: one [B, C] program advances every
    active slot by a chunk of the same bucket size — the serving engine
    compiles one prefill program per bucket and issues one device call per
    (step, bucket) however many slots are filling.

    tokens: [B, C] int32 (rows of inactive slots are padding); active: [B]
    bool.  The caller zeroes inactive rows' length/block-table metadata, so
    inactive paged writes land on the trash page; inactive rows of
    batch-dim leaves (dense KV) are reverted here against the input cache.
    Returns (last-position logits [B, V], cache')."""
    B, C = tokens.shape
    x = common.embed_tokens(params["embed"], tokens, cfg)
    starts = cache["length"]
    flags = layer_flags(cfg)
    bt = cache.get("block_table")

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        attn, k_new, v_new = _chunk_attn_batched(
            p, x, cfg, k_l, v_l, starts, bt=bt, is_global=is_global,
            shard=shard)
        x = x + attn
        x = x + _mlp_block(p, x, cfg)
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    if bt is None:
        m = active[None, :, None, None]
        k_c = jnp.where(m, k_c, cache["k"])
        v_c = jnp.where(m, v_c, cache["v"])
    new_cache = dict(cache)
    new_cache.update(
        k=k_c, v=v_c,
        length=cache["length"] + jnp.where(active, C, 0).astype(jnp.int32))
    return logits[:, 0], new_cache
