"""Jamba-style hybrid — jamba-1.5-large-398b: Mamba+attention 1:7
interleave, MoE (16e top-2) every other layer.

Layer pattern per period-8 block (attn_interval=8, moe_interval=2):
    j == 0     : attention sub-layer
    j in 1..7  : mamba2 sub-layer
    j even     : dense FFN      j odd : MoE FFN

Parameters are stacked per *block* (homogeneous), scanned over blocks, with
the 8 sub-layers statically unrolled inside — compile time O(1) in depth
while keeping three different sub-layer parameter shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from . import common, transformer, moe as moe_m, mamba as mamba_m
from .config import ModelConfig
from .module import ParamSpec


def _period(cfg: ModelConfig) -> int:
    return cfg.attn_interval


def _n_blocks(cfg: ModelConfig) -> int:
    assert cfg.n_layers % _period(cfg) == 0
    return cfg.n_layers // _period(cfg)


def _ffn_split(cfg: ModelConfig):
    per = _period(cfg)
    moe_js = [j for j in range(per) if j % cfg.moe_interval == cfg.moe_interval - 1]
    dense_js = [j for j in range(per) if j not in moe_js]
    return dense_js, moe_js


def param_specs(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.vocab_size
    nb, per = _n_blocks(cfg), _period(cfg)
    Hq, Hkv, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    E, Fe = cfg.n_experts, cfg.moe_d_ff
    dense_js, moe_js = _ffn_split(cfg)

    attn = {
        "ln1": ParamSpec((nb, D), ("stack", None), "zeros"),
        "wq": ParamSpec((nb, D, Hq * Dh), ("stack", "embed", "heads"), "fan_in"),
        "wk": ParamSpec((nb, D, Hkv * Dh), ("stack", "embed", "heads"), "fan_in"),
        "wv": ParamSpec((nb, D, Hkv * Dh), ("stack", "embed", "heads"), "fan_in"),
        "wo": ParamSpec((nb, Hq * Dh, D), ("stack", "heads", "embed"), "fan_in"),
    }
    mamba_specs = {
        k: ParamSpec((nb, per - 1) + s.shape[1:], ("stack", None) + s.logical_axes[1:],
                     s.init, s.dtype)
        for k, s in mamba_m.layer_param_specs(cfg, 1).items()
    }
    ffn = {
        "ln2": ParamSpec((nb, len(dense_js), D), ("stack", None, None), "zeros"),
        "wi_gate": ParamSpec((nb, len(dense_js), D, F), ("stack", None, "embed", "mlp"), "fan_in"),
        "wi_up": ParamSpec((nb, len(dense_js), D, F), ("stack", None, "embed", "mlp"), "fan_in"),
        "wo_mlp": ParamSpec((nb, len(dense_js), F, D), ("stack", None, "mlp", "embed"), "fan_in"),
    }
    moe = {
        "ln2": ParamSpec((nb, len(moe_js), D), ("stack", None, None), "zeros"),
        "router": ParamSpec((nb, len(moe_js), D, E), ("stack", None, "embed", "experts"), "fan_in"),
        "we_gate": ParamSpec((nb, len(moe_js), E, D, Fe), ("stack", None, "experts", "embed", "expert_mlp"), "fan_in"),
        "we_up": ParamSpec((nb, len(moe_js), E, D, Fe), ("stack", None, "experts", "embed", "expert_mlp"), "fan_in"),
        "we_down": ParamSpec((nb, len(moe_js), E, Fe, D), ("stack", None, "experts", "expert_mlp", "embed"), "fan_in"),
    }
    return {
        "embed": ParamSpec((V, D), ("vocab", "embed"), "embed"),
        "blocks": {"attn": attn, "mamba": mamba_specs, "ffn": ffn, "moe": moe},
        "final_norm": ParamSpec((D,), (None,), "zeros"),
    }


def _sub(tree, idx):
    return jax.tree.map(lambda t: t[idx], tree)


def _ffn_apply(blk, j, x, cfg: ModelConfig):
    dense_js, moe_js = _ffn_split(cfg)
    if j in moe_js:
        p = _sub(blk["moe"], moe_js.index(j))
        h = common.rms_norm(x, p["ln2"])
        y, aux = moe_m.moe_ffn(p, h, cfg)
        return x + y, aux
    p = _sub(blk["ffn"], dense_js.index(j))
    return x + transformer._mlp_block(p, x, cfg), jnp.float32(0)


def apply(params, batch, cfg: ModelConfig, collect_cache: bool = False,
          with_aux: bool = False):
    x = common.embed_tokens(params["embed"], batch["tokens"], cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    per = _period(cfg)

    def body(carry, blk):
        x = carry
        auxes = []
        kv = None
        for j in range(per):
            if j == 0:
                attn, k, v = transformer._attn_block(
                    blk["attn"], x, cfg, pos, pos, jnp.bool_(True))
                x = x + attn
                kv = (k, v)
            else:
                p = _sub(blk["mamba"], j - 1)
                out, _, _ = mamba_m.mamba_block(p, x, cfg)
                x = x + out
            x, aux = _ffn_apply(blk, j, x, cfg)
            auxes.append(aux)
            x = sharding.constrain(x, ("batch", None, "embed_act"))
        return x, (jnp.stack(auxes).mean(), kv if collect_cache else None)

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "layer" else body
    x, (auxes, kvs) = jax.lax.scan(body_fn, x, params["blocks"])
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    outs = [logits]
    if collect_cache:
        outs.append(kvs)
    if with_aux:
        outs.append(jnp.mean(auxes))
    return outs[0] if len(outs) == 1 else tuple(outs)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, layout=None):
    """Hybrid decode state: per-block KV (dense rows or posit-coded pages
    behind a PagedLayout, shared block table across blocks) + per-block
    mamba conv/SSM states (O(1) in sequence — never paged)."""
    nb, per = _n_blocks(cfg), _period(cfg)
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.ssm_d_inner + 2 * N
    dt = common.kv_store_dtype(cfg)
    specs = {
        "ssm": ParamSpec((nb, per - 1, batch, H, P, N),
                         ("stack", None, "batch", "ssm_heads", None, None), "zeros"),
        "conv": ParamSpec((nb, per - 1, batch, cfg.ssm_conv - 1, conv_ch),
                          ("stack", None, "batch", None, "ssm_heads"), "zeros", jnp.float32),
        "length": ParamSpec((batch,), ("batch",), "zeros", jnp.int32),
    }
    if layout is None:
        kv_shape = (nb, batch, max_seq, cfg.n_kv_heads * cfg.head_dim)
        kv_axes = ("stack", "batch", "kv_seq", "kv_heads")
    else:
        kv_shape = (nb, layout.n_pages, layout.page_size,
                    cfg.n_kv_heads * cfg.head_dim)
        kv_axes = ("stack", "kv_pages", None, "kv_heads")
        specs["block_table"] = ParamSpec(
            (batch, layout.pages_per_slot(max_seq)), ("batch", None),
            "zeros", jnp.int32)
    specs["k"] = ParamSpec(kv_shape, kv_axes, "zeros", dt)
    specs["v"] = ParamSpec(kv_shape, kv_axes, "zeros", dt)
    return specs


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, layout=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_seq, layout),
                        is_leaf=lambda s: isinstance(s, ParamSpec))


def prefill(params, batch, cfg: ModelConfig, max_seq=None):
    """Prefill: run blocks collecting KV + final SSM/conv states."""
    x = common.embed_tokens(params["embed"], batch["tokens"], cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    per = _period(cfg)
    max_seq = max_seq or S

    def body(carry, blk):
        x = carry
        convs, ssms = [], []
        kv = None
        for j in range(per):
            if j == 0:
                attn, k, v = transformer._attn_block(
                    blk["attn"], x, cfg, pos, pos, jnp.bool_(True))
                x = x + attn
                kv = (k, v)
            else:
                p = _sub(blk["mamba"], j - 1)
                out, cs, ss = mamba_m.mamba_block(p, x, cfg)
                x = x + out
                convs.append(cs)
                ssms.append(ss)
            x, _ = _ffn_apply(blk, j, x, cfg)
        return x, (kv, jnp.stack(convs), jnp.stack(ssms))

    x, (kvs, convs, ssms) = jax.lax.scan(body, x, params["blocks"])
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    nb = _n_blocks(cfg)
    fold = lambda t: common.kv_encode(cfg, t.reshape(nb, B, S, -1))
    k_cache, v_cache = fold(kvs[0]), fold(kvs[1])
    if max_seq > S:
        pad = ((0, 0), (0, 0), (0, max_seq - S), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    cache = {"k": k_cache, "v": v_cache, "ssm": ssms,
             "conv": convs.astype(jnp.float32),
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def prefill_chunk(params, tokens, cache, slot, cfg: ModelConfig, shard=None):
    """Chunked prefill for one slot: attention sub-layers write/gather the
    slot's KV (dense row or pages), mamba sub-layers carry the slot's
    conv/SSM states across chunks (see transformer/mamba prefill_chunk).
    Under a kv_pages shard only the KV pages are distributed; conv/SSM
    states stay replicated.  Returns the last position's logits [1, 1, V]
    only.  Attention sub-layers ride transformer's `_chunk_attn`, so the
    fused prefill program (QuantPolicy.fused_prefill) applies here too."""
    C = tokens.shape[1]
    x = common.embed_tokens(params["embed"], tokens, cfg)
    start = cache["length"][slot]
    per = _period(cfg)
    bt_row = cache["block_table"][slot] if "block_table" in cache else None
    conv_s = jax.lax.dynamic_slice_in_dim(cache["conv"], slot, 1, axis=2)
    ssm_s = jax.lax.dynamic_slice_in_dim(cache["ssm"], slot, 1, axis=2)

    def body(x, xs):
        blk, k_l, v_l, conv_l, ssm_l = xs
        convs, ssms = [], []
        k_new = v_new = None
        for j in range(per):
            if j == 0:
                attn, k_new, v_new = transformer._chunk_attn(
                    blk["attn"], x, cfg, k_l, v_l, start, bt_row=bt_row,
                    slot=None if bt_row is not None else slot,
                    is_global=jnp.bool_(True), shard=shard)
                x = x + attn
            else:
                p = _sub(blk["mamba"], j - 1)
                out, cs, ss = mamba_m.mamba_block(
                    p, x, cfg, conv_state=conv_l[j - 1],
                    ssm_state=ssm_l[j - 1])
                x = x + out
                convs.append(cs)
                ssms.append(ss)
            x, _ = _ffn_apply(blk, j, x, cfg)
        return x, (k_new, v_new, jnp.stack(convs), jnp.stack(ssms))

    x, (k_c, v_c, convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], conv_s, ssm_s))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    new_cache = dict(cache)
    new_cache.update(
        k=k_c, v=v_c,
        conv=cache["conv"].at[:, :, slot].set(
            convs[:, :, 0].astype(jnp.float32)),
        ssm=cache["ssm"].at[:, :, slot].set(ssms[:, :, 0]),
        length=cache["length"].at[slot].set(start + C))
    return logits, new_cache


def prefill_chunk_batched(params, tokens, cache, active, cfg: ModelConfig,
                          shard=None):
    """Cross-slot batched chunked prefill: attention sub-layers run the
    batched chunk attention over every slot's own pages/rows, mamba
    sub-layers carry all slots' conv/SSM states at once; inactive rows are
    reverted against the input cache.  Returns (last-position logits
    [B, V], cache')."""
    B, C = tokens.shape
    x = common.embed_tokens(params["embed"], tokens, cfg)
    starts = cache["length"]
    per = _period(cfg)
    bt = cache.get("block_table")

    def body(x, xs):
        blk, k_l, v_l, conv_l, ssm_l = xs
        convs, ssms = [], []
        k_new = v_new = None
        for j in range(per):
            if j == 0:
                attn, k_new, v_new = transformer._chunk_attn_batched(
                    blk["attn"], x, cfg, k_l, v_l, starts, bt=bt,
                    is_global=jnp.bool_(True), shard=shard)
                x = x + attn
            else:
                p = _sub(blk["mamba"], j - 1)
                out, cs, ss = mamba_m.mamba_block(
                    p, x, cfg, conv_state=conv_l[j - 1],
                    ssm_state=ssm_l[j - 1])
                x = x + out
                convs.append(cs)
                ssms.append(ss)
            x, _ = _ffn_apply(blk, j, x, cfg)
        return x, (k_new, v_new, jnp.stack(convs), jnp.stack(ssms))

    x, (k_c, v_c, convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["conv"], cache["ssm"]))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    if bt is None:
        m = active[None, :, None, None]
        k_c = jnp.where(m, k_c, cache["k"])
        v_c = jnp.where(m, v_c, cache["v"])
    new_cache = dict(cache)
    new_cache.update(
        k=k_c, v=v_c,
        conv=jnp.where(active[None, None, :, None, None],
                       convs.astype(jnp.float32), cache["conv"]),
        ssm=jnp.where(active[None, None, :, None, None, None],
                      ssms, cache["ssm"]),
        length=cache["length"] + jnp.where(active, C, 0).astype(jnp.int32))
    return logits[:, 0], new_cache


def _decode_step_paged(params, tokens, cache, cfg: ModelConfig, shard=None,
                       sample=None):
    """Paged decode: attention sub-layers scatter the token's KV codes
    into the slot's current page and attend via the paged-attention
    kernel; mamba/FFN sub-layers are unchanged (conv/SSM states stay
    replicated under a kv_pages shard)."""
    length = cache["length"]
    bt = cache["block_table"]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    per = _period(cfg)

    def body(x, xs):
        blk, k_l, v_l, conv_l, ssm_l = xs
        convs, ssms = [], []
        k_new = v_new = None
        for j in range(per):
            if j == 0:
                attn, k_new, v_new = transformer._paged_attn_token(
                    blk["attn"], x, cfg, k_l, v_l, bt, length,
                    jnp.bool_(True), shard=shard)
                x = x + attn
            else:
                p = _sub(blk["mamba"], j - 1)
                out, cs, ss = mamba_m.mamba_block(
                    p, x, cfg, conv_state=conv_l[j - 1],
                    ssm_state=ssm_l[j - 1], single_step=True)
                x = x + out
                convs.append(cs)
                ssms.append(ss)
            x, _ = _ffn_apply(blk, j, x, cfg)
        return x, (k_new, v_new, jnp.stack(convs), jnp.stack(ssms))

    x, (k_c, v_c, convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["conv"], cache["ssm"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "ssm": ssms, "conv": convs,
                 "block_table": bt, "length": length + 1}
    if sample is not None:
        return common.sample_head(x[:, 0], params["embed"], cfg, sample,
                                  transpose=True), new_cache
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    return logits[:, 0], new_cache


def decode_step(params, tokens, cache, cfg: ModelConfig, shard=None,
                sample=None):
    if "block_table" in cache:
        return _decode_step_paged(params, tokens, cache, cfg, shard=shard,
                                  sample=sample)
    if shard is not None:
        raise ValueError("kv_pages sharding requires a paged cache")
    B = tokens.shape[0]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    S_max = cache["k"].shape[2]
    length = cache["length"]
    q_pos = length[:, None]
    kv_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None], (B, S_max))
    per = _period(cfg)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim

    def body(x, xs):
        blk, k_l, v_l, conv_l, ssm_l = xs
        convs, ssms = [], []
        k_new = v_new = None
        for j in range(per):
            if j == 0:
                # knobs (upcast/softcap) must mirror the prefill/paged
                # attention paths, or dense-vs-paged token parity breaks
                # for configs that set them
                p = blk["attn"]
                h = common.rms_norm(x, p["ln1"],
                                    upcast=not cfg.tp_bf16_reduce)
                q = common.qdot(h, p["wq"], cfg.quant).reshape(B, 1, cfg.n_heads, Dh)
                k = common.qdot(h, p["wk"], cfg.quant).reshape(B, 1, Hkv, Dh)
                v = common.qdot(h, p["wv"], cfg.quant).reshape(B, 1, Hkv, Dh)
                q = common.rope(q, q_pos, cfg.rope_theta)
                k = common.rope(k, q_pos, cfg.rope_theta)
                k_new = transformer._cache_insert(
                    k_l, common.kv_encode(cfg, k.reshape(B, 1, -1)), length)
                v_new = transformer._cache_insert(
                    v_l, common.kv_encode(cfg, v.reshape(B, 1, -1)), length)
                kc = common.kv_decode(cfg, k_new).reshape(B, S_max, Hkv, Dh)
                vc = common.kv_decode(cfg, v_new).reshape(B, S_max, Hkv, Dh)
                attn = common.decode_attention(
                    q, kc, vc, length + 1, kv_pos, window=None,
                    softcap_val=cfg.logit_softcap)
                x = x + common.qdot(attn.reshape(B, 1, cfg.n_heads * Dh),
                                    p["wo"], cfg.quant)
            else:
                p = _sub(blk["mamba"], j - 1)
                out, cs, ss = mamba_m.mamba_block(
                    p, x, cfg, conv_state=conv_l[j - 1],
                    ssm_state=ssm_l[j - 1], single_step=True)
                x = x + out
                convs.append(cs)
                ssms.append(ss)
            x, _ = _ffn_apply(blk, j, x, cfg)
        return x, (k_new, v_new, jnp.stack(convs), jnp.stack(ssms))

    x, (k_c, v_c, convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["conv"], cache["ssm"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "ssm": ssms, "conv": convs,
                 "length": length + 1}
    if sample is not None:
        return common.sample_head(x[:, 0], params["embed"], cfg, sample,
                                  transpose=True), new_cache
    logits = common.logits_head(x, params["embed"], cfg, transpose=True)
    return logits[:, 0], new_cache
