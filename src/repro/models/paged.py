"""Paged KV-cache layout + page-pool utilities (vLLM-style block tables).

Serving decode memory should scale with *tokens in flight*, not with
`batch_slots x max_seq`: the KV cache becomes a pool of fixed-size pages
`[n_pages, page_size, Hkv*Dh]` stored at the QuantPolicy's KV code width
(int8/int16 posit codes — the PDPU storage-format win applied to decode
state), and each batch slot owns an ordered list of page indices (its
*block table*): page j of a slot holds absolute positions
[j*page_size, (j+1)*page_size).

Invariants the serving engine maintains (and the kernels rely on):

  * page 0 is reserved as the trash page — never allocated; zeroed block-
    table rows (free / mid-prefill slots) harmlessly direct stray writes
    and gathers there,
  * a slot's pages appear in its block-table row in position order, so
    `pos -> (row[pos // page_size], pos % page_size)` is the only address
    computation anywhere,
  * positions >= length are dead: reclaimed pages are handed to new
    requests *without zeroing* — every position is written (at `length`)
    before any attention may read it (reads mask `pos < length`), so stale
    keys from a retired request can never leak into a new one,
  * pages are *refcounted* (serve.PageAllocator): one physical page may
    appear in many block tables (prompt-prefix sharing).  A shared page is
    immutable below its frozen prefix — a slot that must write below it
    first forks the page (`fork_page`, copy-on-write) into a private copy
    and swaps its block-table entry; writes at or above the frozen prefix
    (a donor appending decode tokens past every sharer's trusted range)
    may land in place.

Global page-id contract (kv_pages-sharded pools)
------------------------------------------------

When the pool is sharded along its page dimension over a mesh axis (the
`kv_pages` rule in parallel/sharding.py), block tables keep addressing
**global** page ids: the pool is conceptually still `[n_pages, ps, F]`,
device (shard) `s` of `n_shards` physically holds the contiguous global-id
range `[s*pages_per_shard, (s+1)*pages_per_shard)`, and

    shard(g)  = g // pages_per_shard
    local(g)  = g %  pages_per_shard
    global(s, l) = s * pages_per_shard + l

Every shard reserves its **local page 0** (global ids `s*pages_per_shard`)
as a trash page: inside `shard_map`, a block-table entry this shard does
not own localizes to its own trash page, so stray writes from other
shards' pages land harmlessly and gathers of non-owned pages are masked
(`localize_ids` returns the ownership mask).  Global page 0 remains the
canonical trash page zeroed block-table rows point at — on shard 0 it *is*
local page 0, on every other shard it is non-owned and redirects to that
shard's own trash.  The allocator (serve.PageAllocator) never hands out
any `g` with `g % pages_per_shard == 0`.

The `shard=...` parameter on the insert/gather/fork helpers below accepts
a `PageShard` and must only be used inside a `shard_map` over that axis;
`shard=None` (the default) is the single-pool case and is unchanged.

The dense `[L, B, max_seq, F]` cache remains the `layout=None` special
case throughout `cache_specs` / `init_cache` / `decode_step`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


# flash_attention's default key-chunk length (models/common.py).  The fused
# prefill kernel replays flash's chunked streaming scan bit-for-bit at this
# chunk length; tests pin this against the flash_attention default.
FLASH_CHUNK = 1024


def fused_prefill_span_ok(max_pages: int, page_size: int, chunk: int) -> bool:
    """True when the fused prefill kernel is bit-exact for this geometry.

    Short spans (history plus the incoming chunk within one flash chunk)
    replay flash_attention's degenerate single pass.  Longer spans stream
    history page-by-page inside the kernel, running one flash softmax step
    per completed `FLASH_CHUNK` of staged pages — which requires pages to
    tile the flash chunk exactly.  Only a page size that does not divide
    `FLASH_CHUNK` still forces the decomposed fallback."""
    if max_pages * page_size + chunk <= FLASH_CHUNK:
        return True
    return FLASH_CHUNK % page_size == 0


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Geometry of the paged KV pool.

    page_size : tokens per page (the policy's `kv_page_size` by default).
    n_pages   : total pool pages across every shard, *including* the
                per-shard reserved trash pages (local page 0 of each).
    n_shards  : devices the page dimension is sharded over (the `kv_pages`
                mesh axis).  1 = the single-pool case.
    """

    page_size: int
    n_pages: int
    n_shards: int = 1

    def __post_init__(self):
        if self.page_size <= 0 or self.n_shards < 1:
            raise ValueError(f"bad paged layout {self}")
        if self.n_pages % self.n_shards:
            raise ValueError(
                f"n_pages={self.n_pages} must divide evenly over "
                f"n_shards={self.n_shards} (the kv_pages mesh axis)")
        if self.pages_per_shard < 2:
            raise ValueError(
                f"each shard needs its trash page plus >=1 usable page; "
                f"got {self.pages_per_shard} pages/shard in {self}")

    @property
    def pages_per_shard(self) -> int:
        return self.n_pages // self.n_shards

    @property
    def capacity(self) -> int:
        """Allocatable pages: everything but the per-shard trash pages."""
        return self.n_pages - self.n_shards

    def pages_per_slot(self, max_seq: int) -> int:
        """Block-table row length: pages addressing positions < max_seq."""
        return -(-max_seq // self.page_size)

    # -- host-side global <-> (shard, local) id mapping -------------------

    def _check(self, page: int):
        if not 0 <= page < self.n_pages:
            raise ValueError(
                f"page id {page} out of range [0, {self.n_pages})")

    def shard_of(self, page: int) -> int:
        self._check(page)
        return page // self.pages_per_shard

    def local_id(self, page: int) -> int:
        self._check(page)
        return page % self.pages_per_shard

    def global_id(self, shard: int, local: int) -> int:
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        if not 0 <= local < self.pages_per_shard:
            raise ValueError(
                f"local id {local} out of range [0, {self.pages_per_shard})")
        return shard * self.pages_per_shard + local

    def is_trash(self, page: int) -> bool:
        """Every shard's local page 0 is reserved (global 0 included)."""
        self._check(page)
        return page % self.pages_per_shard == 0

    @staticmethod
    def for_slots(batch: int, max_seq: int, page_size: int,
                  n_pages: int | None = None,
                  n_shards: int = 1) -> "PagedLayout":
        """Default pool: full capacity for every slot plus the trash page
        per shard (capacity parity with the dense cache; smaller pools
        oversubscribe).  Sharded pools split the budget evenly: each of
        the n_shards devices holds ceil(batch*pages_per_slot/n_shards)
        usable pages plus its own trash page."""
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        per = -(-max_seq // page_size)
        if n_pages is None:
            n_pages = n_shards * (-(-(batch * per) // n_shards) + 1)
        return PagedLayout(page_size, n_pages, n_shards)


@dataclasses.dataclass(frozen=True)
class PageShard:
    """kv_pages shard context: which mesh axis the pool's page dimension
    is split over.  Only meaningful inside a fully-manual `shard_map` that
    binds `axis` — the helpers below call `jax.lax.axis_index(axis)` and
    psum/pmax over it."""

    axis: str
    n_shards: int


def localize_ids(ids, pages_per_shard: int, shard: PageShard):
    """Global page ids -> (local ids, ownership mask) for this shard.

    Non-owned ids localize to this shard's trash page 0 so they can be
    used directly as scatter/gather indices; callers mask reads with the
    returned `owned` mask (writes to local 0 are harmless by contract)."""
    loc = ids - jax.lax.axis_index(shard.axis) * pages_per_shard
    owned = (loc >= 0) & (loc < pages_per_shard)
    return jnp.where(owned, loc, 0), owned


def insert_tokens(pages, block_table, lengths, vals, shard: PageShard | None = None):
    """Write one decode token per slot into the page pool.

    pages: [P, ps, F]; block_table: [B, M] (global ids); lengths: [B]
    (write position per slot); vals: [B, F].  Rows whose block-table
    entries are zeroed (free / mid-prefill slots) land on the trash page.
    Under `shard`, pages is the local sub-pool and non-owned destinations
    land on this shard's own trash page."""
    ps = pages.shape[1]
    B = vals.shape[0]
    page = block_table[jnp.arange(B), jnp.clip(lengths // ps, 0,
                                               block_table.shape[1] - 1)]
    if shard is not None:
        page, _ = localize_ids(page, pages.shape[0], shard)
    return pages.at[page, lengths % ps].set(vals.astype(pages.dtype))


def insert_chunk(pages, bt_row, start, vals, shard: PageShard | None = None):
    """Write a prefill chunk for one slot: vals [C, F] at positions
    start + [0, C) of the slot whose block-table row is bt_row [M]."""
    ps = pages.shape[1]
    pos = start + jnp.arange(vals.shape[0], dtype=jnp.int32)
    page = bt_row[jnp.clip(pos // ps, 0, bt_row.shape[0] - 1)]
    if shard is not None:
        page, _ = localize_ids(page, pages.shape[0], shard)
    return pages.at[page, pos % ps].set(vals.astype(pages.dtype))


def insert_chunk_batched(pages, bt, starts, vals, shard: PageShard | None = None):
    """Write one prefill chunk per slot in a single scatter: vals [B, C, F]
    at positions starts[b] + [0, C) of slot b.  Rows whose block-table
    entries are zeroed (inactive slots in a batched prefill call) land on
    the trash page."""
    ps = pages.shape[1]
    B, C, _ = vals.shape
    pos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None]       # [B, C]
    page = jnp.take_along_axis(bt, jnp.clip(pos // ps, 0,
                                            bt.shape[1] - 1), axis=1)  # [B, C]
    if shard is not None:
        page, _ = localize_ids(page, pages.shape[0], shard)
    return pages.at[page, pos % ps].set(vals.astype(pages.dtype))


def gather_slot(pages, bt_row, shard: PageShard | None = None):
    """Materialize one slot's pages densely: [M*ps, F].  Entries beyond
    the slot's written prefix are garbage — callers mask by position.
    Under `shard`, each device contributes the pages it owns (zeros
    elsewhere) and a psum over the shard axis rebuilds the exact global
    gather — a sequence-parallel all-gather that ships posit codes, not
    decoded floats."""
    M = bt_row.shape[0]
    ps, F = pages.shape[1], pages.shape[2]
    if shard is None:
        return pages[bt_row].reshape(M * ps, F)
    loc, owned = localize_ids(bt_row, pages.shape[0], shard)
    rows = jnp.where(owned[:, None, None], pages[loc],
                     jnp.zeros((), pages.dtype))
    return jax.lax.psum(rows, shard.axis).reshape(M * ps, F)


def gather_slots(pages, bt, shard: PageShard | None = None):
    """Materialize every slot's pages densely: [B, M*ps, F] (the batched
    `gather_slot`).  Zeroed block-table rows gather the trash page —
    garbage, masked by position like any unwritten suffix."""
    B, M = bt.shape
    ps, F = pages.shape[1], pages.shape[2]
    if shard is None:
        return pages[bt].reshape(B, M * ps, F)
    loc, owned = localize_ids(bt, pages.shape[0], shard)
    rows = jnp.where(owned[..., None, None], pages[loc],
                     jnp.zeros((), pages.dtype))
    return jax.lax.psum(rows, shard.axis).reshape(B, M * ps, F)


def fork_page(pool, dst, src, shard: PageShard | None = None):
    """Copy-on-write fork: duplicate page `src` into page `dst` across the
    leading (layer/stack) dim.  pool: [L, P, ps, F]; dst/src are traced
    scalars so one compile covers every fork.  Under `shard`, src/dst are
    global ids possibly on different devices: the owner of `src`
    broadcasts the page (psum of a single non-zero contribution) and the
    owner of `dst` writes it; everyone else is a no-op."""
    if shard is None:
        return pool.at[:, dst].set(pool[:, src])
    pps = pool.shape[1]
    lsrc, own_src = localize_ids(src, pps, shard)
    row = jnp.where(own_src, pool[:, lsrc], jnp.zeros((), pool.dtype))
    row = jax.lax.psum(row, shard.axis)
    ldst, own_dst = localize_ids(dst, pps, shard)
    keep = pool[:, ldst]
    return pool.at[:, ldst].set(jnp.where(own_dst, row, keep))
