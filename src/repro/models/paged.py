"""Paged KV-cache layout + page-pool utilities (vLLM-style block tables).

Serving decode memory should scale with *tokens in flight*, not with
`batch_slots x max_seq`: the KV cache becomes a pool of fixed-size pages
`[n_pages, page_size, Hkv*Dh]` stored at the QuantPolicy's KV code width
(int8/int16 posit codes — the PDPU storage-format win applied to decode
state), and each batch slot owns an ordered list of page indices (its
*block table*): page j of a slot holds absolute positions
[j*page_size, (j+1)*page_size).

Invariants the serving engine maintains (and the kernels rely on):

  * page 0 is reserved as the trash page — never allocated; zeroed block-
    table rows (free / mid-prefill slots) harmlessly direct stray writes
    and gathers there,
  * a slot's pages appear in its block-table row in position order, so
    `pos -> (row[pos // page_size], pos % page_size)` is the only address
    computation anywhere,
  * positions >= length are dead: reclaimed pages are handed to new
    requests *without zeroing* — every position is written (at `length`)
    before any attention may read it (reads mask `pos < length`), so stale
    keys from a retired request can never leak into a new one,
  * pages are *refcounted* (serve.PageAllocator): one physical page may
    appear in many block tables (prompt-prefix sharing).  A shared page is
    immutable below its frozen prefix — a slot that must write below it
    first forks the page (`fork_page`, copy-on-write) into a private copy
    and swaps its block-table entry; writes at or above the frozen prefix
    (a donor appending decode tokens past every sharer's trusted range)
    may land in place.

The dense `[L, B, max_seq, F]` cache remains the `layout=None` special
case throughout `cache_specs` / `init_cache` / `decode_step`.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Geometry of the paged KV pool.

    page_size : tokens per page (the policy's `kv_page_size` by default).
    n_pages   : total pool pages, *including* the reserved trash page 0.
    """

    page_size: int
    n_pages: int

    def __post_init__(self):
        if self.page_size <= 0 or self.n_pages < 2:
            raise ValueError(f"bad paged layout {self}")

    def pages_per_slot(self, max_seq: int) -> int:
        """Block-table row length: pages addressing positions < max_seq."""
        return -(-max_seq // self.page_size)

    @staticmethod
    def for_slots(batch: int, max_seq: int, page_size: int,
                  n_pages: int | None = None) -> "PagedLayout":
        """Default pool: full capacity for every slot plus the trash page
        (capacity parity with the dense cache; smaller pools oversubscribe)."""
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        per = -(-max_seq // page_size)
        return PagedLayout(page_size,
                           n_pages if n_pages is not None
                           else batch * per + 1)


def insert_tokens(pages, block_table, lengths, vals):
    """Write one decode token per slot into the page pool.

    pages: [P, ps, F]; block_table: [B, M]; lengths: [B] (write position
    per slot); vals: [B, F].  Rows whose block-table entries are zeroed
    (free / mid-prefill slots) land on the trash page.
    """
    ps = pages.shape[1]
    B = vals.shape[0]
    page = block_table[jnp.arange(B), jnp.clip(lengths // ps, 0,
                                               block_table.shape[1] - 1)]
    return pages.at[page, lengths % ps].set(vals.astype(pages.dtype))


def insert_chunk(pages, bt_row, start, vals):
    """Write a prefill chunk for one slot: vals [C, F] at positions
    start + [0, C) of the slot whose block-table row is bt_row [M]."""
    ps = pages.shape[1]
    pos = start + jnp.arange(vals.shape[0], dtype=jnp.int32)
    page = bt_row[jnp.clip(pos // ps, 0, bt_row.shape[0] - 1)]
    return pages.at[page, pos % ps].set(vals.astype(pages.dtype))


def insert_chunk_batched(pages, bt, starts, vals):
    """Write one prefill chunk per slot in a single scatter: vals [B, C, F]
    at positions starts[b] + [0, C) of slot b.  Rows whose block-table
    entries are zeroed (inactive slots in a batched prefill call) land on
    the trash page."""
    ps = pages.shape[1]
    B, C, _ = vals.shape
    pos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None]       # [B, C]
    page = jnp.take_along_axis(bt, jnp.clip(pos // ps, 0,
                                            bt.shape[1] - 1), axis=1)  # [B, C]
    return pages.at[page, pos % ps].set(vals.astype(pages.dtype))


def gather_slot(pages, bt_row):
    """Materialize one slot's pages densely: [M*ps, F].  Entries beyond
    the slot's written prefix are garbage — callers mask by position."""
    M = bt_row.shape[0]
    ps, F = pages.shape[1], pages.shape[2]
    return pages[bt_row].reshape(M * ps, F)


def gather_slots(pages, bt):
    """Materialize every slot's pages densely: [B, M*ps, F] (the batched
    `gather_slot`).  Zeroed block-table rows gather the trash page —
    garbage, masked by position like any unwritten suffix."""
    B, M = bt.shape
    ps, F = pages.shape[1], pages.shape[2]
    return pages[bt].reshape(B, M * ps, F)


def fork_page(pool, dst, src):
    """Copy-on-write fork: duplicate page `src` into page `dst` across the
    leading (layer/stack) dim.  pool: [L, P, ps, F]; dst/src are traced
    scalars so one compile covers every fork."""
    return pool.at[:, dst].set(pool[:, src])
