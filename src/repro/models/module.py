"""Minimal from-scratch parameter system (no flax/haiku available).

A model is three pure things:
  * ``param_specs(cfg) -> pytree[ParamSpec]``  (shapes/dtypes/logical axes/init)
  * ``init(rng, cfg)   -> pytree[jnp.ndarray]`` (materialize the specs)
  * ``apply(params, inputs, cfg) -> outputs``

ParamSpecs make the multi-pod dry-run allocation-free: shardings and
ShapeDtypeStructs come straight from the specs, no tracing or host memory.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | fan_in | embed
    dtype: Any = jnp.float32

    def abstract(self):
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def abstract_params(specs):
    """pytree[ParamSpec] -> pytree[ShapeDtypeStruct] (no allocation)."""
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=_is_spec)


def init_params(rng, specs):
    """Materialize a spec tree with deterministic per-leaf RNG streams."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))

    def one(key, s: ParamSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "normal":
            return (0.02 * jax.random.normal(key, s.shape)).astype(s.dtype)
        if s.init == "embed":
            return (1.0 * jax.random.normal(key, s.shape)).astype(s.dtype)
        if s.init == "fan_in":
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = 1.0 / math.sqrt(fan_in)
            return (std * jax.random.normal(key, s.shape)).astype(s.dtype)
        if s.init.startswith("const:"):
            return jnp.full(s.shape, float(s.init.split(":")[1]), s.dtype)
        if s.init == "arange1":  # 1..n (Mamba A_log style)
            n = int(np.prod(s.shape))
            return jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                           ).reshape(s.shape).astype(s.dtype)
        raise ValueError(f"unknown init '{s.init}'")

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(rngs, leaves)])


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves))
