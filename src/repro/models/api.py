"""Unified model API — dispatch by config family.

Every architecture exposes the same five entry points:
    param_specs(cfg)                      -> pytree[ParamSpec]
    init(rng, cfg)                        -> params
    apply(params, batch, cfg)             -> logits          (train/encode)
    prefill(params, batch, cfg, max_seq)  -> (logits, cache) (serving)
    decode_step(params, tokens, cache, cfg) -> (logits, cache')
plus `input_specs(cfg, shape)` producing allocation-free ShapeDtypeStructs
for the dry-run, and `cache_specs` for decode-state dry-runs.

Posit-packed checkpoints (re-exported from `packing`): `pack_params` /
`unpack_params` convert qdot weights to/from posit code arrays,
`packed_param_specs` types the restore tree, `pack_manifest` tags the
checkpoint.  apply/prefill/decode_step accept packed params transparently
(the GEMM dispatch layer detects code containers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import transformer, moe, mamba, hybrid
from .config import ModelConfig, ShapeConfig
from .module import ParamSpec, abstract_params, init_params
from .packing import (pack_params, unpack_params, packed_param_specs,  # noqa: F401
                      pack_manifest, weight_bytes)
from .paged import PagedLayout  # noqa: F401  (re-exported serving layout)


def _mod(cfg: ModelConfig):
    return {
        "dense": transformer,
        "encoder": transformer,
        "vlm": transformer,
        "moe": moe,
        "ssm": mamba,
        "hybrid": hybrid,
    }[cfg.family]


def param_specs(cfg: ModelConfig):
    return _mod(cfg).param_specs(cfg)


def init(rng, cfg: ModelConfig):
    return init_params(rng, param_specs(cfg))


def apply(params, batch, cfg: ModelConfig, **kw):
    return _mod(cfg).apply(params, batch, cfg, **kw)


def prefill(params, batch, cfg: ModelConfig, max_seq: Optional[int] = None):
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no autoregressive serving")
    return _mod(cfg).prefill(params, batch, cfg, max_seq=max_seq)


def decode_step(params, tokens, cache, cfg: ModelConfig, shard=None):
    """shard: optional paged.PageShard when the paged KV pool is sharded
    along kv_pages and this call runs inside a shard_map over that axis
    (block tables hold global page ids; see models/paged.py)."""
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    return _mod(cfg).decode_step(params, tokens, cache, cfg, shard=shard)


def sample_noise(keys, vocab_size: int):
    """Per-slot standard-gumbel noise [B, V] for the fused decode step —
    exactly what `jax.random.categorical(key, logits)` draws internally,
    so `argmax(noise + logits/T)` replays the decomposed sampler bitwise."""
    return jax.vmap(
        lambda kk: jax.random.gumbel(kk, (vocab_size,), jnp.float32))(keys)


def decode_and_sample(params, tokens, cache, cfg: ModelConfig, noise,
                      temperature, *, greedy: bool, top_k: int, shard=None):
    """One-program decode step: attention + logits head + sampling epilogue
    in a single device dispatch.  Returns ([B] int32 tokens, cache') —
    bit-identical to `decode_step` followed by the engine sampler (the
    model's sample_head replays the head qdot plan and the temperature /
    top-k / gumbel-argmax sampler inside one Pallas program).

    noise: [B, V] f32 gumbel rows from `sample_noise` (None when greedy);
    temperature: f32 scalar (ignored when greedy)."""
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    from . import common
    spec = common.SampleSpec(noise=noise, temperature=temperature,
                             greedy=greedy, top_k=top_k)
    return _mod(cfg).decode_step(params, tokens, cache, cfg, shard=shard,
                                 sample=spec)


def decode_verify(params, tokens, cache, cfg: ModelConfig, noise,
                  temperature, *, greedy: bool, top_k: int, shard=None):
    """k-token speculative verify: tokens [B, T] (committed next token +
    T-1 draft proposals per slot) run as ONE batched multi-query paged-
    attention dispatch under the serve policy.  Returns ([B, T] int32
    target tokens, cache' with length + T) — row (b, j) is bitwise the
    token T sequential `decode_and_sample` calls would have emitted at
    that position given the same inputs, so callers accept the longest
    draft prefix that matches and roll back the rest on the host.

    noise: [B*T, V] f32 gumbel rows from `sample_noise` over per-(slot,
    draw-index) keys, b-major (None when greedy); temperature: f32 scalar
    (ignored when greedy)."""
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    mod = _mod(cfg)
    if not hasattr(mod, "decode_verify"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no k-token verify step")
    from . import common
    spec = common.SampleSpec(noise=noise, temperature=temperature,
                             greedy=greedy, top_k=top_k)
    return mod.decode_verify(params, tokens, cache, cfg, shard=shard,
                             sample=spec)


def prefill_chunk(params, tokens, cache, slot, cfg: ModelConfig, shard=None):
    """Process one prompt chunk [1, C] for one slot of a serving cache
    (dense or paged) at positions length[slot] + [0, C).  The serving
    engine's chunked-prefill path: fixed bucketed chunk shapes instead of
    a retrace per prompt length, writes straight into the slot's cache/
    pages instead of a whole-cache splice.  shard: optional kv_pages
    PageShard (inside a shard_map; see decode_step)."""
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no autoregressive serving")
    return _mod(cfg).prefill_chunk(params, tokens, cache, slot, cfg,
                                   shard=shard)


def prefill_chunk_batched(params, tokens, cache, active, cfg: ModelConfig,
                          shard=None):
    """Cross-slot batched chunked prefill: advance every active slot by one
    same-size chunk in a single [B, C] program.  tokens: [B, C] int32
    (inactive rows are padding); active: [B] bool.  The caller zeroes
    inactive rows' length/block-table metadata (paged writes land on the
    trash page); inactive rows of batch-dim state (dense KV, SSM/conv) are
    reverted internally.  One compile per chunk bucket — the serving
    engine's batched-prefill path.  shard: optional kv_pages PageShard
    (inside a shard_map; see decode_step).  Returns (last-position logits
    [B, V], cache')."""
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no autoregressive serving")
    return _mod(cfg).prefill_chunk_batched(params, tokens, cache, active, cfg,
                                           shard=shard)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                layout: Optional[PagedLayout] = None):
    return _mod(cfg).cache_specs(cfg, batch, max_seq, layout)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               layout: Optional[PagedLayout] = None):
    return _mod(cfg).init_cache(cfg, batch, max_seq, layout)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, never allocated)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels=True):
    """Model inputs for one assigned shape cell.

    train/prefill: full [B, S] token batch (plus stub-frontend embeddings
    for audio/vlm, which replace/augment part of the sequence).
    decode: one token per row; the KV/SSM cache comes from cache_specs.
    """
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if shape.kind == "decode":
        batch["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        return batch
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend == "vision_stub":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
        batch["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.frontend_tokens), jnp.int32)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if with_labels and shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def abstract_state(cfg: ModelConfig):
    return abstract_params(param_specs(cfg))
