"""Mixture-of-Experts LM — qwen3-moe-235b (128e top-8) and
deepseek-moe-16b (2 shared + 64 routed top-6, fine-grained).

Expert dispatch is sort-based with a capacity limit (GShard-style dropping,
the scheme production JAX MoE stacks use): token->expert choices are sorted
by expert id, ranked within expert, scattered into an [E, C, D] buffer that
is *expert-sharded over the model axis* (EP) — XLA SPMD materializes the
all-to-alls.  Attention/embedding blocks reuse `transformer`.

Every expert einsum (we_gate / we_up / we_down, both dispatch flavors)
routes through `common.qdot_grouped` -> `kernels/dispatch.qdot_grouped`:
fake_quant for training, the batched Pallas fused kernel over packed posit
expert stacks for serving, chunked-PDPU per expert for validation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from . import common, transformer
from .config import ModelConfig
from .module import ParamSpec


def param_specs(cfg: ModelConfig):
    specs = transformer.param_specs(cfg)
    L, D, E, Fe = cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    layers = specs["layers"]
    # replace the dense FFN with routed experts (+ optional shared experts)
    for k in ("wi_gate", "wi_up", "wo_mlp"):
        del layers[k]
    layers.update({
        "router": ParamSpec((L, D, E), ("layers", "embed", "experts"), "fan_in"),
        "we_gate": ParamSpec((L, E, D, Fe), ("layers", "experts", "embed", "expert_mlp"), "fan_in"),
        "we_up": ParamSpec((L, E, D, Fe), ("layers", "experts", "embed", "expert_mlp"), "fan_in"),
        "we_down": ParamSpec((L, E, Fe, D), ("layers", "experts", "expert_mlp", "embed"), "fan_in"),
    })
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        layers.update({
            "ws_gate": ParamSpec((L, D, Fs), ("layers", "embed", "mlp"), "fan_in"),
            "ws_up": ParamSpec((L, D, Fs), ("layers", "embed", "mlp"), "fan_in"),
            "ws_down": ParamSpec((L, Fs, D), ("layers", "mlp", "embed"), "fan_in"),
        })
    return specs


def moe_ffn(p, x, cfg: ModelConfig):
    """Routed expert FFN. x: [B, S, D] -> ([B, S, D], aux_loss scalar)."""
    if cfg.moe_grouped_dispatch:
        return moe_ffn_grouped(p, x, cfg)
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)

    # --- router ------------------------------------------------------------
    logits = jnp.dot(xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    vals, ids = jax.lax.top_k(probs, k)      # [T, k]
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum(f_e * p_e)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch with capacity ---------------------------------
    C = int(T * k / E * cfg.capacity_factor)
    C = max(8, -(-C // 8) * 8)
    flat_e = ids.reshape(-1)                           # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)  # E*C == drop bucket
    tok = order // k

    buf = jnp.zeros((E * C, D), x.dtype).at[slot].set(xf[tok], mode="drop")
    cap_axis = "expert_cap" if cfg.shard_expert_cap else None
    buf = sharding.constrain(buf.reshape(E, C, D),
                             ("experts", cap_axis, "embed_act"))

    # --- expert computation (EP over the model axis) -----------------------
    # the grouped GEMM dispatch: stacked [E, D, F] expert weights (float
    # masters or packed posit codes) against the [E, C, D] dispatch buffer
    wq = cfg.quant
    g = common.qdot_grouped(buf, p["we_gate"], wq, out_dtype=jnp.float32)
    u = common.qdot_grouped(buf, p["we_up"], wq, out_dtype=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    h = sharding.constrain(h, ("experts", cap_axis, "expert_mlp"))
    out_e = common.qdot_grouped(h, p["we_down"], wq,
                                prec_dtype=common.tp_prec(cfg),
                                out_dtype=x.dtype)
    out_e = out_e.reshape(E * C, D)

    # --- combine ------------------------------------------------------------
    slot_c = jnp.minimum(slot, E * C - 1)
    per_choice = jnp.where(keep[:, None], out_e[slot_c], 0.0)
    per_choice = per_choice * vals.reshape(-1)[order][:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok].add(per_choice)
    y = y.reshape(B, S, D)

    # --- shared experts (deepseek-moe) --------------------------------------
    if cfg.n_shared_experts:
        hn = x  # shared experts see the same normalized input as routed ones
        g = common.qdot(hn, p["ws_gate"], wq)
        u = common.qdot(hn, p["ws_up"], wq)
        hs = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = y + common.qdot(hs, p["ws_down"], wq, prec_dtype=common.tp_prec(cfg))
    return y, aux


def moe_ffn_grouped(p, x, cfg: ModelConfig):
    """GShard-style grouped dispatch: each sequence is a routing group.

    All index-space work (top-k, sort, rank, scatter, combine-gather) is
    vmapped over the batch dim, which is sharded over (pod, data) — it
    stays shard-local.  The only cross-device movement is the expert einsum
    itself: buf [B, E, Cg, D] is batch-sharded x expert-sharded, which is
    exactly the EP exchange pattern, instead of SPMD replicating a global
    [B*S*k, D] gather/scatter (the flat path's failure mode — §Perf)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    Cg = int(S * k / E * cfg.capacity_factor)
    Cg = max(4, -(-Cg // 4) * 4)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # [B, S, E]
    vals, ids = jax.lax.top_k(probs, k)      # [B, S, k]
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean((0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        jnp.ones((B * S * k,), jnp.float32)) / (B * S * k)
    aux = E * jnp.sum(me * ce)

    def dispatch_group(xf, ids_g):
        """xf: [S, D]; ids_g: [S, k] -> (buf, slot, tok, keep, order)."""
        flat_e = ids_g.reshape(-1)                       # [S*k]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(S * k, dtype=jnp.int32) - starts[sorted_e]
        keep = rank < Cg
        slot = jnp.where(keep, sorted_e * Cg + rank, E * Cg)
        tok = order // k
        buf = jnp.zeros((E * Cg, D), x.dtype).at[slot].set(xf[tok], mode="drop")
        return buf, slot, tok, keep, order

    buf, slot, tok, keep, order = jax.vmap(dispatch_group)(x, ids)
    buf = buf.reshape(B, E, Cg, D)
    # two-phase: the scatter runs batch-local (replicated over 'model'),
    # THEN the buffer reshard to expert sharding is one clean collective —
    # keeps SPMD from partitioning the scatter itself (AR-of-one-hot blowup)
    buf = sharding.constrain(buf, ("batch", None, None, "embed_act"))
    buf = sharding.constrain(buf, ("batch", "experts", None, "embed_act"))

    # grouped GEMM dispatch over the batched [B, E, Cg, D] buffer — the
    # batch dim folds onto the per-expert rows inside qdot_grouped
    wq = cfg.quant
    g = common.qdot_grouped(buf, p["we_gate"], wq, out_dtype=jnp.float32)
    u = common.qdot_grouped(buf, p["we_up"], wq, out_dtype=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    h = sharding.constrain(h, ("batch", "experts", None, "expert_mlp"))
    out_e = common.qdot_grouped(h, p["we_down"], wq,
                                prec_dtype=common.tp_prec(cfg),
                                out_dtype=x.dtype)
    out_e = sharding.constrain(out_e, ("batch", "experts", None, "embed_act"))

    def combine_group(out_g, slot_g, tok_g, keep_g, order_g, vals_g):
        out_flat = out_g.reshape(E * Cg, D)
        per_choice = jnp.where(keep_g[:, None],
                               out_flat[jnp.minimum(slot_g, E * Cg - 1)], 0.0)
        w = vals_g.reshape(-1)[order_g][:, None].astype(out_flat.dtype)
        return jnp.zeros((S, D), out_flat.dtype).at[tok_g].add(per_choice * w)

    y = jax.vmap(combine_group)(out_e, slot, tok, keep, order, vals)
    y = sharding.constrain(y, ("batch", None, "embed_act"))

    if cfg.n_shared_experts:
        g2 = common.qdot(x, p["ws_gate"], wq)
        u2 = common.qdot(x, p["ws_up"], wq)
        hs = jax.nn.silu(g2.astype(jnp.float32)).astype(x.dtype) * u2
        y = y + common.qdot(hs, p["ws_down"], wq,
                            prec_dtype=common.tp_prec(cfg))
    return y, aux


def _layer(p, x, cfg: ModelConfig, q_pos, kv_pos, is_global):
    attn, k, v = transformer._attn_block(p, x, cfg, q_pos, kv_pos, is_global)
    x = x + attn
    h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
    ff, aux = moe_ffn(p, h, cfg)
    x = x + ff
    x = sharding.constrain(x, ("batch", None, "embed_act"))
    return x, aux


def apply(params, batch, cfg: ModelConfig, collect_cache: bool = False,
          with_aux: bool = False):
    x = transformer._embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    flags = transformer.layer_flags(cfg)

    def body(carry, xs):
        layer_params, is_global = xs
        x = carry
        attn, k, v = transformer._attn_block(layer_params, x, cfg, pos, pos, is_global)
        x = x + attn
        h = common.rms_norm(x, layer_params["ln2"], upcast=not cfg.tp_bf16_reduce)
        ff, aux = moe_ffn(layer_params, h, cfg)
        x = x + ff
        x = sharding.constrain(x, ("batch", None, "embed_act"))
        return x, (aux, (k, v) if collect_cache else None)

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "layer" else body
    x, (auxes, kvs) = jax.lax.scan(body_fn, x, (params["layers"], flags))
    x = common.rms_norm(x, params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    aux = jnp.mean(auxes)
    outs = [logits]
    if collect_cache:
        outs.append(kvs)
    if with_aux:
        outs.append(aux)
    return outs[0] if len(outs) == 1 else tuple(outs)


cache_specs = transformer.cache_specs
init_cache = transformer.init_cache


def prefill(params, batch, cfg: ModelConfig, max_seq=None):
    logits, (ks, vs) = apply(params, batch, cfg, collect_cache=True)
    B, S = ks.shape[1], ks.shape[2]
    max_seq = max_seq or S
    fold = lambda t: common.kv_encode(cfg, t.reshape(cfg.n_layers, B, S, -1))
    k_cache, v_cache = fold(ks), fold(vs)
    if max_seq > S:
        pad = ((0, 0), (0, 0), (0, max_seq - S), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    return logits, {"k": k_cache, "v": v_cache,
                    "length": jnp.full((B,), S, jnp.int32)}


def prefill_chunk(params, tokens, cache, slot, cfg: ModelConfig, shard=None):
    """Chunked prefill with MoE FFN (see transformer.prefill_chunk;
    returns the last position's logits [1, 1, V] only).

    Expert routing is per token; the capacity limit applies within the
    chunk, so smoke-scale capacity factors avoid drops per chunk exactly
    as they do per full prompt.  The attention stage rides transformer's
    `_chunk_attn`, so the fused prefill program (QuantPolicy.fused_prefill)
    applies to MoE paged serving unchanged."""
    C = tokens.shape[1]
    x = common.embed_tokens(params["embed"], tokens, cfg)
    start = cache["length"][slot]
    flags = transformer.layer_flags(cfg)
    bt_row = cache["block_table"][slot] if "block_table" in cache else None

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        attn, k_new, v_new = transformer._chunk_attn(
            p, x, cfg, k_l, v_l, start, bt_row=bt_row,
            slot=None if bt_row is not None else slot, is_global=is_global,
            shard=shard)
        x = x + attn
        h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
        ff, _ = moe_ffn(p, h, cfg)
        x = x + ff
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    new_cache = dict(cache)
    new_cache.update(k=k_c, v=v_c,
                     length=cache["length"].at[slot].set(start + C))
    return logits, new_cache


def prefill_chunk_batched(params, tokens, cache, active, cfg: ModelConfig,
                          shard=None):
    """Cross-slot batched chunked prefill with MoE FFN (see
    transformer.prefill_chunk_batched).  The capacity limit applies over
    the whole [B, C] batch; smoke-scale capacity factors are drop-proof
    (capacity >= tokens), so active rows stay bit-identical to the
    per-slot path regardless of batch composition."""
    B, C = tokens.shape
    x = common.embed_tokens(params["embed"], tokens, cfg)
    starts = cache["length"]
    flags = transformer.layer_flags(cfg)
    bt = cache.get("block_table")

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        attn, k_new, v_new = transformer._chunk_attn_batched(
            p, x, cfg, k_l, v_l, starts, bt=bt, is_global=is_global,
            shard=shard)
        x = x + attn
        h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
        ff, _ = moe_ffn(p, h, cfg)
        x = x + ff
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = common.logits_head(
        x, params["embed"] if cfg.tie_embeddings else params["head"],
        cfg, transpose=cfg.tie_embeddings)
    if bt is None:
        m = active[None, :, None, None]
        k_c = jnp.where(m, k_c, cache["k"])
        v_c = jnp.where(m, v_c, cache["v"])
    new_cache = dict(cache)
    new_cache.update(
        k=k_c, v=v_c,
        length=cache["length"] + jnp.where(active, C, 0).astype(jnp.int32))
    return logits[:, 0], new_cache


def _decode_step_paged(params, tokens, cache, cfg: ModelConfig, shard=None,
                       sample=None):
    """Paged decode with MoE FFN (see transformer._decode_step_paged)."""
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    length = cache["length"]
    bt = cache["block_table"]
    flags = transformer.layer_flags(cfg)

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        attn, k_new, v_new = transformer._paged_attn_token(
            p, x, cfg, k_l, v_l, bt, length, is_global, shard=shard)
        x = x + attn
        h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
        ff, _ = moe_ffn(p, h, cfg)
        x = x + ff
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "block_table": bt, "length": length + 1}
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if sample is not None:
        return common.sample_head(x[:, 0], head, cfg, sample,
                                  transpose=cfg.tie_embeddings), new_cache
    logits = common.logits_head(x, head, cfg, transpose=cfg.tie_embeddings)
    return logits[:, 0], new_cache


def decode_step(params, tokens, cache, cfg: ModelConfig, shard=None,
                sample=None):
    """One autoregressive step with MoE FFN."""
    if "block_table" in cache:
        return _decode_step_paged(params, tokens, cache, cfg, shard=shard,
                                  sample=sample)
    if shard is not None:
        raise ValueError("kv_pages sharding requires a paged cache")
    B = tokens.shape[0]
    x = common.embed_tokens(params["embed"], tokens[:, None], cfg)
    S_max = cache["k"].shape[2]
    length = cache["length"]
    q_pos = length[:, None]
    kv_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None], (B, S_max))
    flags = transformer.layer_flags(cfg)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim

    def body(x, xs):
        p, is_global, k_l, v_l = xs
        h = common.rms_norm(x, p["ln1"])
        q = common.qdot(h, p["wq"], cfg.quant).reshape(B, 1, cfg.n_heads, Dh)
        k = common.qdot(h, p["wk"], cfg.quant).reshape(B, 1, Hkv, Dh)
        v = common.qdot(h, p["wv"], cfg.quant).reshape(B, 1, Hkv, Dh)
        if cfg.qk_norm:
            q = common.rms_norm(q, p["q_norm"])
            k = common.rms_norm(k, p["k_norm"])
        q = common.rope(q, q_pos, cfg.rope_theta)
        k = common.rope(k, q_pos, cfg.rope_theta)
        k_new = transformer._cache_insert(k_l, common.kv_encode(cfg, k.reshape(B, 1, -1)), length)
        v_new = transformer._cache_insert(v_l, common.kv_encode(cfg, v.reshape(B, 1, -1)), length)
        kc = common.kv_decode(cfg, k_new).reshape(B, S_max, Hkv, Dh)
        vc = common.kv_decode(cfg, v_new).reshape(B, S_max, Hkv, Dh)
        attn = common.decode_attention(q, kc, vc, length + 1, kv_pos,
                                       window=None, softcap_val=cfg.logit_softcap)
        x = x + common.qdot(attn.reshape(B, 1, cfg.n_heads * Dh), p["wo"], cfg.quant)
        h = common.rms_norm(x, p["ln2"], upcast=not cfg.tp_bf16_reduce)
        ff, _ = moe_ffn(p, h, cfg)
        x = x + ff
        return x, (k_new, v_new)

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.rms_norm(x, params["final_norm"])
    new_cache = {"k": k_c, "v": v_c, "length": length + 1}
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if sample is not None:
        return common.sample_head(x[:, 0], head, cfg, sample,
                                  transpose=cfg.tie_embeddings), new_cache
    logits = common.logits_head(x, head, cfg, transpose=cfg.tie_embeddings)
    return logits[:, 0], new_cache
