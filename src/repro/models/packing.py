"""One-shot param packing: float checkpoints -> posit-code weight arrays.

The paper's storage win — P(n<=16) weights in int8/int16 containers — only
materializes if the *checkpoint* holds codes and the serving matmul decodes
them in-kernel (`kernels/dispatch.py`, execution='fused').  This module is
the conversion pass:

    params_packed = pack_params(params, cfg)        # float -> codes
    mgr.save(step, params_packed, extra=pack_manifest(cfg))
    ...
    engine = ServingEngine.from_checkpoint(cfg, dir, ...)   # serves codes

Only weights consumed through the GEMM dispatch layer are packed (per
family, below) — dense projections via `qdot`, routed MoE expert stacks
(we_*) via `qdot_grouped`, SSM in/out projections via `qdot`.  Other leaves
— norms, embeddings read by jnp.take, routers, conv taps, SSM scan params —
stay float.  Packing is one rounding per weight (posit encode), identical
to what fake_quant applies on the fly, so a packed model served fused
computes the same quantized function.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import PositFormat
from .config import ModelConfig
from .module import ParamSpec

# weight-leaf names consumed via the GEMM dispatch layer, by sub-family.
_ATTN_NAMES = ("wq", "wk", "wv", "wo")
_MLP_NAMES = ("wi_gate", "wi_up", "wo_mlp")
_EXPERT_NAMES = ("we_gate", "we_up", "we_down")   # stacked: qdot_grouped
_SHARED_EXPERT_NAMES = ("ws_gate", "ws_up", "ws_down")
_SSM_NAMES = ("in_proj", "out_proj")

_SUPPORTED_FAMILIES = ("dense", "encoder", "vlm", "moe", "ssm", "hybrid")


def packable_paths(cfg: ModelConfig) -> Tuple[Tuple[str, ...], ...]:
    """Paths (key tuples) of the weight leaves that pack to posit codes."""
    fam = cfg.family
    if fam in ("dense", "encoder", "vlm"):
        paths = [("layers", n) for n in _ATTN_NAMES + _MLP_NAMES]
    elif fam == "moe":
        names = _ATTN_NAMES + _EXPERT_NAMES
        if cfg.n_shared_experts:
            names += _SHARED_EXPERT_NAMES
        paths = [("layers", n) for n in names]
    elif fam == "ssm":
        paths = [("layers", n) for n in _SSM_NAMES]
    elif fam == "hybrid":
        # jamba-style blocks: attention + mamba + dense-FFN + MoE sub-trees
        paths = [("blocks", "attn", n) for n in _ATTN_NAMES]
        paths += [("blocks", "mamba", n) for n in _SSM_NAMES]
        paths += [("blocks", "ffn", n) for n in _MLP_NAMES]
        paths += [("blocks", "moe", n) for n in _EXPERT_NAMES]
    else:
        raise NotImplementedError(
            f"param packing not supported for family '{fam}' "
            f"(have {sorted(_SUPPORTED_FAMILIES)})")
    if not cfg.tie_embeddings:
        paths.append(("head",))
    return tuple(paths)


def _get(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _set(tree, path, value):
    for k in path[:-1]:
        tree = tree[k]
    tree[path[-1]] = value


def _copy_tree(tree):
    if isinstance(tree, dict):
        return {k: _copy_tree(v) for k, v in tree.items()}
    return tree


def pack_params(params, cfg: ModelConfig, fmt: PositFormat = None):
    """Replace every packable float weight with posit codes (int8/int16).

    One rounding per weight — the same rounding fake_quant applies on every
    forward pass, applied once at conversion time instead.
    """
    fmt = fmt or cfg.quant.weights
    if fmt is None:
        raise ValueError("pack_params needs a weights format "
                         "(cfg.quant.weights or explicit fmt)")
    packed = _copy_tree(params)
    for path in packable_paths(cfg):
        leaf = _get(params, path)
        _set(packed, path, posit.pack(jnp.asarray(leaf, jnp.float32), fmt))
    return packed


def unpack_params(params, cfg: ModelConfig, fmt: PositFormat = None,
                  dtype=jnp.float32):
    """Inverse of pack_params: decode code leaves back to float arrays."""
    fmt = fmt or cfg.quant.weights
    if fmt is None:
        raise ValueError("unpack_params needs a weights format")
    out = _copy_tree(params)
    for path in packable_paths(cfg):
        leaf = _get(params, path)
        _set(out, path, posit.unpack(leaf, fmt, dtype=dtype))
    return out


def packed_param_specs(cfg: ModelConfig, fmt: PositFormat = None):
    """param_specs with packable leaves re-typed to the code storage dtype —
    the `like` tree for restoring a packed checkpoint (checkpoint.restore)."""
    from . import api

    fmt = fmt or cfg.quant.weights
    if fmt is None:
        raise ValueError("packed_param_specs needs a weights format")
    storage = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[fmt.storage_bits]
    out = _copy_tree(api.param_specs(cfg))
    for path in packable_paths(cfg):
        spec = _get(out, path)
        _set(out, path, spec._replace(dtype=storage))
    return out


def pack_manifest(cfg: ModelConfig, fmt: PositFormat = None) -> dict:
    """Checkpoint `extra` metadata marking a packed-weights checkpoint."""
    fmt = fmt or cfg.quant.weights
    if fmt is None:
        raise ValueError("pack_manifest needs a weights format "
                         "(cfg.quant.weights or explicit fmt)")
    return {"packed_weights": True, "weights_format": str(fmt),
            "weights_n": fmt.n, "weights_es": fmt.es}


def weight_bytes(params) -> int:
    """Total weight storage footprint (the HBM-resident bytes for weights)."""
    return int(sum(x.nbytes for x in jax.tree.leaves(params)))
