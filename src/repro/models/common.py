"""Shared transformer building blocks (pure JAX, posit-quant aware).

Every matmul routes through `qdot`, which hands off to the posit GEMM
dispatch layer (`kernels/dispatch.py`): the QuantPolicy's execution plan
decides whether the dot fake-quantizes on float (training), runs the fused
Pallas kernel over posit codes (serving — weights packed, and activations
too when the policy sets an activation format), or runs the bit-exact
chunked-PDPU kernel (validation).  Both fake_quant and fused are trainable:
the fused plan carries a custom_vjp STE backward, so QAT can run the packed
kernel forward end to end.  All plans keep the PDPU contract —
low-precision posit operands, wide f32 accumulation.

Attention is a flash-style streaming softmax over KV chunks (lax.scan), so
prefill_32k never materializes an S x S score matrix; sliding-window layers
restrict work to the diagonal band.  KV caches may be stored as posit codes
(int8/int16) per the QuantPolicy — decoded exactly on read.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.quant import QuantPolicy
from repro.kernels import dispatch, ops
from repro.parallel import sharding
from .config import ModelConfig

_NEG = -2.0e38


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def qdot(x, w, policy: QuantPolicy, prec_dtype=jnp.float32):
    """Posit-quantized matmul with wide accumulation (PDPU semantics).

    x: [..., K] activations; w: [K, N] weights — float masters or packed
    posit codes.  The execution plan (policy.execution) picks the datapath;
    see kernels/dispatch.py.  Every plan accumulates wide (f32) — the fused
    wide-accumulator property.

    prec_dtype is the *HLO output dtype* of the fake_quant dot: on TPU the
    MXU always accumulates f32 internally, but when the contraction dim is
    TP-sharded the dot output dtype is what the partial-sum all-reduce
    ships.  Models pass the compute dtype here when cfg.tp_bf16_reduce is on.
    """
    return dispatch.qdot(x, w, policy, prec_dtype=prec_dtype)


def qdot_grouped(x, w, policy: QuantPolicy, prec_dtype=jnp.float32,
                 out_dtype=None):
    """Grouped qdot over stacked expert weights (MoE expert einsums).

    x: [E, C, K] or [B, E, Cg, K]; w: [E, K, N] — float masters or packed
    posit codes.  Same plan semantics as `qdot`, per expert; the fused plan
    runs the batched Pallas kernel so EP serving reads expert stacks as
    int8/int16 codes.  See kernels/dispatch.qdot_grouped.
    """
    return dispatch.qdot_grouped(x, w, policy, prec_dtype=prec_dtype,
                                 out_dtype=out_dtype)


def tp_prec(cfg) -> jnp.dtype:
    """Output dtype for TP-contracted projections (see qdot)."""
    return cfg.compute_dtype if cfg.tp_bf16_reduce else jnp.float32


def wgather(cfg, w, tp_axes):
    """Weight-gather FSDP: re-constrain a weight to TP-only sharding right
    before its matmul, so the FSDP shard is all-gathered (in the compute
    dtype) rather than resolved by partial-summing activation-sized f32
    tensors across the data axis (cfg.fsdp_gather_weights)."""
    if not cfg.fsdp_gather_weights:
        return w
    return sharding.constrain(w, tp_axes)


def rms_norm(x, scale, eps=1e-6, upcast=True):
    """RMSNorm. The variance reduction is always f32; with upcast=False the
    full-tensor normalize runs in x.dtype — no f32 activation tensor is
    materialized, so SPMD all-reduces of the producing dot stay bf16
    (used when ModelConfig.tp_bf16_reduce is on)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    if upcast:
        out = x.astype(jnp.float32) * inv
        return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
    out = x * inv.astype(x.dtype)
    return out * (1.0 + scale).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embeddings. x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    ang = ang[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                    window: Optional[int], chunk_k: int = 1024,
                    softcap_val: float = 0.0):
    """Streaming-softmax attention over KV chunks (never S x S resident).

    q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, D]; GQA via Hq = G * Hkv.
    q_pos: [B, Sq], kv_pos: [B, Skv] absolute positions for masking.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32) * scale

    ck = min(chunk_k, Skv)
    n_chunks = -(-Skv // ck)
    pad = n_chunks * ck - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(B, n_chunks, ck, Hkv, D)
    vc = v.reshape(B, n_chunks, ck, Hkv, D)
    pc = kv_pos.reshape(B, n_chunks, ck)

    def step(carry, blk):
        m, l, o = carry
        kb, vb, pb = blk  # [B, ck, Hkv, D], [B, ck]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(jnp.float32))
        s = softcap(s, softcap_val)
        mask = pb[:, None, None, None, :] >= 0
        if causal:
            mask &= q_pos[:, None, None, :, None] >= pb[:, None, None, None, :]
        if window is not None:
            mask &= (q_pos[:, None, None, :, None] - pb[:, None, None, None, :]) < window
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        o_new = o * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Hkv, G, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(o, 3, 1).reshape(B, Sq, Hq, D)  # [B,Sq,Hkv,G,D]->merge
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, kv_pos, *,
                     window: Optional[int], softcap_val: float = 0.0):
    """Single-token attention over a (possibly posit-coded) KV cache.

    q: [B, 1, Hq, D]; k_cache/v_cache: [B, S, Hkv, D] floats (already
    decoded by the caller if stored as posit); cache_len: [B] valid length.

    The cache's sequence dim is sharded over the 'model' axis (kv_seq); the
    score/softmax path is constrained to keep that sharding so each shard
    attends over its local cache slice (flash-decode style: XLA emits the
    tiny max/sum partial reductions instead of all-gathering the cache).
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    k_cache = sharding.constrain(k_cache, ("batch", "kv_seq", None, None))
    v_cache = sharding.constrain(v_cache, ("batch", "kv_seq", None, None))
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    s = softcap(s, softcap_val)
    s = sharding.constrain(s, ("batch", None, None, "kv_seq"))
    q_pos = cache_len[:, None]  # this token's position == #valid entries
    mask = kv_pos < q_pos  # [B, S]
    if window is not None:
        mask &= (q_pos - kv_pos) <= window
    s = jnp.where(mask[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = sharding.constrain(p, ("batch", None, None, "kv_seq"))
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache with optional posit storage (QuantPolicy.kv_cache)
# ---------------------------------------------------------------------------

def kv_store_dtype(cfg: ModelConfig):
    fmt = cfg.quant.kv_cache
    if fmt is None:
        return cfg.compute_dtype
    return {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[fmt.storage_bits]


def kv_encode(cfg: ModelConfig, x):
    fmt = cfg.quant.kv_cache
    if fmt is None:
        return x.astype(cfg.compute_dtype)
    return posit.pack(x, fmt)


def kv_decode(cfg: ModelConfig, x):
    fmt = cfg.quant.kv_cache
    if fmt is None:
        return x
    return posit.unpack(x, fmt, dtype=cfg.compute_dtype)


# ---------------------------------------------------------------------------
# embeddings / head / losses
# ---------------------------------------------------------------------------

def embed_tokens(emb, tokens, cfg: ModelConfig):
    x = jnp.take(emb, tokens, axis=0).astype(cfg.compute_dtype)
    return sharding.constrain(x, ("batch", None, "embed_act"))


def logits_head(x, emb_or_head, cfg: ModelConfig, transpose: bool):
    # the head historically quantizes only the weights — final hidden states
    # reach the vocab projection unquantized regardless of the policy (under
    # activation-coded fused serving the head therefore takes the
    # float-activation fast path while the trunk runs both operands coded)
    policy = cfg.quant
    if policy.activations is not None:
        policy = dataclasses.replace(policy, activations=None)
    w = emb_or_head
    if transpose:  # tied embedding [V, D] -> project with its transpose
        w = w.T    # lossless for packed posit codes too (pure reindexing)
    out = dispatch.qdot(x, w, policy, prec_dtype=jnp.float32,
                        out_dtype=jnp.float32)
    out = softcap(out, cfg.logit_softcap)
    return sharding.constrain(out, ("batch", None, "vocab"))


@dataclasses.dataclass
class SampleSpec:
    """Sampling epilogue parameters for the fused one-program decode step.

    Constructed inside the engine's jit'd decode function (never crosses a
    jit boundary, so no pytree registration): `noise` is per-slot standard
    gumbel [B, V] (None when greedy — categorical(key, l) == argmax of
    gumbel + l), `temperature` a traced f32 scalar, `greedy`/`top_k` static.
    """
    noise: Optional[jax.Array]
    temperature: jax.Array
    greedy: bool
    top_k: int


def sample_head(x, emb_or_head, cfg: ModelConfig, sample: SampleSpec,
                transpose: bool):
    """Fused replacement for `logits_head` + the serving sampler.

    Replays logits_head's head qdot plan (weights-only quantization, f32
    accumulate, logit softcap) and the temperature/top-k/gumbel sampler in
    one Pallas program (ops.decode_sample), streaming the vocab axis so the
    [B, V] logits never round-trip through HBM.  Bit-identical tokens to
    the two-program logits_head -> sampler path.

    x: [B, D] hidden rows (one decode token per slot).  The head weights
    stay untransposed — the kernel transposes per vocab tile, which commutes
    with the elementwise decode.  bit_exact plans have no fused head
    (the engine keeps the decomposed path there).
    """
    policy = cfg.quant
    w = emb_or_head
    fmt_w = policy.weights
    if policy.execution == "fake_quant":
        plan = "fake_quant"
        if not dispatch.is_packed(w):
            # float masters: qdot fake-quantizes the weights on float before
            # the dot (elementwise, so it commutes with the in-kernel
            # transpose) and the kernel sees plain float weights
            w = policy.maybe_quant_weight(w.astype(x.dtype))
            fmt_w = None
    elif policy.execution == "fused":
        plan = "fused"
        if not dispatch.is_packed(w) and fmt_w is not None:
            # the STE forward: encode float masters once, decode in-kernel
            # (ops._ste_primal's matmul_posit_weights path)
            w = ops.encode(w.astype(jnp.float32), fmt_w)
    else:
        raise ValueError(f"no fused decode head for execution plan "
                         f"{policy.execution!r}")
    return ops.decode_sample(
        x, w, sample.noise, sample.temperature, plan=plan, fmt_w=fmt_w,
        transpose=transpose, greedy=sample.greedy, top_k=sample.top_k,
        softcap_val=cfg.logit_softcap)


def cross_entropy(logits, labels, mask=None):
    """Stable CE over a (possibly vocab-sharded) logits tensor. f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
