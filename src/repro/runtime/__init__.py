"""Fault-tolerance control plane: heartbeats, stragglers, elastic rescale."""
from .fault_tolerance import (  # noqa: F401
    HeartbeatMonitor, HeartbeatConfig, StragglerDetector, NaNGuard,
    plan_rescale, RescalePlan,
)
