"""Fault-tolerance runtime: heartbeats, straggler detection, restart and
elastic-rescale planning.

At 1000+-node scale the failure model is: slow chips (stragglers), dead
hosts (restart from checkpoint on fewer/more hosts), and flaky steps
(NaN/inf from bad HBM).  This module is the *control plane* — pure host
logic, unit-testable without hardware; the data plane hooks are in
`train.trainer` (step timing feed, emergency checkpoint, skip-restore) and
`checkpoint` (elastic resharding restore).
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Dict, List, Optional


@dataclasses.dataclass
class HeartbeatConfig:
    interval_s: float = 10.0
    miss_budget: int = 3          # missed beats before declared dead
    straggler_zscore: float = 3.0  # step-time z-score threshold
    straggler_window: int = 50
    min_steps_for_stats: int = 10


class HeartbeatMonitor:
    """Tracks per-host liveness from heartbeat timestamps."""

    def __init__(self, hosts: List[str], cfg: HeartbeatConfig = HeartbeatConfig()):
        self.cfg = cfg
        self.last_beat: Dict[str, float] = {h: time.monotonic() for h in hosts}

    def beat(self, host: str, now: Optional[float] = None):
        self.last_beat[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        budget = self.cfg.interval_s * self.cfg.miss_budget
        return [h for h, t in self.last_beat.items() if now - t > budget]


class StragglerDetector:
    """Online step-time outlier detection (median + MAD z-score).

    On TPU pods a straggler shows up as the *global* step time inflating
    (synchronous collectives), so the trainer feeds global step durations;
    in a per-host telemetry deployment, feed per-host times with the same
    API and mitigate by re-sharding around the slow host.
    """

    def __init__(self, cfg: HeartbeatConfig = HeartbeatConfig()):
        self.cfg = cfg
        self.times: deque = deque(maxlen=cfg.straggler_window)

    def observe(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        flagged = False
        if len(self.times) >= self.cfg.min_steps_for_stats:
            med = sorted(self.times)[len(self.times) // 2]
            mad = sorted(abs(t - med) for t in self.times)[len(self.times) // 2]
            sigma = 1.4826 * max(mad, 1e-9)
            flagged = (step_time_s - med) / sigma > self.cfg.straggler_zscore
        self.times.append(step_time_s)
        return flagged

    def stats(self):
        if not self.times:
            return {}
        med = sorted(self.times)[len(self.times) // 2]
        return {"median_s": med, "n": len(self.times)}


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    """What an elastic restart looks like after a membership change."""
    old_hosts: int
    new_hosts: int
    new_mesh_shape: tuple
    restore_step: int
    data_start_step: int
    note: str


def plan_rescale(available_hosts: int, chips_per_host: int,
                 restore_step: int, model_axis: int = 16,
                 pods: int = 1) -> RescalePlan:
    """Choose the largest valid mesh on the surviving hosts.

    Keeps the model axis fixed (TP degree is a property of the sharded
    layout) and shrinks/grows the data axis; the pod axis drops to 1 if a
    whole pod is lost.  Checkpoints are mesh-elastic, and the data pipeline
    is step-indexed, so the plan is just (mesh, step).
    """
    chips = available_hosts * chips_per_host
    if chips < model_axis:
        raise RuntimeError(
            f"{chips} chips cannot host model axis {model_axis}; "
            "restore requires at least one full model-parallel group")
    data_axis = chips // (model_axis * pods)
    while data_axis > 1 and (model_axis * data_axis * pods) > chips:
        data_axis -= 1
    shape = (pods, data_axis, model_axis) if pods > 1 else (data_axis, model_axis)
    return RescalePlan(
        old_hosts=-1, new_hosts=available_hosts,
        new_mesh_shape=shape, restore_step=restore_step,
        data_start_step=restore_step,
        note=f"elastic restart on {chips} chips: mesh {shape}, "
             f"deterministic data resume at step {restore_step}")


class NaNGuard:
    """Detects non-finite loss and decides skip vs restore."""

    def __init__(self, max_consecutive: int = 3):
        self.max_consecutive = max_consecutive
        self.consecutive = 0

    def observe(self, loss: float) -> str:
        """-> 'ok' | 'skip' (drop this step) | 'restore' (roll back)."""
        if math.isfinite(loss):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        return "restore" if self.consecutive >= self.max_consecutive else "skip"
