"""Discrete dot-product baselines the paper compares against (Fig. 1).

All baselines are *semantic emulations* on numpy float64: every value that a
real discrete unit would round into its storage format gets rounded at the
same place in the dataflow.  float64 carries >= 53 significand bits, far
beyond any posit/FP16 target here, so each individual rounding is exact for
accuracy-statistics purposes.

  - discrete DPU  (Fig. 1a): multipliers + adder tree, every intermediate
    packed/rounded to the unit format (PACoGen-style for posit, FPnew-style
    for IEEE floats).
  - FMA cascade   (Fig. 1b): sequential fused multiply-add, one rounding per
    MAC step.
  - fused PDPU    : `posit_np.pdpu_chunked_dot_np` (W_m-aligned, one
    rounding per chunk boundary) — the paper's proposal.
  - quire         : exact accumulate + single rounding (W_m = inf limit).
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .formats import PDPUConfig, PositFormat
from . import posit_np as pnp

RoundFn = Callable[[np.ndarray], np.ndarray]


# ---------------------------------------------------------------------------
# rounding functions (storage formats)
# ---------------------------------------------------------------------------

def round_fp64(x):
    return np.asarray(x, dtype=np.float64)


def round_fp32(x):
    return np.asarray(x, dtype=np.float64).astype(np.float32).astype(np.float64)


def round_fp16(x):
    return np.asarray(x, dtype=np.float64).astype(np.float16).astype(np.float64)


def make_round_posit(fmt: PositFormat) -> RoundFn:
    def _r(x):
        return pnp.quantize_np(np.asarray(x, dtype=np.float64), fmt)

    return _r


# ---------------------------------------------------------------------------
# discrete architectures (operate on float64 values along the last axis)
# ---------------------------------------------------------------------------

def dpu_discrete(a, b, N: int, rnd: RoundFn, acc=None):
    """Fig. 1(a): per chunk of N — round each product, reduce through a
    balanced adder tree with a rounding after every add, then fold into the
    running accumulator (also rounded)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    K = a.shape[-1]
    if K % N:
        raise ValueError(f"K={K} not divisible by N={N}")
    acc = np.zeros(a.shape[:-1]) if acc is None else np.asarray(acc, np.float64)
    a = rnd(a)
    b = rnd(b)
    for j in range(K // N):
        sl = slice(j * N, (j + 1) * N)
        terms = [rnd(a[..., i] * b[..., i]) for i in range(sl.start, sl.stop)]
        while len(terms) > 1:  # balanced adder tree, rounding per node
            nxt = []
            for i in range(0, len(terms) - 1, 2):
                nxt.append(rnd(terms[i] + terms[i + 1]))
            if len(terms) % 2:
                nxt.append(terms[-1])
            terms = nxt
        acc = rnd(acc + terms[0])
    return acc


def dpu_fma_cascade(a, b, rnd: RoundFn, acc=None):
    """Fig. 1(b): cascaded FMA units — exact product+add fused, one rounding
    per MAC step."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    acc = np.zeros(a.shape[:-1]) if acc is None else np.asarray(acc, np.float64)
    a = rnd(a)
    b = rnd(b)
    for i in range(a.shape[-1]):
        acc = rnd(acc + a[..., i] * b[..., i])
    return acc


def dpu_pdpu_fused(a, b, cfg: PDPUConfig, acc=None):
    """The paper's PDPU: quantize inputs to fmt_in, run the bit-faithful
    chunked fused datapath, return float64 values of the fmt_out codes."""
    a_codes = pnp.encode_np(np.asarray(a, np.float64), cfg.fmt_in)
    b_codes = pnp.encode_np(np.asarray(b, np.float64), cfg.fmt_in)
    acc_codes = None
    if acc is not None:
        acc_codes = pnp.encode_np(np.asarray(acc, np.float64), cfg.fmt_out)
    out = pnp.pdpu_chunked_dot_np(a_codes, b_codes, cfg, acc_codes)
    return pnp.decode_np(out, cfg.fmt_out)


def dpu_quire(a, b, fmt_in: PositFormat, fmt_out: PositFormat, acc=None):
    """Quire-exact reference: inputs posit-quantized, accumulation exact,
    single output rounding (the W_m -> inf limit of PDPU)."""
    cfg = PDPUConfig(fmt_in, fmt_out, N=4, w_m=4096)
    return dpu_pdpu_fused(a, b, cfg, acc)


# ---------------------------------------------------------------------------
# accuracy metric (paper Table I "Accuracy" column; formula documented in
# DESIGN.md — the paper does not specify its exact definition)
# ---------------------------------------------------------------------------

def accuracy_pct(y, y_ref, clip: float = 1.0) -> float:
    """100 * (1 - mean(min(|y - y_ref| / |y_ref|, clip))).

    Per-element relative error against the FP64 reference, clipped at
    ``clip`` so sign flips / zero crossings count as (at most) total loss of
    that element rather than an unbounded penalty."""
    y = np.asarray(y, np.float64)
    y_ref = np.asarray(y_ref, np.float64)
    denom = np.abs(y_ref)
    err = np.abs(y - y_ref)
    rel = np.where(denom > 0, err / np.maximum(denom, 1e-300), np.where(err > 0, clip, 0.0))
    rel = np.minimum(rel, clip)
    return float(100.0 * (1.0 - rel.mean()))
