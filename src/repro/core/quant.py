"""Framework-level posit quantization policy.

The paper positions PDPU as "the computing core of posit-based accelerators"
with mixed precision as a first-class strategy (§III-B): low-precision posit
inputs, higher-precision posit accumulator/output.  This module carries that
policy through the model stack: every matmul in `repro.models` consults a
`QuantPolicy` to decide which tensors are stored/computed in which posit
format, and the distributed optimizer uses `grad_format` for posit-compressed
gradient all-reduce.

On TPU the decode of a P(n<=16,es) code into f32 is *exact* (see
`core/posit.py`), so the MXU matmul over decoded posits with f32 accumulation
realizes the paper's "fused: decode once, accumulate wide, encode once"
semantics natively — the f32 accumulator plays the W_m-wide aligned
accumulator, and the single encode of the output applies the one rounding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .formats import PositFormat, P16_2, P13_2, P8_2
from . import posit


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which tensors travel through which posit format (None = keep float).

    weights     : storage/compute format of weight matrices.
    activations : format applied to matmul activations (inputs).
    kv_cache    : serving KV-cache storage format.
    grad_allreduce : gradient compression format for cross-replica reduce.
    accum_dtype : wide accumulation dtype — the W_m analogue on TPU.
    """

    weights: Optional[PositFormat] = None
    activations: Optional[PositFormat] = None
    kv_cache: Optional[PositFormat] = None
    grad_allreduce: Optional[PositFormat] = None
    accum_dtype: jnp.dtype = jnp.float32

    @property
    def enabled(self) -> bool:
        return any(f is not None for f in (self.weights, self.activations, self.kv_cache))

    def maybe_quant_weight(self, w):
        if self.weights is None:
            return w
        return posit.quantize_ste(w, self.weights)

    def maybe_quant_act(self, x):
        if self.activations is None:
            return x
        return posit.quantize_ste(x, self.activations)

    def maybe_quant_kv(self, kv):
        if self.kv_cache is None:
            return kv
        return posit.quantize(kv, self.kv_cache)


# The paper's headline mixed-precision configuration, P(13/16,2):
# low-precision inputs, higher-precision accumulation.
PAPER_MIXED = QuantPolicy(weights=P13_2, activations=P13_2)
# Uniform P(16,2) (Table I row 3).
UNIFORM_P16 = QuantPolicy(weights=P16_2, activations=P16_2)
# Serving policy: posit weights + posit KV cache, float activations.
SERVE_P16_KV8 = QuantPolicy(weights=P16_2, kv_cache=P8_2)
# No quantization (baseline).
NONE = QuantPolicy()


def policy_by_name(name: str) -> QuantPolicy:
    table = {
        "none": NONE,
        "paper_mixed": PAPER_MIXED,
        "uniform_p16": UNIFORM_P16,
        "serve_p16_kv8": SERVE_P16_KV8,
    }
    if name not in table:
        raise KeyError(f"unknown quant policy '{name}' (have {sorted(table)})")
    return table[name]
