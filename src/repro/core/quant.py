"""Framework-level posit quantization policy.

The paper positions PDPU as "the computing core of posit-based accelerators"
with mixed precision as a first-class strategy (§III-B): low-precision posit
inputs, higher-precision posit accumulator/output.  This module carries that
policy through the model stack: every matmul in `repro.models` consults a
`QuantPolicy` to decide which tensors are stored/computed in which posit
format, and the distributed optimizer uses `grad_format` for posit-compressed
gradient all-reduce.

Beyond the *formats*, the policy also selects the *execution plan* — which
datapath actually runs each matmul (`kernels/dispatch.py`).  The plan table
(`PLAN_TABLE`) records how each datapath may be used:

  fake_quant : decode(encode(x)) on both operands, then a plain f32 MXU dot
               with straight-through gradients.  Trainable + servable: exact
               posit values, full autodiff support, weights stay float.
  fused      : operands travel as posit *codes* (int8/int16) into the Pallas
               fused GEMM — in-kernel decode, wide f32 accumulate, single
               encode.  Trainable + servable: serving reads weights packed
               (see models/packing.py), halving/quartering weight HBM;
               training runs the same kernel forward with a custom_vjp STE
               backward (kernels/ops.fused_matmul_ste), so QAT loss/grads
               come from the real packed datapath.
  bit_exact  : the chunked-PDPU kernel — the paper's S1..S6 integer datapath
               including the W_m alignment truncation.  Forward-only
               validation at small shapes; O(M*N*K) select-chains, not fast.
               `jax.grad` through it raises (see TRAINABLE_PLANS).

On TPU the decode of a P(n<=16,es) code into f32 is *exact* (see
`core/posit.py`), so the MXU matmul over decoded posits with f32 accumulation
realizes the paper's "fused: decode once, accumulate wide, encode once"
semantics natively — the f32 accumulator plays the W_m-wide aligned
accumulator, and the single encode of the output applies the one rounding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .formats import PositFormat, PDPUConfig, P16_2, P16_1, P13_2, P8_2
from . import posit

@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """One row of the execution-plan table: how a datapath may be used.

    trainable : `jax.grad` flows through it (an STE backward exists).
    servable  : the serving engine may run it on the decode hot path.
    datapath  : one-line description of what actually executes.
    """

    trainable: bool
    servable: bool
    datapath: str


PLAN_TABLE = {
    "fake_quant": ExecutionPlan(
        trainable=True, servable=True,
        datapath="STE fake-quantization + plain f32 MXU dot"),
    "fused": ExecutionPlan(
        trainable=True, servable=True,
        datapath="packed posit codes -> Pallas fused GEMM (in-kernel "
                 "decode, f32 MXU accumulate, single encode); custom_vjp "
                 "STE backward for QAT"),
    "bit_exact": ExecutionPlan(
        trainable=False, servable=True,
        datapath="chunked-PDPU kernel (S1..S6 integer datapath, W_m "
                 "alignment truncation); forward-only validation"),
}
EXECUTION_PLANS = tuple(PLAN_TABLE)
TRAINABLE_PLANS = tuple(p for p, row in PLAN_TABLE.items() if row.trainable)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which tensors travel through which posit format (None = keep float).

    weights     : storage/compute format of weight matrices.
    activations : format applied to matmul activations (inputs).
    kv_cache    : serving KV-cache storage format.
    grad_allreduce : gradient compression format for cross-replica reduce.
    accum_dtype : wide accumulation dtype — the W_m analogue on TPU.
    execution   : which GEMM datapath runs the matmuls (see PLAN_TABLE and
                  kernels/dispatch.py): 'fake_quant' | 'fused' |
                  'bit_exact'.  fake_quant and fused are trainable (both
                  carry STE backwards); bit_exact is forward-only.
    kv_page_size : tokens per KV page when serving with a paged cache
                  (models/paged.py): the KV pool is [n_pages, kv_page_size,
                  Hkv*Dh] at `kv_cache` code width and the Pallas paged-
                  attention kernel gathers/decodes pages by block table.
                  Dense serving ignores it.
    prefix_sharing : serving-scheduler knob — requests whose prompts share
                  a prefix map the same physical KV pages (refcounted,
                  copy-on-write on divergence) and only prefill the
                  unshared tail, turning repeated-system-prompt traffic
                  from O(requests x prompt) into O(unique prefix) prefill
                  compute and KV pages.  Paged serving only; the engine
                  ctor can override per instance.
    batched_prefill : serving-scheduler knob — prefill chunks of the same
                  bucket size from multiple slots run as one
                  [batch_slots, chunk] program (api.prefill_chunk_batched)
                  instead of a per-slot loop: one compile per bucket and
                  one device call per (step, bucket) regardless of how
                  many slots are filling.
    fused_prefill : serving-kernel knob — paged prefill chunks run the
                  fused Pallas program (kernels/prefill_attention.py):
                  chunk attention + posit KV encode + page scatter in ONE
                  device program instead of three (flash_attention,
                  kv_encode, insert_chunk).  Bit-identical to the
                  decomposed path for arbitrary spans — history beyond one
                  flash chunk streams through the kernel's running flash
                  softmax page-by-page; only a page size that does not
                  divide `paged.FLASH_CHUNK` still forces the decomposed
                  fallback (paged.fused_prefill_span_ok).
    fused_decode : serving-kernel knob — each paged decode step runs
                  attention + logits-head GEMM + sampling epilogue as ONE
                  device program (common.sample_head /
                  kernels ops.decode_sample) instead of a decode dispatch
                  followed by a sampler dispatch.  Bit-identical tokens;
                  bit_exact execution keeps the decomposed pair (its head
                  GEMM has no fused replay).
    pdpu_n, pdpu_w_m : chunk size and alignment width of the PDPU instance
                  used by the 'bit_exact' plan (paper Table I knobs).
    """

    weights: Optional[PositFormat] = None
    activations: Optional[PositFormat] = None
    kv_cache: Optional[PositFormat] = None
    grad_allreduce: Optional[PositFormat] = None
    accum_dtype: jnp.dtype = jnp.float32
    execution: str = "fake_quant"
    kv_page_size: int = 16
    prefix_sharing: bool = True
    batched_prefill: bool = True
    fused_prefill: bool = True
    fused_decode: bool = True
    pdpu_n: int = 4
    pdpu_w_m: int = 14

    def __post_init__(self):
        if self.execution not in EXECUTION_PLANS:
            raise ValueError(
                f"unknown execution plan '{self.execution}' (have {EXECUTION_PLANS})")
        if self.execution != "fake_quant" and self.weights is None:
            raise ValueError(
                f"execution='{self.execution}' requires a posit weights format")

    @property
    def enabled(self) -> bool:
        return any(f is not None for f in (self.weights, self.activations, self.kv_cache))

    def maybe_quant_weight(self, w):
        if self.weights is None:
            return w
        return posit.quantize_ste(w, self.weights)

    def maybe_quant_act(self, x):
        if self.activations is None:
            return x
        return posit.quantize_ste(x, self.activations)

    def maybe_quant_kv(self, kv):
        if self.kv_cache is None:
            return kv
        return posit.quantize(kv, self.kv_cache)

    @property
    def plan(self) -> ExecutionPlan:
        """Plan-table row for the selected execution datapath."""
        return PLAN_TABLE[self.execution]

    @property
    def trainable(self) -> bool:
        """True if `jax.grad` flows through this policy's datapath."""
        return self.plan.trainable

    def require_trainable(self) -> "QuantPolicy":
        """Raise early (before tracing) when the selected datapath cannot
        back-propagate — the same condition the dispatch-layer grad barrier
        enforces lazily under `jax.grad`."""
        if not self.trainable:
            raise ValueError(
                f"execution plan '{self.execution}' is not differentiable; "
                f"trainable plans are {TRAINABLE_PLANS}.  Switch with "
                f"QuantPolicy.with_execution(...) for QAT — bit_exact is a "
                f"forward-only validation datapath.")
        return self

    def with_execution(self, plan: str) -> "QuantPolicy":
        """Same formats, different datapath — e.g. train fake_quant, then
        serve the identical policy fused."""
        return dataclasses.replace(self, execution=plan)

    def with_serving_activations(self, fmt: PositFormat) -> "QuantPolicy":
        """Activation-format serving knob: encode matmul activations to
        `fmt` posit codes and run the both-operands fused kernel, trading a
        rounding per activation element for code-width GEMM operand
        bandwidth (int8/int16 instead of f32 into the MXU tiles)."""
        return dataclasses.replace(self, activations=fmt, execution="fused")

    def with_draft(self, weights: Optional[PositFormat] = None,
                   execution: str = "fake_quant") -> "QuantPolicy":
        """Speculative-draft policy derived from this serving policy.

        `kv_cache` and `kv_page_size` are kept identical — the draft model
        writes (placeholder) codes into the very pages the target verify
        pass re-encodes and attends, so draft/verify agree on every page
        address and code width and speculative acceptance is exact by
        construction, never approximate.  Only the compute side gets
        cheaper: `execution` defaults to the fake_quant stand-in (plain
        f32 dots over fake-quantized masters — no packed-kernel launches
        on the draft path) and `weights` may narrow the draft's weight
        code (e.g. P(8, 0) via the plan table) for a bandwidth-bound
        draft."""
        return dataclasses.replace(
            self,
            weights=weights if weights is not None else self.weights,
            execution=execution)

    def pdpu_config(self) -> PDPUConfig:
        """PDPU instance for the bit_exact plan: inputs in the weights
        format, accumulator/output in the paper's wider P(16,es)."""
        fmt_in = self.weights or self.activations
        if fmt_in is None:
            raise ValueError("bit_exact plan needs a posit weights/activations format")
        fmt_out = PositFormat(max(fmt_in.n, 16), fmt_in.es)
        return PDPUConfig(fmt_in, fmt_out, N=self.pdpu_n, w_m=self.pdpu_w_m)


# The paper's headline mixed-precision configuration, P(13/16,2):
# low-precision inputs, higher-precision accumulation.
PAPER_MIXED = QuantPolicy(weights=P13_2, activations=P13_2)
# Uniform P(16,2) (Table I row 3).
UNIFORM_P16 = QuantPolicy(weights=P16_2, activations=P16_2)
# Serving policy: posit weights + posit KV cache, float activations.
SERVE_P16_KV8 = QuantPolicy(weights=P16_2, kv_cache=P8_2)
# Serving fast path: packed posit weights through the fused Pallas kernel.
SERVE_FUSED_P16 = QuantPolicy(weights=P16_2, kv_cache=P8_2, execution="fused")
# Activation-coded serving: both operands travel as posit codes through the
# both-operands fused kernel (the accuracy/bandwidth trade — one extra
# rounding per activation element for int16 instead of f32 GEMM operands).
SERVE_FUSED_P16_A13 = SERVE_FUSED_P16.with_serving_activations(P13_2)
# Paged serving: fused weights + P(16,1)-coded KV pages of 16 tokens — the
# paged runtime's default (decode state at int16 code width, allocated per
# page in flight instead of per max_seq slot).
SERVE_PAGED_P16 = QuantPolicy(weights=P16_2, kv_cache=P16_1,
                              execution="fused", kv_page_size=16)
# Hardware-faithful validation: every matmul through the chunked-PDPU kernel.
VALIDATE_BIT_EXACT = QuantPolicy(weights=P13_2, activations=P13_2,
                                 execution="bit_exact")
# No quantization (baseline).
NONE = QuantPolicy()


def policy_by_name(name: str) -> QuantPolicy:
    table = {
        "none": NONE,
        "paper_mixed": PAPER_MIXED,
        "uniform_p16": UNIFORM_P16,
        "serve_p16_kv8": SERVE_P16_KV8,
        "serve_fused_p16": SERVE_FUSED_P16,
        "serve_fused_p16_a13": SERVE_FUSED_P16_A13,
        "serve_paged_p16": SERVE_PAGED_P16,
        "validate_bit_exact": VALIDATE_BIT_EXACT,
    }
    if name not in table:
        raise KeyError(f"unknown quant policy '{name}' (have {sorted(table)})")
    return table[name]
