"""PDPU — fused posit dot-product unit, bit-faithful JAX emulation.

Implements the paper's 6-stage datapath (Fig. 4) as vectorized int32 JAX:

  S1 Decode     : 2N+1 posit decoders (the *only* decodes — fused property)
  S2 Multiply   : exact integer mantissa products + exponent comparator tree
  S3 Align      : shift into the W_m-wide window at e_max, truncate, 2's-comp
  S4 Accumulate : sum of N+1 aligned terms (== the recursive CSA tree result)
  S5 Normalize  : leading-zero count -> final scale / significand
  S6 Encode     : single posit rounding + pack (the *only* encode)

Bit-exact against the independent Python staged model and, for wide W_m,
against the exact quire oracle (see tests/test_pdpu.py).

This module is the *reference semantics* of the hardware; the Pallas kernel
`repro.kernels.pdpu_dot` runs the same datapath on TPU tiles, and the numpy
twin (`posit_np.pdpu_dot_np`) drives the paper's accuracy benchmarks.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .formats import PDPUConfig, PositFormat
from . import posit

_I32 = jnp.int32
# python int (not a jnp scalar) so Pallas kernels can close over this module
_NEG_INF = -(1 << 24)


def _validate(cfg: PDPUConfig):
    fbi = cfg.fmt_in.frac_bits
    if 2 * (fbi + 1) + 2 + cfg.guard_bits > 31:
        raise ValueError("input mantissa product exceeds the int32 datapath")
    hi_bits = cfg.w_m + cfg.guard_bits + math.ceil(math.log2(cfg.N + 1)) + 1
    if hi_bits > 31:
        raise ValueError(
            f"w_m={cfg.w_m}, N={cfg.N} accumulator needs {hi_bits} bits > int32; "
            "use posit_np.pdpu_dot_np (int64) or the quire oracle for wide w_m"
        )


def pdpu_dot(va_codes, vb_codes, acc_codes, cfg: PDPUConfig):
    """out = round( acc + Va . Vb ) through the W_m-aligned fused datapath.

    va_codes, vb_codes: int arrays [..., N] of cfg.fmt_in posit codes.
    acc_codes:          int array  [...]    of cfg.fmt_out posit codes.
    Returns cfg.fmt_out posit codes, int32 [...].
    """
    _validate(cfg)
    fi, fo, w_m = cfg.fmt_in, cfg.fmt_out, cfg.w_m
    va_codes = va_codes.astype(_I32)
    vb_codes = vb_codes.astype(_I32)
    acc_codes = acc_codes.astype(_I32)

    # ---- S1: decode (sole decode stage) ----------------------------------
    za, na, sa, ea, fa = posit.decode_unpacked(va_codes, fi)
    zb, nb, sb, eb, fb_ = posit.decode_unpacked(vb_codes, fi)
    zc, nc, sc, ec, fc = posit.decode_unpacked(acc_codes, fo)
    any_nar = jnp.any(na | nb, axis=-1) | nc

    fbi, fbo = fi.frac_bits, fo.frac_bits

    # ---- S2: mantissa products (radix-4 Booth == exact int multiply) -----
    prod = fa * fb_                      # [..., N]; 2*fbi frac bits, in [1,4)
    s_ab = sa ^ sb
    e_ab = jnp.where(za | zb, _NEG_INF, ea + eb)
    e_c = jnp.where(zc, _NEG_INF, ec)
    # comparator tree
    e_max = jnp.maximum(jnp.max(e_ab, axis=-1), e_c)
    all_zero = e_max == _NEG_INF
    e_max_s = jnp.where(all_zero, 0, e_max)

    # ---- S3: align into the w_m window (LSB weight 2**(e_max+2-w_m));
    # guard_bits extra low bits are kept and shifted-out bits optionally
    # OR into a sticky LSB (faithful-rounding plumbing; see PDPUConfig) ----
    G = cfg.guard_bits
    lsb_w = e_max_s + 2 - w_m

    def _align(frac, e, fb, lsb):
        sh = (e - fb) - lsb + G
        sh = jnp.where(e == _NEG_INF, -31, sh)
        sh = jnp.clip(sh, -31, 31)
        left = frac << jnp.maximum(sh, 0)
        right_sh = jnp.minimum(-sh, 31)
        right = frac >> right_sh
        out = jnp.where(sh >= 0, left, right)
        if cfg.sticky:
            dropped = jnp.where(sh < 0, frac & ((_I32(1) << right_sh) - 1), 0)
            out = out | (dropped != 0).astype(_I32)
        return out

    t = _align(prod, e_ab, 2 * fbi, lsb_w[..., None])
    t = jnp.where(s_ab == 1, -t, t)      # two's complement conversion
    tc = _align(fc, e_c, fbo, lsb_w)
    tc = jnp.where(sc == 1, -tc, tc)

    # ---- S4: accumulate (int add == recursive CSA tree, bit-exact) -------
    ssum = jnp.sum(t, axis=-1) + tc
    f_s = (ssum < 0).astype(_I32)
    sm = jnp.abs(ssum)

    # ---- S5: normalize ----------------------------------------------------
    p = posit.bit_length32(jnp.maximum(sm, 1)) - 1  # MSB index
    f_scale = (e_max_s + 2 - w_m - G) + p

    # ---- S6: single posit rounding + pack (sole encode stage) ------------
    code = posit.encode_core(f_s, f_scale, sm, p, jnp.zeros(sm.shape, bool), fo)
    code = jnp.where(all_zero | (sm == 0), 0, code)
    code = jnp.where(any_nar, fo.nar_code, code)
    return code.astype(_I32)


def pdpu_chunked_dot(a_codes, b_codes, cfg: PDPUConfig, acc_codes=None):
    """Long dot product by chunk-size-N PDPU accumulation (paper §III-C).

    a_codes, b_codes: [..., K], K % N == 0.  The running accumulator lives
    in fmt_out between chunks — exactly the hardware dataflow where one
    PDPU instance processes a DNN dot product over K/N cycles.
    """
    K = a_codes.shape[-1]
    N = cfg.N
    if K % N != 0:
        raise ValueError(f"dot length {K} not divisible by chunk size {N}")
    steps = K // N
    if acc_codes is None:
        acc = jnp.zeros(a_codes.shape[:-1], dtype=_I32)
    else:
        acc = acc_codes.astype(_I32)

    a_ch = jnp.moveaxis(a_codes.reshape(a_codes.shape[:-1] + (steps, N)), -2, 0)
    b_ch = jnp.moveaxis(b_codes.reshape(b_codes.shape[:-1] + (steps, N)), -2, 0)

    def body(acc, ab):
        a, b = ab
        return pdpu_dot(a, b, acc, cfg), None

    acc, _ = jax.lax.scan(body, acc, (a_ch, b_ch))
    return acc


def pdpu_matmul_exact(a_codes, b_codes, cfg: PDPUConfig):
    """[M,K] x [K,N_out] posit matmul through chunked PDPU accumulation.

    Bit-faithful to an accelerator tiling its GEMM onto PDPU chunk units.
    Emulation only — O(M*N_out*K) scalar dataflow; use the fused Pallas
    kernel for production compute.
    """
    M, K = a_codes.shape
    K2, N_out = b_codes.shape
    assert K == K2
    a_exp = jnp.broadcast_to(a_codes[:, None, :], (M, N_out, K))
    b_exp = jnp.broadcast_to(b_codes.T[None, :, :], (M, N_out, K))
    return pdpu_chunked_dot(a_exp, b_exp, cfg)
