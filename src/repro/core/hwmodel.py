"""Analytical hardware cost model of the configurable PDPU generator.

The paper evaluates PDPU in silicon (TSMC 28nm, Synopsys DC).  No synthesis
toolchain exists in this environment, so this module provides the same
*generator interface* — any (n_in/n_out, es, N, w_m) -> area / delay / power
/ GOPS / efficiency — as an analytical model whose feature forms follow the
datapath structure (Fig. 4/5/6) and whose coefficients are calibrated
against the paper's own Table I:

    feature                         hardware source
    -----------------------------   ------------------------------------
    2N·n_i·log2(n_i) + 2·n_o·log2(n_o)   posit decoders/encoder (LZC + dynamic
                                         shifters dominate; §IV-B)
    N·(mant_in)^1.6                       radix-4 Booth multipliers
    N·W_acc + 2·W_acc·log2(W_acc)        CSA tree + aligners + LZC/normalise
    delay ~ log2 of each stage's tree    balanced tree depths

Calibration residuals on the paper's six PDPU rows: area <= 5.2%,
delay <= 0.7%, power <= 10.7% (quire power uses an activity derate — only a
w_m_eff-wide window of a quire accumulator switches per operation).

Everything else in Table I (FPnew, PACoGen, posit FMA) is a *measured
baseline from the paper*, reproduced as reported constants for the
comparison table; this model only generates PDPU-family numbers.
"""
from __future__ import annotations

import dataclasses
import math

from .formats import PDPUConfig

# nnls fit against Table I (see benchmarks/bench_table1.py for validation)
_AREA_C = (12.583013580820825, 1.4513916143062553, 0.0, 4.312122624876216)
_DELAY_C = (0.7788118775308295, 0.006926975660276358, 0.10295216126195986, 0.041830706985364015)
_POWER_C = 0.0007242659286068411  # mW per (um^2 / ns) of active area
_ACTIVITY_WM_CAP = 128  # calibrated: a quire accumulator's switching activity
# saturates around a 128-bit window (matches Table I quire power 5.87 mW)
_T_REG = 0.05  # ns, register setup+cq overhead per pipeline stage


@dataclasses.dataclass(frozen=True)
class HwReport:
    area_um2: float
    delay_ns: float  # combinational critical path
    power_mw: float
    stage_delay_ns: tuple  # S1..S6
    stage_area_um2: tuple  # S1..S6
    pipeline_delay_ns: float  # worst stage + register overhead
    fmax_ghz: float
    gops: float  # N MACs per combinational delay (Table I convention)
    gops_pipelined: float
    area_eff: float  # GOPS / mm^2
    energy_eff: float  # GOPS / W

    def row(self):
        return (self.area_um2, self.delay_ns, self.power_mw, self.gops,
                self.area_eff, self.energy_eff)


def _wacc(N: int, w_m: int) -> float:
    return w_m + math.ceil(math.log2(N + 1)) + 2


def _area_terms(cfg: PDPUConfig, w_m=None):
    n_i, n_o, es, N = cfg.fmt_in.n, cfg.fmt_out.n, cfg.fmt_in.es, cfg.N
    w_m = cfg.w_m if w_m is None else w_m
    fbi = cfg.fmt_in.frac_bits
    wacc = _wacc(N, w_m)
    f_codec = 2 * N * (n_i * math.log2(n_i)) + 2 * n_o * math.log2(n_o)
    f_mul = N * (fbi + 1) ** 1.6
    f_ali = (N + 1) * w_m * math.log2(w_m)
    f_accnrm = N * wacc + 2 * wacc * math.log2(wacc)
    return f_codec, f_mul, f_ali, f_accnrm


def area_um2(cfg: PDPUConfig, w_m=None) -> float:
    f = _area_terms(cfg, w_m)
    return sum(c * x for c, x in zip(_AREA_C, f))


def delay_ns(cfg: PDPUConfig) -> float:
    n_i, n_o, N, w_m = cfg.fmt_in.n, cfg.fmt_out.n, cfg.N, cfg.w_m
    fbi = cfg.fmt_in.frac_bits
    wacc = _wacc(N, w_m)
    d0, d1, d2, d3 = _DELAY_C
    return (d0
            + d1 * (math.log2(n_i) + math.log2(fbi + 1) + math.log2(n_o))
            + d2 * math.log2(N + 1)
            + d3 * (math.log2(w_m) + 2 * math.log2(wacc)))


def power_mw(cfg: PDPUConfig) -> float:
    active = area_um2(cfg, w_m=min(cfg.w_m, _ACTIVITY_WM_CAP))
    return _POWER_C * active / delay_ns(cfg)


def stage_breakdown(cfg: PDPUConfig):
    """Per-stage (delay_ns, area_um2) for S1..S6 — paper Fig. 6.

    The fitted total is distributed over stages by the datapath elements
    each stage owns (decoders -> S1, multipliers+comparator -> S2, aligners
    -> S3, CSA+adder -> S4, LZC+shift -> S5, encoder -> S6).
    """
    n_i, n_o, es, N, w_m = (cfg.fmt_in.n, cfg.fmt_out.n, cfg.fmt_in.es,
                            cfg.N, cfg.w_m)
    fbi = cfg.fmt_in.frac_bits
    wacc = _wacc(N, w_m)
    c1, c2, c3, c4 = _AREA_C
    dec_in = 2 * N * n_i * math.log2(n_i)
    dec_acc = n_o * math.log2(n_o)
    enc = n_o * math.log2(n_o)
    a1 = c1 * (dec_in + dec_acc)
    a2 = c2 * N * (fbi + 1) ** 1.6
    a3 = c3 * (N + 1) * w_m * math.log2(w_m) + c4 * wacc * math.log2(wacc) * 0.5
    a4 = c4 * N * wacc + c4 * wacc * math.log2(wacc) * 0.5
    a5 = c4 * wacc * math.log2(wacc)
    a6 = c1 * enc
    # renormalize the distribution to the fitted total (keeps Fig.6 shares
    # consistent with the Table I totals)
    tot = area_um2(cfg)
    s = a1 + a2 + a3 + a4 + a5 + a6
    areas = tuple(a * tot / s for a in (a1, a2, a3, a4, a5, a6))

    d0, d1, d2, d3 = _DELAY_C
    base = d0 / 6.0
    t1 = base + d1 * math.log2(n_i)
    t2 = base + d1 * math.log2(fbi + 1) + d2 * math.log2(N + 1) * 0.7
    t3 = base + d3 * math.log2(w_m)
    t4 = base + d2 * math.log2(N + 1) * 0.3 + d3 * math.log2(wacc)
    t5 = base + d3 * math.log2(wacc)
    t6 = base + d1 * math.log2(n_o)
    tot_d = delay_ns(cfg)
    sd = t1 + t2 + t3 + t4 + t5 + t6
    delays = tuple(t * tot_d / sd for t in (t1, t2, t3, t4, t5, t6))
    return delays, areas


def report(cfg: PDPUConfig) -> HwReport:
    a = area_um2(cfg)
    d = delay_ns(cfg)
    p = power_mw(cfg)
    sdel, sarea = stage_breakdown(cfg)
    pipe = max(sdel) + _T_REG
    gops = cfg.N / d  # 1 MAC == 1 op (Table I footnote)
    return HwReport(
        area_um2=a, delay_ns=d, power_mw=p,
        stage_delay_ns=sdel, stage_area_um2=sarea,
        # paper convention ("operate up to 2.7 GHz"): fmax = 1/worst stage
        pipeline_delay_ns=pipe, fmax_ghz=1.0 / max(sdel),
        gops=gops, gops_pipelined=cfg.N / pipe,
        area_eff=gops / (a * 1e-6), energy_eff=gops / (p * 1e-3),
    )


# ---------------------------------------------------------------------------
# Table I baselines — the paper's *measured* numbers, kept as constants so
# the benchmark can print the full comparison table. (We model only PDPU.)
# ---------------------------------------------------------------------------

PAPER_TABLE1_BASELINES = {
    # name: (formats, N, area_um2, delay_ns, power_mw)
    "FPnew DPU FP32": ("FP32", 4, 28563.19, 3.45, 7.60),
    "FPnew DPU FP16": ("FP16", 4, 13448.99, 2.75, 4.29),
    "PACoGen DPU P(16,2)": ("P(16,2)", 4, 13433.11, 4.45, 12.21),
    "FPnew FMA FP32": ("FP32", 1, 6668.17, 1.20, 3.97),
    "FPnew FMA FP16": ("FP16", 1, 3713.72, 1.00, 2.51),
    "Posit FMA P(16,2)": ("P(16,2)", 1, 7035.34, 1.35, 3.79),
}

PAPER_TABLE1_PDPU = {
    # name: (area_um2, delay_ns, power_mw) as reported — calibration targets
    "PDPU P(16/16,2) N=4 Wm=14": (9579.15, 1.62, 4.49),
    "PDPU P(13/16,2) N=4 Wm=14": (7694.82, 1.60, 3.66),
    "PDPU P(13/16,2) N=8 Wm=14": (13560.37, 1.69, 5.80),
    "PDPU P(10/16,2) N=8 Wm=14": (10006.42, 1.70, 4.24),
    "PDPU P(13/16,2) N=8 Wm=10": (12157.11, 1.66, 5.06),
    "Quire PDPU P(13/16,2) N=4": (29209.45, 2.10, 5.87),
}
