"""Posit format descriptors shared by every codec implementation.

A posit format P(n, es) is fully described by its word size ``n`` and
exponent size ``es`` (posit-2017 generalized; posit-2022 fixes es=2).
All codec layers (exact oracle, numpy, JAX, Pallas) consume this one
descriptor so configs are interchangeable across the stack.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PositFormat:
    """P(n, es) descriptor with derived constants."""

    n: int
    es: int = 2

    def __post_init__(self):
        if not (2 <= self.n <= 32):
            raise ValueError(f"posit word size n={self.n} out of supported range [2, 32]")
        if not (0 <= self.es <= 4):
            raise ValueError(f"posit exponent size es={self.es} out of supported range [0, 4]")

    # ---- derived constants -------------------------------------------------
    @property
    def useed_log2(self) -> int:
        """log2(useed) = 2**es."""
        return 1 << self.es

    @property
    def mask(self) -> int:
        return (1 << self.n) - 1

    @property
    def sign_mask(self) -> int:
        return 1 << (self.n - 1)

    @property
    def nar_code(self) -> int:
        return 1 << (self.n - 1)

    @property
    def maxpos_code(self) -> int:
        return (1 << (self.n - 1)) - 1

    @property
    def minpos_code(self) -> int:
        return 1

    @property
    def max_scale(self) -> int:
        """scale of maxpos = (n-2) * 2**es."""
        return (self.n - 2) << self.es

    @property
    def min_scale(self) -> int:
        return -self.max_scale

    @property
    def frac_bits(self) -> int:
        """Fraction bits available with the shortest (2-bit) regime.

        Every decoded posit's significand fits in 1 + frac_bits bits; fewer
        bits are available for longer regimes but the decoder zero-pads, so
        a fixed-width fraction register of this width is exact.
        """
        return max(self.n - 3 - self.es, 0)

    @property
    def storage_bits(self) -> int:
        """Smallest power-of-two container width."""
        for w in (8, 16, 32):
            if self.n <= w:
                return w
        return 64

    def __str__(self) -> str:  # matches the paper's P(n,es) notation
        return f"P({self.n},{self.es})"


# The formats the paper uses in Table I, importable by name.
P16_2 = PositFormat(16, 2)
P16_1 = PositFormat(16, 1)   # paged-KV storage format (serving runtime)
P13_2 = PositFormat(13, 2)
P10_2 = PositFormat(10, 2)
P8_2 = PositFormat(8, 2)
P8_1 = PositFormat(8, 1)
P8_0 = PositFormat(8, 0)


@dataclasses.dataclass(frozen=True)
class PDPUConfig:
    """Configuration of one PDPU instance — mirrors the paper's generator.

    ``fmt_in``  : posit format of the input vectors Va, Vb.
    ``fmt_out`` : posit format of ``acc`` and ``out`` (mixed precision when
                  different from fmt_in; the paper's P(13/16,2) notation).
    ``N``       : dot-product chunk size (number of parallel products).
    ``w_m``     : alignment width — the bit width the aligned product
                  mantissas are truncated to before the CSA accumulation.
                  Larger w_m -> closer to quire-exact; the paper's fidelity
                  vs hardware-cost knob (Table I uses 10 / 14 / 256).
    ``guard_bits`` / ``sticky`` : alignment shifter keeps `guard_bits`
                  extra low-order bits plus an OR-reduction (sticky) of all
                  shifted-out bits — standard FP-datapath rounding support.
                  The paper does not specify its shifter's rounding plumbing;
                  with guard+sticky on (default) the fused unit beats the
                  per-op-rounded discrete DPU on accuracy, matching the
                  paper's Table I ordering (see benchmarks/bench_table1.py).
                  Set guard_bits=0, sticky=False for plain truncation.
    """

    fmt_in: PositFormat
    fmt_out: PositFormat
    N: int = 4
    w_m: int = 14
    guard_bits: int = 2
    sticky: bool = True

    def __post_init__(self):
        if self.fmt_in.es != self.fmt_out.es:
            # the paper keeps es identical across mixed-precision in/out
            raise ValueError("PDPU mixed precision requires matching es for in/out formats")
        if self.N < 1:
            raise ValueError("dot-product size N must be >= 1")
        if self.w_m < 4:
            raise ValueError("alignment width w_m must be >= 4")

    @property
    def name(self) -> str:
        if self.fmt_in.n == self.fmt_out.n:
            return f"P({self.fmt_in.n}/{self.fmt_out.n},{self.fmt_in.es}) N={self.N} Wm={self.w_m}"
        return f"P({self.fmt_in.n}/{self.fmt_out.n},{self.fmt_in.es}) N={self.N} Wm={self.w_m}"


# Table I configurations of the proposed PDPU.
PDPU_P16_16_N4_W14 = PDPUConfig(P16_2, P16_2, N=4, w_m=14)
PDPU_P13_16_N4_W14 = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
PDPU_P13_16_N8_W14 = PDPUConfig(P13_2, P16_2, N=8, w_m=14)
PDPU_P10_16_N8_W14 = PDPUConfig(P10_2, P16_2, N=8, w_m=14)
PDPU_P13_16_N8_W10 = PDPUConfig(P13_2, P16_2, N=8, w_m=10)
PDPU_QUIRE_P13_16_N4 = PDPUConfig(P13_2, P16_2, N=4, w_m=256)
