"""Exact posit arithmetic oracle (pure Python, Fraction-based).

Ground truth for every other implementation in the repo.  Values are exact
``fractions.Fraction``; rounding is done by nearest-candidate search over the
full code table, which is trivially correct by construction (posit-2022
round-to-nearest, ties to even code, clamp to maxpos / minpos, never
underflow a non-zero value to zero).

Scope: small word sizes (table is O(2^n)); used by tests and the paper's
accuracy benchmarks, never on the hot path.
"""
from __future__ import annotations

import functools
from fractions import Fraction
from typing import Optional, Sequence

from .formats import PositFormat

NAR = None  # decode result for the Not-a-Real pattern


def decode_exact(code: int, fmt: PositFormat) -> Optional[Fraction]:
    """Decode an n-bit posit code to its exact value (None for NaR)."""
    n, es = fmt.n, fmt.es
    code &= fmt.mask
    if code == 0:
        return Fraction(0)
    if code == fmt.nar_code:
        return NAR
    sign = (code >> (n - 1)) & 1
    body = ((-code) & fmt.mask) if sign else code
    # bits after the sign, MSB first
    bits = [(body >> i) & 1 for i in range(n - 2, -1, -1)]
    r0 = bits[0]
    m = 0
    while m < len(bits) and bits[m] == r0:
        m += 1
    k = (m - 1) if r0 else -m
    rest = bits[m + 1:]  # skip terminator (may be absent if regime fills)
    e_bits = rest[:es]
    e_bits += [0] * (es - len(e_bits))  # truncated exponent bits read as 0
    e = 0
    for b in e_bits:
        e = (e << 1) | b
    f_bits = rest[es:]
    frac = Fraction(1)
    for i, b in enumerate(f_bits):
        if b:
            frac += Fraction(1, 1 << (i + 1))
    scale = k * (1 << es) + e
    value = frac * (Fraction(2) ** scale)
    return -value if sign else value


@functools.lru_cache(maxsize=8)
def _positive_table(fmt: PositFormat):
    """Sorted list of (value, code) for all strictly positive codes."""
    table = []
    for code in range(1, fmt.maxpos_code + 1):
        v = decode_exact(code, fmt)
        table.append((v, code))
    table.sort()
    return table


def encode_exact(value, fmt: PositFormat) -> int:
    """Round an exact real (Fraction/int/float) to a posit code.

    Posit-2022 semantics: round-to-nearest-even **in pattern space** (the
    bit string is extended with the exact remaining bits and RNE'd at n
    bits), which is what hardware and SoftPosit implement.  In the
    regime/exponent-dominated gaps this differs from linear nearest-value
    rounding.  |v| >= maxpos clamps to maxpos; 0 < |v| <= minpos rounds to
    minpos (no underflow to zero, no overflow to NaR).

    Exact pattern midpoints: the pattern halfway between consecutive
    positive codes c and c+1 of P(n,es) is precisely the value of code
    2c+1 in P(n+1,es) — that equivalence gives exact RNE with Fractions.
    """
    if value is NAR:
        return fmt.nar_code
    v = Fraction(value)
    if v == 0:
        return 0
    neg = v < 0
    a = -v if neg else v
    table = _positive_table(fmt)
    lo, hi = 0, len(table) - 1
    if a >= table[hi][0]:
        code = table[hi][1]  # clamp to maxpos
    elif a <= table[lo][0]:
        code = table[lo][1]  # clamp to minpos
    else:
        # binary search: largest code with value <= a (codes are monotonic)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if table[mid][0] <= a:
                lo = mid
            else:
                hi = mid
        vlo, base = table[lo]
        if a == vlo:
            code = base
        else:
            ext = PositFormat(fmt.n + 1, fmt.es)
            vmid = decode_exact(2 * base + 1, ext)
            if a > vmid:
                code = base + 1
            elif a < vmid:
                code = base
            else:  # exact pattern tie: even code (LSB == 0)
                code = base if (base & 1) == 0 else base + 1
    if neg:
        code = (-code) & fmt.mask
    return code


def to_float(code: int, fmt: PositFormat) -> float:
    v = decode_exact(code, fmt)
    return float("nan") if v is NAR else float(v)


def from_float(x: float, fmt: PositFormat) -> int:
    if x != x or x in (float("inf"), float("-inf")):
        return fmt.nar_code
    return encode_exact(Fraction(x), fmt)


# ---------------------------------------------------------------------------
# Exact (quire-style) fused dot product: the ideal PDPU with w_m = infinity.
# ---------------------------------------------------------------------------

def quire_dot_exact(
    va: Sequence[int],
    vb: Sequence[int],
    acc: int,
    fmt_in: PositFormat,
    fmt_out: PositFormat,
) -> int:
    """out = round_{fmt_out}( acc + sum_i va_i * vb_i ), exactly one rounding.

    This is the quire semantics: the entire dot product is exact; the single
    rounding happens at the final encode.  Any NaR input poisons the output.
    """
    total = decode_exact(acc, fmt_out)
    if total is NAR:
        return fmt_out.nar_code
    for ca, cb in zip(va, vb):
        a = decode_exact(ca, fmt_in)
        b = decode_exact(cb, fmt_in)
        if a is NAR or b is NAR:
            return fmt_out.nar_code
        total += a * b
    return encode_exact(total, fmt_out)


# ---------------------------------------------------------------------------
# Bit-faithful staged PDPU model (paper Fig. 4, S1..S6) with finite w_m.
# Independent Python-int re-derivation of the hardware datapath, used to
# cross-validate the vectorized JAX emulation bit for bit.
# ---------------------------------------------------------------------------

def pdpu_dot_model(
    va: Sequence[int],
    vb: Sequence[int],
    acc: int,
    fmt_in: PositFormat,
    fmt_out: PositFormat,
    w_m: int,
    guard_bits: int = 2,
    sticky: bool = True,
) -> int:
    n_terms = len(va)
    assert len(vb) == n_terms

    def _dec(code, fmt):
        """-> (is_zero, is_nar, sign, scale, frac_int, frac_bits)."""
        code &= fmt.mask
        if code == 0:
            return True, False, 0, 0, 0, fmt.frac_bits
        if code == fmt.nar_code:
            return False, True, 0, 0, 0, fmt.frac_bits
        v = decode_exact(code, fmt)
        sign = 1 if v < 0 else 0
        a = -v if sign else v
        # a = frac * 2**scale with frac in [1, 2); extract integer mantissa
        scale = 0
        while a >= 2:
            a /= 2
            scale += 1
        while a < 1:
            a *= 2
            scale -= 1
        fb = fmt.frac_bits
        frac = a * (1 << fb)
        assert frac.denominator == 1, "posit fraction wider than frac_bits?"
        return False, False, sign, scale, int(frac), fb

    NEG_INF = -(1 << 30)

    # S1: decode
    terms = []
    any_nar = False
    for ca, cb in zip(va, vb):
        za, na, sa, ea, fa, fba = _dec(ca, fmt_in)
        zb, nb, sb, eb, fb_, fbb = _dec(cb, fmt_in)
        any_nar |= na or nb
        if za or zb:
            terms.append((0, NEG_INF, 0, fba + fbb))
        else:
            # S2: exact integer mantissa product (2 int bits, fba+fbb frac bits)
            terms.append((sa ^ sb, ea + eb, fa * fb_, fba + fbb))
    zc, nc, sc, ec, fc, fbc = _dec(acc, fmt_out)
    any_nar |= nc
    if any_nar:
        return fmt_out.nar_code
    terms.append((sc, ec if not zc else NEG_INF, fc if not zc else 0, fbc))

    # S2b: comparator tree
    e_max = max(t[1] for t in terms)
    if e_max == NEG_INF:
        return 0  # everything zero

    # S3: align into a w_m-wide window (+ guard_bits kept below, shifted-out
    # bits optionally ORed into a sticky LSB); MSB of the window sits at
    # weight 2**(e_max + 1) (products reach [1,4)).
    G = guard_bits
    ssum = 0
    for sign, e, frac, fb in terms:
        if e == NEG_INF:
            continue
        # frac has fb fraction bits; its value is frac * 2**(e - fb).
        # Window LSB weight: 2**(e_max + 1 - (w_m - 1)) = 2**(e_max + 2 - w_m)
        lsb_w = e_max + 2 - w_m
        shift = (e - fb) - lsb_w + G
        if shift >= 0:
            aligned = frac << shift
        else:
            aligned = frac >> -shift
            if sticky and (frac & ((1 << -shift) - 1)):
                aligned |= 1
        ssum += -aligned if sign else aligned  # S4: two's complement CSA + add

    if ssum == 0:
        return 0
    f_s = 1 if ssum < 0 else 0
    sm = -ssum if f_s else ssum

    # S5: normalize — value = sm * 2**(e_max + 2 - w_m - G)
    p = sm.bit_length() - 1
    f_scale = (e_max + 2 - w_m - G) + p

    # S6: round to fmt_out (RNE on the exact remaining bits) and pack.
    # value = (-1)**f_s * (sm / 2**p) * 2**f_scale, significand in [1, 2).
    mag = Fraction(sm, 1 << p) * (Fraction(2) ** f_scale)
    return encode_exact(-mag if f_s else mag, fmt_out)
