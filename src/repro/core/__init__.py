"""repro.core — the paper's contribution: posit arithmetic + the PDPU.

Layers (each bit-exact against the one below, enforced by tests):
  posit_py  : exact Fraction oracle (ground truth)
  posit_np  : vectorized numpy int64 codec + PDPU emulation (benchmarks)
  posit     : jittable JAX int32 codec (models/kernels building block)
  pdpu      : fused 6-stage PDPU emulation in JAX
  discrete  : the paper's baseline architectures (Fig. 1)
  quant     : framework-level posit quantization policy
  hwmodel   : configurable-generator cost model (Table I / Fig. 6)
"""
from .formats import (  # noqa: F401
    PositFormat, PDPUConfig,
    P16_2, P13_2, P10_2, P8_2, P8_1, P8_0,
    PDPU_P16_16_N4_W14, PDPU_P13_16_N4_W14, PDPU_P13_16_N8_W14,
    PDPU_P10_16_N8_W14, PDPU_P13_16_N8_W10, PDPU_QUIRE_P13_16_N4,
)
from .quant import QuantPolicy, policy_by_name  # noqa: F401
from . import posit, pdpu, posit_np, posit_py, discrete, hwmodel, quant  # noqa: F401
