"""Vectorized posit codec on numpy int64 — the fast host-side reference.

Bridges posit codes <-> float64 exactly (posit fractions are <= 27 bits and
scales are far inside the f64 exponent range for every supported format),
with correct posit-2022 round-to-nearest-even on encode.

Used by: the discrete-DPU / FMA-cascade accuracy baselines (paper Table I),
the PDPU numpy emulation, and as a second cross-check against the exact
Fraction oracle in tests.
"""
from __future__ import annotations

import numpy as np

from .formats import PDPUConfig, PositFormat

_I64 = np.int64


def _check(fmt: PositFormat):
    if fmt.n > 32:
        raise ValueError("numpy codec supports n <= 32")


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_unpacked_np(codes, fmt: PositFormat):
    """codes -> (is_zero, is_nar, sign, scale, frac) with frac in
    [2**fb, 2**(fb+1)) for non-zero values, fb = fmt.frac_bits."""
    _check(fmt)
    n, es = fmt.n, fmt.es
    x = np.asarray(codes).astype(_I64) & fmt.mask
    is_zero = x == 0
    is_nar = x == fmt.nar_code
    sign = (x >> (n - 1)) & 1
    xa = np.where(sign == 1, (-x) & fmt.mask, x)
    # left-align the n-1 bits after the sign at bit 62 of an int64
    body = (xa << (63 - (n - 1))) & ((1 << 63) - 1)
    r0 = (body >> 62) & 1
    inv = np.where(r0 == 1, ~body & ((1 << 63) - 1), body)
    # count leading zeros within the 62..0 window of `inv` (bit 63 is 0)
    lz = 62 - _bit_length(inv) + 1
    m = np.minimum(lz, n - 1)
    k = np.where(r0 == 1, m - 1, -m)
    rem = (body << (m + 1)) & ((1 << 63) - 1)
    e = (rem >> (63 - es)) if es > 0 else np.zeros_like(rem)
    fb = fmt.frac_bits
    if fb > 0:
        mant = ((rem << es) & ((1 << 63) - 1)) >> (63 - fb)
    else:
        mant = np.zeros_like(rem)
    frac = (1 << fb) | mant
    scale = k * (1 << es) + e
    valid = ~(is_zero | is_nar)
    frac = np.where(valid, frac, 0)
    scale = np.where(valid, scale, 0)
    sign = np.where(valid, sign, 0)
    return is_zero, is_nar, sign, scale, frac


def _bit_length(x):
    """Vectorized bit_length for non-negative int64 (0 -> 0)."""
    x = np.asarray(x)
    out = np.zeros(x.shape, dtype=_I64)
    v = x.copy()
    for s in (32, 16, 8, 4, 2, 1):
        ge = v >= (np.int64(1) << s)
        out += np.where(ge, s, 0)
        v = np.where(ge, v >> s, v)
    return out + (x > 0)


def decode_np(codes, fmt: PositFormat):
    """codes -> float64 values (NaR -> nan). Exact."""
    is_zero, is_nar, sign, scale, frac = decode_unpacked_np(codes, fmt)
    fb = fmt.frac_bits
    val = np.ldexp(frac.astype(np.float64), (scale - fb).astype(np.int32))
    val = np.where(sign == 1, -val, val)
    val = np.where(is_zero, 0.0, val)
    val = np.where(is_nar, np.nan, val)
    return val


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------

def encode_core_np(sign, scale, frac, F: int, sticky, fmt: PositFormat):
    """Round/pack unpacked values into posit codes (posit-2022 RNE).

    frac must be 0 (zero result) or normalized in [2**F, 2**(F+1)).
    ``sticky`` is a boolean array: true iff non-zero bits were already
    dropped strictly below frac's LSB.
    """
    _check(fmt)
    n, es = fmt.n, fmt.es
    sign = np.asarray(sign).astype(_I64)
    scale = np.asarray(scale).astype(_I64)
    frac = np.asarray(frac).astype(_I64)
    sticky = np.asarray(sticky).astype(bool)

    is_zero = frac == 0

    # pre-reduce fraction width so the packed body fits in int64 and the
    # rounding cut always lands inside the kept bits (shift >= 1 below).
    Fp = n - es  # >= n - es - 2 + 2
    if F > Fp:
        drop = F - Fp
        sticky = sticky | ((frac & ((np.int64(1) << drop) - 1)) != 0)
        frac = frac >> drop
    elif F < Fp:
        frac = frac << (Fp - F)

    k = scale >> es  # floor division
    e = scale & ((1 << es) - 1)

    # regime saturation (posit clamps, never overflows to NaR)
    sat_hi = k >= n - 2
    sat_lo = k <= -(n - 1)
    k_c = np.clip(k, -(n - 2), n - 3)
    e = np.where(sat_hi | sat_lo, 0, e)

    rlen = np.where(k_c >= 0, k_c + 2, 1 - k_c)  # incl. terminator
    reg = np.where(k_c >= 0, ((np.int64(1) << (k_c + 1)) - 1) << 1, np.int64(1))
    body_hi = (reg << es) | e
    body = (body_hi << Fp) | (frac & ((np.int64(1) << Fp) - 1))
    total_bits = rlen + es + Fp
    shift = total_bits - (n - 1)  # >= 1 by construction of Fp

    g = (body >> (shift - 1)) & 1
    st = sticky | ((body & ((np.int64(1) << (shift - 1)) - 1)) != 0)
    base = body >> shift
    roundup = (g == 1) & (st | ((base & 1) == 1))
    code_abs = base + roundup

    code_abs = np.where(sat_hi, fmt.maxpos_code, code_abs)
    code_abs = np.where(sat_lo, fmt.minpos_code, code_abs)
    code = np.where(sign == 1, (-code_abs) & fmt.mask, code_abs)
    code = np.where(is_zero, 0, code)
    return code.astype(_I64)


def encode_np(values, fmt: PositFormat):
    """float64 -> posit codes with exact RNE (nan/inf -> NaR)."""
    v = np.asarray(values, dtype=np.float64)
    is_nar = ~np.isfinite(v)
    v = np.where(is_nar, 0.0, v)
    sign = (np.signbit(v)).astype(_I64)
    mant, exp = np.frexp(np.abs(v))  # mant in [0.5, 1)
    frac = np.round(mant * (1 << 53)).astype(_I64)  # exact: f64 has 53 bits
    # normalize to [2**52, 2**53): frexp mant >= 0.5 so frac in [2**52, 2**53]
    over = frac == (1 << 53)
    frac = np.where(over, frac >> 1, frac)
    exp = np.where(over, exp + 1, exp)
    scale = exp.astype(_I64) - 1
    code = encode_core_np(sign, scale, frac, 52, np.zeros(v.shape, bool), fmt)
    code = np.where(is_nar, fmt.nar_code, code)
    return code


def quantize_np(values, fmt: PositFormat):
    """Fake-quantize float64 through the posit format (encode -> decode)."""
    return decode_np(encode_np(values, fmt), fmt)


# ---------------------------------------------------------------------------
# PDPU emulation (paper Fig. 4 datapath), vectorized over leading dims.
# ---------------------------------------------------------------------------

_NEG_INF = np.int64(-(1 << 40))


def pdpu_dot_np(va_codes, vb_codes, acc_codes, cfg: PDPUConfig):
    """out = PDPU(acc, Va, Vb) — bit-faithful staged emulation.

    va_codes, vb_codes: int arrays [..., N]; acc_codes: [...].
    Returns posit codes [...] in cfg.fmt_out.

    w_m >= 60 routes to the quire path (float64 exact-accumulate + single
    rounding); narrower w_m runs the S1..S6 integer datapath bit-exactly.
    """
    fi, fo, w_m = cfg.fmt_in, cfg.fmt_out, cfg.w_m
    va_codes = np.asarray(va_codes)
    vb_codes = np.asarray(vb_codes)
    acc_codes = np.asarray(acc_codes)

    # integer path needs 2*W - 1 <= 62 bits (see S6); wider w_m is
    # numerically indistinguishable from quire for any fmt_out <= 16 bits.
    W_chk = w_m + cfg.guard_bits + int(np.ceil(np.log2(cfg.N + 1))) + 2
    if 2 * W_chk - 1 > 62:
        a = decode_np(va_codes, fi)
        b = decode_np(vb_codes, fi)
        c = decode_np(acc_codes, fo)
        total = c + np.sum(a * b, axis=-1)
        return encode_np(total, fo)

    # S1: decode
    za, na, sa, ea, fa = decode_unpacked_np(va_codes, fi)
    zb, nb, sb, eb, fb_ = decode_unpacked_np(vb_codes, fi)
    zc, nc, sc, ec, fc = decode_unpacked_np(acc_codes, fo)
    any_nar = np.any(na | nb, axis=-1) | nc

    fbi, fbo = fi.frac_bits, fo.frac_bits
    # S2: exact mantissa products + product exponents
    prod = fa * fb_  # [..., N], 2*fbi fraction bits, value in [1, 4)
    s_ab = sa ^ sb
    e_ab = np.where(za | zb, _NEG_INF, ea + eb)
    e_c = np.where(zc, _NEG_INF, ec)
    # comparator tree
    e_max = np.maximum(np.max(e_ab, axis=-1), e_c)

    all_zero = e_max == _NEG_INF
    e_max_s = np.where(all_zero, 0, e_max)  # safe for shifts

    # S3: align into the w_m window (LSB weight 2**(e_max + 2 - w_m)) with
    # `G` guard bits kept below it; shifted-out bits optionally OR into a
    # sticky LSB (cfg.sticky) — otherwise plain truncation, as plain
    # arithmetic shifters would do.
    G = cfg.guard_bits
    lsb_w = e_max_s + 2 - w_m

    def _align(frac, e, fb):
        sh = (e - fb) - (lsb_w[..., None] if frac.ndim > lsb_w.ndim else lsb_w) + G
        sh = np.where(e == _NEG_INF, -63, sh)
        sh = np.clip(sh, -63, 62)
        left = np.where(sh >= 0, frac << np.maximum(sh, 0), 0)
        right_sh = np.minimum(-sh, 63)
        right = np.where(sh < 0, frac >> right_sh, 0)
        out = np.where(sh >= 0, left, right)
        if cfg.sticky:
            dropped = np.where(sh < 0,
                               frac & ((np.int64(1) << right_sh) - 1), 0)
            out = out | (dropped != 0).astype(_I64)
        return out

    t = _align(prod, e_ab, 2 * fbi)
    t = np.where(s_ab == 1, -t, t)
    tc = _align(fc, e_c, fbo)
    tc = np.where(sc == 1, -tc, tc)

    # S4: accumulate (int64 add == CSA tree result, bit-exact)
    ssum = np.sum(t, axis=-1) + tc

    f_s = (ssum < 0).astype(_I64)
    sm = np.abs(ssum)
    # S5: normalize
    p = _bit_length(sm) - 1
    p = np.maximum(p, 0)
    f_scale = (e_max_s + 2 - w_m - G) + p

    # S6: encode — value = sm * 2**(f_scale - p); per-element F varies, so
    # renormalize every sm to a common width W then encode once.
    W = w_m + G + int(np.ceil(np.log2(cfg.N + 1))) + 2
    frac_n = sm << (W - p).astype(_I64)  # p <= W-1 by construction
    code = encode_core_np(f_s, f_scale, frac_n, W, np.zeros(sm.shape, bool), fo)
    code = np.where(all_zero | (sm == 0), 0, code)
    code = np.where(any_nar, fo.nar_code, code)
    return code


def pdpu_chunked_dot_np(a_codes, b_codes, cfg: PDPUConfig, acc_codes=None):
    """Long dot product via chunk-size-N PDPU accumulation (paper §III-C).

    a_codes, b_codes: [..., K] with K % N == 0. Sequential chunk
    accumulation through the fmt_out accumulator, exactly as a hardware
    PDPU would process a DNN dot product.
    """
    a_codes = np.asarray(a_codes)
    K = a_codes.shape[-1]
    N = cfg.N
    if K % N != 0:
        raise ValueError(f"dot length {K} not divisible by chunk size {N}")
    if acc_codes is None:
        acc = np.zeros(a_codes.shape[:-1], dtype=_I64)
    else:
        acc = np.asarray(acc_codes).astype(_I64)
    for j in range(K // N):
        sl = slice(j * N, (j + 1) * N)
        acc = pdpu_dot_np(a_codes[..., sl], b_codes[..., sl], acc, cfg)
    return acc
