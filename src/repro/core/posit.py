"""Vectorized, jittable posit codec in JAX (int32/uint32 datapath).

Bit-for-bit identical to the numpy reference (`posit_np`) and the exact
Fraction oracle (`posit_py`) — enforced by exhaustive tests.  Supports
n <= 16 (the paper's entire design space) with an exact float32 bridge:
every P(n<=16, es<=2) value has <= 14 significand bits and |scale| <= 60,
so decode -> f32 is lossless and the MXU can compute on decoded values
with zero representation error.

These functions are also the building blocks of the Pallas kernels
(`repro.kernels`): the same int32 bit manipulation lowers to TPU VPU ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .formats import PositFormat

_I32 = jnp.int32
_U32 = jnp.uint32


def _check_jax_fmt(fmt: PositFormat):
    if fmt.n > 16:
        raise ValueError("JAX posit codec supports n <= 16 (int32 datapath)")
    if fmt.max_scale > 120:
        raise ValueError("format scale range exceeds the exact float32 bridge")


def bit_length32(x):
    """Vectorized bit_length for non-negative int32/uint32 (0 -> 0).

    Select-chain binary search — only shifts/compares, so it lowers inside
    Pallas TPU kernels (unlike lax.clz) and is used by both the codec and
    the PDPU normalizer."""
    v = x.astype(_U32)
    out = jnp.zeros(v.shape, _I32)
    for s in (16, 8, 4, 2, 1):
        ge = v >= (_U32(1) << s)
        out = out + jnp.where(ge, _I32(s), 0)
        v = jnp.where(ge, v >> s, v)
    return out + (x != 0).astype(_I32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_unpacked(codes, fmt: PositFormat):
    """codes -> (is_zero, is_nar, sign, scale, frac); frac in
    [2**fb, 2**(fb+1)) for finite non-zero values, fb = fmt.frac_bits.

    All outputs int32 (flags bool).  NaR/zero rows return sign=scale=frac=0.
    """
    _check_jax_fmt(fmt)
    n, es = fmt.n, fmt.es
    x = codes.astype(_U32) & _U32(fmt.mask)
    is_zero = x == 0
    is_nar = x == _U32(fmt.nar_code)
    sign = ((x >> (n - 1)) & 1).astype(_I32)
    xa = jnp.where(sign == 1, (_U32(0) - x) & _U32(fmt.mask), x)
    # left-align the n-1 post-sign bits so the first regime bit sits at bit 30
    body = (xa << (32 - n)) & _U32(0x7FFFFFFF)
    r0 = (body >> 30) & 1
    inv = jnp.where(r0 == 1, ~body, body) & _U32(0x7FFFFFFF)
    lz = 31 - bit_length32(inv)  # leading run length from bit 30
    m = jnp.minimum(lz, n - 1)
    k = jnp.where(r0 == 1, m - 1, -m)
    rem = (body << (m + 1).astype(_U32)) & _U32(0x7FFFFFFF)
    if es > 0:
        e = (rem >> (31 - es)).astype(_I32)
    else:
        e = jnp.zeros_like(k)
    fb = fmt.frac_bits
    if fb > 0:
        mant = (((rem << es) & _U32(0x7FFFFFFF)) >> (31 - fb)).astype(_I32)
    else:
        mant = jnp.zeros_like(k)
    frac = (_I32(1) << fb) | mant
    scale = k * (1 << es) + e
    valid = ~(is_zero | is_nar)
    return (
        is_zero,
        is_nar,
        jnp.where(valid, sign, 0),
        jnp.where(valid, scale, 0),
        jnp.where(valid, frac, 0),
    )


def decode(codes, fmt: PositFormat, dtype=jnp.float32):
    """codes -> float values. Exact for n <= 16 into f32 (NaR -> nan).

    The f32 is assembled bit-by-bit (|scale| <= 120 keeps the exponent in
    the normal range), so this lowers inside Pallas TPU kernels."""
    is_zero, is_nar, sign, scale, frac = decode_unpacked(codes, fmt)
    fb = fmt.frac_bits
    # value = (-1)^sign * 1.mant * 2**scale, mant = low fb bits of frac
    exp_f = jnp.where(is_zero | is_nar, 0, scale + 127)
    mant23 = (frac & ((1 << fb) - 1)) << (23 - fb)
    bits = (sign << 31) | (exp_f << 23) | mant23
    val = jax.lax.bitcast_convert_type(bits.astype(_I32), jnp.float32)
    val = jnp.where(is_zero, 0.0, val)
    val = jnp.where(is_nar, jnp.nan, val)
    return val.astype(dtype)


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------

def encode_core(sign, scale, frac, F, sticky, fmt: PositFormat):
    """Round/pack unpacked values into posit codes (posit-2022 pattern RNE).

    sign/scale/frac: int32 arrays. frac must be 0 (-> code 0) or normalized
    in [2**F, 2**(F+1)).  F may be a python int or a per-element int32 array
    (the PDPU normalizer produces per-element widths).  ``sticky`` marks
    non-zero bits already discarded strictly below frac's LSB.
    """
    _check_jax_fmt(fmt)
    n, es = fmt.n, fmt.es
    sign = sign.astype(_I32)
    scale = scale.astype(_I32)
    frac = frac.astype(_I32)
    is_zero = frac == 0

    # normalize the fraction register to a fixed Fp = n - es fraction bits;
    # with the minimum regime length 2 this guarantees the final rounding
    # cut lands at shift >= 1 and the packed body fits in 31 bits.
    Fp = n - es
    F = jnp.asarray(F, dtype=_I32)
    drop = jnp.clip(F - Fp, 0, 31)
    up = jnp.clip(Fp - F, 0, 31)
    sticky = jnp.asarray(sticky, dtype=bool) | ((frac & ((_I32(1) << drop) - 1)) != 0)
    frac = (frac >> drop) << up

    k = scale >> es  # arithmetic shift = floor division
    e = scale & ((1 << es) - 1) if es > 0 else jnp.zeros_like(scale)

    sat_hi = k >= n - 2
    sat_lo = k <= -(n - 1)
    k_c = jnp.clip(k, -(n - 2), n - 3)
    e = jnp.where(sat_hi | sat_lo, 0, e)

    rlen = jnp.where(k_c >= 0, k_c + 2, 1 - k_c)
    reg = jnp.where(k_c >= 0, ((_I32(1) << (k_c + 1)) - 1) << 1, _I32(1))
    body_hi = (reg << es) | e
    body = (body_hi << Fp) | (frac & ((1 << Fp) - 1))
    shift = rlen + es + Fp - (n - 1)  # >= 1 by construction

    g = (body >> (shift - 1)) & 1
    st = sticky | ((body & ((_I32(1) << (shift - 1)) - 1)) != 0)
    base = body >> shift
    roundup = ((g == 1) & (st | ((base & 1) == 1))).astype(_I32)
    code_abs = base + roundup

    code_abs = jnp.where(sat_hi, fmt.maxpos_code, code_abs)
    code_abs = jnp.where(sat_lo, fmt.minpos_code, code_abs)
    code = jnp.where(sign == 1, (-code_abs) & fmt.mask, code_abs)
    return jnp.where(is_zero, 0, code).astype(_I32)


def encode(values, fmt: PositFormat):
    """float (f32/bf16/f16) -> posit codes (int32; low n bits valid).

    Exact pattern-RNE from the float value (nan/inf -> NaR).  Decomposes the
    f32 bit pattern directly (no frexp), so it lowers inside Pallas TPU
    kernels.  f32 subnormals sit far below minpos of every supported format
    and saturate to minpos via a forced out-of-range scale."""
    _check_jax_fmt(fmt)
    v = values.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(v, _I32)
    sign = (bits >> 31) & 1
    exp8 = (bits >> 23) & 0xFF
    mant = bits & 0x7FFFFF
    is_nar = exp8 == 255  # inf / nan
    is_zero = (exp8 == 0) & (mant == 0)
    subnormal = (exp8 == 0) & (mant != 0)
    scale = jnp.where(subnormal, -130, exp8 - 127)
    frac = jnp.where(is_zero, 0, (_I32(1) << 23) | mant)
    code = encode_core(sign, scale, frac, 23, jnp.zeros(v.shape, bool), fmt)
    return jnp.where(is_nar, fmt.nar_code, code).astype(_I32)


# ---------------------------------------------------------------------------
# storage + quantization helpers
# ---------------------------------------------------------------------------

def pack(values, fmt: PositFormat):
    """float -> posit codes in the narrowest container dtype (int8/int16)."""
    code = encode(values, fmt)
    dt = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[fmt.storage_bits]
    return code.astype(dt)


def unpack(codes, fmt: PositFormat, dtype=jnp.float32):
    """posit codes (any int container) -> float values."""
    return decode(codes.astype(_I32) & fmt.mask, fmt, dtype=dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_ste(x, fmt: PositFormat):
    """Fake-quantize through the posit format with a straight-through grad.

    Forward: decode(encode(x)) — the exact value a posit pipeline would see.
    Backward: identity (STE), the standard recipe for quantization-aware
    training (paper §III-B mixed-precision motivation / PositNN [26]).
    """
    return unpack(encode(x, fmt), fmt, dtype=x.dtype)


def _quantize_fwd(x, fmt):
    return quantize_ste(x, fmt), None


def _quantize_bwd(fmt, _, g):
    return (g,)


quantize_ste.defvjp(_quantize_fwd, _quantize_bwd)


def quantize(x, fmt: PositFormat):
    """Non-differentiable fake-quantization (encode -> decode)."""
    return unpack(encode(x, fmt), fmt, dtype=x.dtype)
