"""The paper's evaluation workload, synthesized.

Table I evaluates every unit on "the activations, weights, and outputs of
the first convolution layer of ResNet18 extracted in FP64".  No ImageNet /
torchvision exists offline, so we synthesize tensors with the statistics
that drive the comparison (DESIGN.md §2 records this substitution):

  * activations: ImageNet-normalized pixels are strongly spatially
    correlated (AR(1), rho ~ 0.98 across a 7x7x3 im2col window) with
    per-patch contrast variation — zero-mean, unit-ish variance, heavy
    shoulders (the Fig. 3 histogram shape);
  * weights: He-scaled, zero-mean *per filter* (trained conv1 filters are
    edge/color detectors — they nearly cancel on smooth patches, which is
    what makes the output distribution cancellation-heavy and rounding
    error visible, as in the paper's accuracy spread);
  * dot products: the im2col rows of the 7x7/stride-2 conv, K = 147.
"""
from __future__ import annotations

import numpy as np

K_CONV1 = 7 * 7 * 3          # 147 MACs per output (ResNet-style stem)
OUT_CHANNELS = 64


def conv1_workload(n_positions: int = 256, batch: int = 1, seed: int = 0,
                   pad_to: int = 8, rho: float = 0.98):
    """Returns (a, b) float64, row-aligned operand pairs for
    M = batch * n_positions * 64 dot products of length K=147
    (zero-padded to a chunk multiple — posit code 0 is exact zero)."""
    rng = np.random.default_rng(seed)
    n_patch = batch * n_positions
    eps = rng.normal(0, 1, (n_patch, K_CONV1))
    acts = np.zeros((n_patch, K_CONV1))
    acts[:, 0] = eps[:, 0]
    for k in range(1, K_CONV1):  # AR(1) spatial correlation
        acts[:, k] = rho * acts[:, k - 1] + np.sqrt(1 - rho ** 2) * eps[:, k]
    acts *= 1.0 + 0.5 * np.abs(rng.normal(0, 1, (n_patch, 1)))  # contrast
    weights = rng.normal(0, np.sqrt(2.0 / K_CONV1), (OUT_CHANNELS, K_CONV1))
    weights -= weights.mean(axis=1, keepdims=True)  # edge-detector-like
    a = np.repeat(acts, OUT_CHANNELS, axis=0)          # [M, K]
    b = np.tile(weights, (n_patch, 1))                 # [M, K]
    pad = (-K_CONV1) % pad_to
    if pad:
        a = np.pad(a, ((0, 0), (0, pad)))
        b = np.pad(b, ((0, 0), (0, pad)))
    return a, b


def dnn_value_histogram(seed: int = 0, n: int = 200_000):
    """Samples of the activation distribution for Fig. 3."""
    rng = np.random.default_rng(seed)
    return 0.8 * rng.normal(0, 1.0, n) + 0.2 * rng.normal(0, 2.2, n)
