"""Benchmark entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1     # one section

Output is CSV-ish: `name,value[,derived]` lines plus `claim,<name>,PASS|FAIL`
rows tying each section back to the paper's quantitative claims.
"""
from __future__ import annotations

import sys
import time


SECTIONS = ("table1", "fig3", "fig6", "fused_vs_discrete", "kernels",
            "roofline", "grad_compress")


def _section(name):
    print(f"\n===== {name} =====")
    t0 = time.perf_counter()
    if name == "table1":
        from . import bench_table1
        bench_table1.main()
    elif name == "fig3":
        from . import bench_fig3
        bench_fig3.main()
    elif name == "fig6":
        from . import bench_fig6
        bench_fig6.main()
    elif name == "fused_vs_discrete":
        from . import bench_fused_vs_discrete
        bench_fused_vs_discrete.main()
    elif name == "kernels":
        from . import bench_kernels
        bench_kernels.main()
    elif name == "roofline":
        from . import roofline
        roofline.main()
    elif name == "grad_compress":
        from . import bench_grad_compress
        bench_grad_compress.main()
    else:
        raise KeyError(name)
    print(f"{name},section_seconds,{time.perf_counter() - t0:.1f}")


def main() -> None:
    wanted = sys.argv[1:] or list(SECTIONS)
    for name in wanted:
        _section(name)


if __name__ == '__main__':
    main()
