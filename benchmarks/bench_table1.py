"""Table I reproduction: accuracy of every dot-product unit on the
conv1-shaped workload + area/delay/power/efficiency columns.

Accuracy is *computed* (bit-faithful emulations vs the FP64 reference);
PDPU hardware columns come from the calibrated generator cost model; the
non-PDPU hardware columns are the paper's own measured values (we cannot
synthesize RTL here — DESIGN.md §2).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import discrete, hwmodel
from repro.core.formats import (P10_2, P13_2, P16_2, PDPUConfig)
from .workload import conv1_workload


def hit_rate_pct(y, y_ref, tau: float = 0.01) -> float:
    """Fraction of outputs within relative tolerance tau of the FP64 ref.

    The paper's "Accuracy" column is consistent with a threshold metric
    (its quire row is below 100% — input quantization alone fails some
    outputs), so we report this alongside the mean-relative metric."""
    import numpy as np
    rel = np.abs(y - y_ref) / np.maximum(np.abs(y_ref), 1e-300)
    return float(100.0 * (rel < tau).mean())


def rows(n_positions: int = 96, seed: int = 0):
    a, b = conv1_workload(n_positions=n_positions, seed=seed)
    exact = (a * b).sum(-1)  # FP64 reference

    out = []

    def add(name, formats, N, wm, y, hw_row, modeled):
        out.append({
            "name": name, "formats": formats, "N": N, "w_m": wm,
            "accuracy_pct": discrete.accuracy_pct(y, exact),
            "hit_pct": hit_rate_pct(y, exact),
            "area_um2": hw_row[0], "delay_ns": hw_row[1], "power_mw": hw_row[2],
            "gops": N / hw_row[1],
            "area_eff": (N / hw_row[1]) / (hw_row[0] * 1e-6),
            "energy_eff": (N / hw_row[1]) / (hw_row[2] * 1e-3),
            "hw_source": "model" if modeled else "paper-reported",
        })

    t0 = time.perf_counter()
    # --- discrete float DPUs (FPnew-style) --------------------------------
    bl = hwmodel.PAPER_TABLE1_BASELINES
    add("FPnew DPU", "FP32", 4, None,
        discrete.dpu_discrete(a, b, 4, discrete.round_fp32),
        bl["FPnew DPU FP32"][2:], False)
    add("FPnew DPU", "FP16", 4, None,
        discrete.dpu_discrete(a, b, 4, discrete.round_fp16),
        bl["FPnew DPU FP16"][2:], False)
    # --- discrete posit DPU (PACoGen-style) --------------------------------
    add("PACoGen DPU", "P(16,2)", 4, None,
        discrete.dpu_discrete(a, b, 4, discrete.make_round_posit(P16_2)),
        bl["PACoGen DPU P(16,2)"][2:], False)

    # --- proposed PDPU variants (Table I block) -----------------------------
    pdpu_rows = [
        ("P(16/16,2)", PDPUConfig(P16_2, P16_2, N=4, w_m=14)),
        ("P(13/16,2)", PDPUConfig(P13_2, P16_2, N=4, w_m=14)),
        ("P(13/16,2)", PDPUConfig(P13_2, P16_2, N=8, w_m=14)),
        ("P(10/16,2)", PDPUConfig(P10_2, P16_2, N=8, w_m=14)),
        ("P(13/16,2)", PDPUConfig(P13_2, P16_2, N=8, w_m=10)),
    ]
    for fmts, cfg in pdpu_rows:
        r = hwmodel.report(cfg)
        add("Proposed PDPU", fmts, cfg.N, cfg.w_m,
            discrete.dpu_pdpu_fused(a, b, cfg),
            (r.area_um2, r.delay_ns, r.power_mw), True)

    # --- quire PDPU ----------------------------------------------------------
    qcfg = PDPUConfig(P13_2, P16_2, N=4, w_m=256)
    rq = hwmodel.report(qcfg)
    add("Quire PDPU", "P(13/16,2)", 4, 256,
        discrete.dpu_pdpu_fused(a, b, qcfg),
        (rq.area_um2, rq.delay_ns, rq.power_mw), True)

    # --- FMA cascades ---------------------------------------------------------
    add("FPnew FMA", "FP32", 1, None,
        discrete.dpu_fma_cascade(a, b, discrete.round_fp32),
        bl["FPnew FMA FP32"][2:], False)
    add("FPnew FMA", "FP16", 1, None,
        discrete.dpu_fma_cascade(a, b, discrete.round_fp16),
        bl["FPnew FMA FP16"][2:], False)
    add("Posit FMA", "P(16,2)", 1, None,
        discrete.dpu_fma_cascade(a, b, discrete.make_round_posit(P16_2)),
        bl["Posit FMA P(16,2)"][2:], False)
    wall = time.perf_counter() - t0
    return out, wall


def claims_check(table):
    """The paper's orderings that must reproduce (EXPERIMENTS.md)."""
    by = {}
    for r in table:
        by[(r["name"], r["formats"], r["N"], r["w_m"])] = r
    fp32 = by[("FPnew DPU", "FP32", 4, None)]
    fp16 = by[("FPnew DPU", "FP16", 4, None)]
    paco = by[("PACoGen DPU", "P(16,2)", 4, None)]
    p16 = by[("Proposed PDPU", "P(16/16,2)", 4, 14)]
    p1316 = by[("Proposed PDPU", "P(13/16,2)", 4, 14)]
    p1016 = by[("Proposed PDPU", "P(10/16,2)", 8, 14)]
    w10 = by[("Proposed PDPU", "P(13/16,2)", 8, 10)]
    quire = by[("Quire PDPU", "P(13/16,2)", 4, 256)]
    fma16 = by[("Posit FMA", "P(16,2)", 1, None)]
    checks = {
        # posit-16 ~ FP32 > FP16 (paper: 100 / 98.86-99.10 / 91.21); the
        # paper's 8-point FP16 collapse needs its (unavailable) real data —
        # both our metrics reproduce the ordering, not that magnitude.
        "fp32_beats_fp16": fp32["hit_pct"] - fp16["hit_pct"] > 1.0,
        "p16_close_to_fp32": fp32["accuracy_pct"] - p16["accuracy_pct"] < 2.0,
        "p16_beats_fp16": (p16["accuracy_pct"] > fp16["accuracy_pct"]
                           and p16["hit_pct"] > fp16["hit_pct"]),
        # fused PDPU > discrete PACoGen and > FMA cascade at same format
        "fused_beats_discrete": (p16["accuracy_pct"] >= paco["accuracy_pct"]
                                 and p16["hit_pct"] >= paco["hit_pct"]),
        "fused_beats_fma": p16["hit_pct"] > fma16["hit_pct"],
        # w_m=14 within 0.5% of quire (paper: 98.69 vs 98.79)
        "wm14_matches_quire": (abs(p1316["accuracy_pct"] - quire["accuracy_pct"]) < 0.5
                               and abs(p1316["hit_pct"] - quire["hit_pct"]) < 1.0),
        # inappropriate format/width costs ~10% accuracy (paper §IV-A)
        "p10_drops": p1316["hit_pct"] - p1016["hit_pct"] > 5.0,
        "w10_drops": p1316["hit_pct"] - w10["hit_pct"] > 2.0,
        # paper's headline hardware claims, from the calibrated model:
        "area_saving_vs_pacogen": 1 - p1316["area_um2"] / paco["area_um2"] > 0.35,
        "delay_saving_vs_pacogen": 1 - p1316["delay_ns"] / paco["delay_ns"] > 0.55,
        "power_saving_vs_pacogen": 1 - p1316["power_mw"] / paco["power_mw"] > 0.60,
        "area_eff_vs_quire_5x": p1316["area_eff"] / quire["area_eff"] > 4.0,
        "energy_eff_vs_quire_2x": p1316["energy_eff"] / quire["energy_eff"] > 1.8,
        "area_eff_vs_posit_fma_3x": p1316["area_eff"] / fma16["area_eff"] > 2.5,
    }
    return checks


def main(csv=True):
    table, wall = rows()
    if csv:
        print("unit,formats,N,w_m,accuracy_pct,hit_pct,area_um2,delay_ns,"
              "power_mw,gops,area_eff,energy_eff,hw_source")
        for r in table:
            print(f"{r['name']},{r['formats']},{r['N']},{r['w_m']},"
                  f"{r['accuracy_pct']:.2f},{r['hit_pct']:.2f},"
                  f"{r['area_um2']:.0f},"
                  f"{r['delay_ns']:.2f},{r['power_mw']:.2f},{r['gops']:.2f},"
                  f"{r['area_eff']:.0f},{r['energy_eff']:.0f},{r['hw_source']}")
    checks = claims_check(table)
    for k, v in checks.items():
        print(f"claim,{k},{'PASS' if v else 'FAIL'}")
    print(f"table1,wall_seconds,{wall:.1f}")
    return table, checks


if __name__ == "__main__":
    main()
