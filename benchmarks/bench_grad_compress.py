"""Posit-compressed gradient all-reduce: quality + wire-byte accounting.

Beyond-paper section: applies PDPU's thesis (narrow posit operands, wide
accumulation, error feedback) to the bandwidth-starved cross-pod gradient
reduction.  Reports the quantization error with/without error feedback and
the analytic wire-byte saving at pod scale.
"""
from __future__ import annotations

import numpy as np

from repro.core import posit_np as pnp
from repro.core.formats import P8_2, P8_1
from repro.optim import compress


def emulate_ring(grads, fmt, err):
    """Single-process emulation of the compressed ring for n pods (numpy):
    stage-1 encode per pod (with feedback), exact sum, stage-2 encode."""
    n = grads.shape[0]
    gf = grads + err
    codes = pnp.encode_np(gf, fmt)
    deq = pnp.decode_np(codes, fmt)
    new_err = gf - deq
    total = deq.sum(0)
    out = pnp.decode_np(pnp.encode_np(total, fmt), fmt) / n
    return out, new_err


def main():
    rng = np.random.default_rng(0)
    n_pods, dim = 8, 4096
    grads = rng.normal(0, 1e-3, (n_pods, dim))  # gradient-scaled values
    want = grads.mean(0)

    for fmt in (P8_2, P8_1):
        err = np.zeros_like(grads)
        acc_fb = np.zeros(dim)
        acc_nofb = np.zeros(dim)
        steps = 50
        for _ in range(steps):
            out_fb, err = emulate_ring(grads, fmt, err)
            out_nofb, _ = emulate_ring(grads, fmt, np.zeros_like(grads))
            acc_fb += out_fb
            acc_nofb += out_nofb
        bias_fb = np.abs(acc_fb / steps - want).mean() / np.abs(want).mean()
        bias_nofb = np.abs(acc_nofb / steps - want).mean() / np.abs(want).mean()
        print(f"grad_compress,{fmt},rel_bias_feedback,{bias_fb:.5f}")
        print(f"grad_compress,{fmt},rel_bias_no_feedback,{bias_nofb:.5f}")
        print(f"claim,error_feedback_debiases_{fmt.n}b,"
              f"{'PASS' if bias_fb < 0.25 * bias_nofb else 'FAIL'}")

    wire = compress.wire_bytes({"g": np.zeros(104_000_000)}, 512, P8_2)
    print(f"grad_compress,wire_f32_bytes_per_dev,{wire['f32_allreduce_bytes']:.3g}")
    print(f"grad_compress,wire_posit8_bytes_per_dev,{wire['posit_bytes']:.3g}")
    print(f"claim,wire_bytes_4x_saving,"
          f"{'PASS' if wire['ratio'] > 3.9 else 'FAIL'}")


if __name__ == "__main__":
    main()
