"""MoE grouped execution-plan comparison: the routed-expert hot path.

bench_exec_paths.py measures dense-model plans; this one measures what MoE
serving is dominated by — the grouped expert einsums — under each
QuantPolicy.execution plan and both dispatch flavors:

  latency              : wall time of the jit'd forward (CPU interpret wall
                         time is NOT TPU performance; the plan-to-plan ratio
                         shows dispatch overheads)
  expert weight bytes  : storage of the we_* stacks alone — the EP-sharded
                         HBM term the grouped fused path shrinks (int8/int16
                         codes vs f32 masters)
  total weight bytes   : whole-checkpoint footprint

Plans: fake_quant on float masters (train), fused over packed expert codes
(serve), bit_exact chunked-PDPU per expert on a micro config (validation).

A final section measures activation-coded grouped serving
(QuantPolicy.with_serving_activations): the expert slabs enter the grouped
fused kernel as posit codes alongside the packed weights — both GEMM
operands at code width — reporting the logits RMSE against the
float-activation reference (the accuracy/bandwidth trade on the MoE path).

Results are also written as machine-readable BENCH_moe_paths.json
(latency + storage per plan; the CI artifact).

    PYTHONPATH=src python benchmarks/bench_moe_paths.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.timing import time_ms, write_bench_json
    from benchmarks.act_serving import act_checks, bench_act_serving, \
        print_act_rows
except ImportError:  # bare-script run: benchmarks/ itself is sys.path[0]
    from timing import time_ms, write_bench_json
    from act_serving import act_checks, bench_act_serving, print_act_rows
from repro import configs
from repro.core.formats import P13_2, P16_2, P8_2
from repro.core.quant import QuantPolicy
from repro.models import api, packing


def expert_bytes(params) -> int:
    """Storage of the routed expert stacks (the EP-sharded weight term)."""
    layers = params.get("layers") or params.get("blocks", {}).get("moe", {})
    return int(sum(np.asarray(layers[n]).nbytes
                   for n in ("we_gate", "we_up", "we_down") if n in layers))


def bench_cfg(cfg, plans, B, S, rng, reps=3):
    rows = []
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    for plan in plans:
        pcfg = cfg.replace(quant=cfg.quant.with_execution(plan))
        params = api.init(jax.random.key(0), pcfg)
        if plan == "fused":
            params = api.pack_params(params, pcfg)
        dispatch = "gshard" if pcfg.moe_grouped_dispatch else "sorted"
        fwd = jax.jit(lambda p, t: api.apply(p, {"tokens": t}, pcfg))
        ms = time_ms(fwd, params, tokens, reps=reps)
        rows.append((pcfg.name, plan, dispatch, B, S, ms,
                     expert_bytes(params), api.weight_bytes(params)))
    return rows


def main():
    rng = np.random.default_rng(0)
    rows = []

    # smoke-scale MoE: train plan (float masters) vs serve plan (packed
    # expert codes through the grouped fused kernel), both dispatch flavors
    smoke = configs.get_smoke("qwen3_moe_235b").replace(
        quant=QuantPolicy(weights=P16_2, kv_cache=P8_2))
    rows += bench_cfg(smoke, ("fake_quant", "fused"), B=2, S=32, rng=rng)
    rows += bench_cfg(smoke.replace(moe_grouped_dispatch=True),
                      ("fake_quant", "fused"), B=2, S=32, rng=rng)

    # micro MoE: all three plans incl. per-expert chunked-PDPU validation
    micro = smoke.replace(
        name="qwen3-moe-micro", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, head_dim=8, vocab_size=64, n_experts=4, top_k=2,
        moe_d_ff=8,
        quant=QuantPolicy(weights=P13_2, activations=P13_2, pdpu_n=4))
    rows += bench_cfg(micro, ("fake_quant", "fused", "bit_exact"),
                      B=1, S=8, rng=rng, reps=1)

    print("model,plan,dispatch,batch,seq,forward_ms,"
          "expert_weight_bytes,total_weight_bytes")
    for name, plan, disp, B, S, ms, eb, wb in rows:
        print(f"{name},{plan},{disp},{B},{S},{ms:.1f},{eb},{wb}")

    # activation-coded grouped serving: both operands at code width
    act_rows = bench_act_serving(smoke, B=2, S=16, rng=rng, act_fmt=P13_2)
    print_act_rows(act_rows)

    by_plan = {r[1]: r for r in rows[:2]}
    f32_experts = by_plan["fake_quant"][6]
    packed_experts = by_plan["fused"][6]
    checks = {
        # int16 codes vs f32 masters: exactly half the expert storage
        "packed_experts_half": packed_experts * 2 == f32_experts,
        "packed_total_smaller": by_plan["fused"][7] < by_plan["fake_quant"][7],
        "all_plans_ran": len(rows) == 7,
        **act_checks(act_rows),
    }
    print("checks:", checks)
    write_bench_json("moe_paths", {
        "plans": [dict(zip(("model", "plan", "dispatch", "batch", "seq",
                            "forward_ms", "expert_weight_bytes",
                            "total_weight_bytes"), r))
                  for r in rows],
        "act_serving": [dict(zip(("model", "act_mode", "batch", "seq",
                                  "forward_ms", "act_bytes_per_elem",
                                  "logits_rmse_vs_float_act"), r))
                        for r in act_rows],
        "checks": checks,
    })
    assert all(checks.values()), checks


if __name__ == "__main__":
    main()
