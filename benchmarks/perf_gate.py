"""CI perf gate: compare fresh BENCH_*.json against the committed baselines.

    BENCH_OUTPUT_DIR=/tmp/bench PYTHONPATH=src python benchmarks/perf_gate.py

For every committed baseline `BENCH_<name>.json` at the repo root, the gate
loads the freshly-generated counterpart from `BENCH_OUTPUT_DIR` (the bench
entrypoints write there when it is set — CI points it at a scratch dir so
the committed baselines are never clobbered before comparison) and fails
loudly when:

  * the fresh file is missing (a bench stopped emitting its JSON);
  * any boolean under the baseline's `checks` dict is no longer true
    (structural guarantees: bit parity, storage ratios, token parity);
  * any metric under the baseline's `gated` dict regressed by more than
    `TOLERANCE` (10%).  Gated metrics are deterministic structural ratios
    (device programs per prefill chunk, kernel launches per decode token,
    KV storage reduction) — higher is better for all of them.  Raw
    wall-clock latencies are deliberately NOT gated: CI hosts run the
    Pallas kernels in interpret mode, where timing noise swamps any real
    signal; latencies stay recorded in the JSONs for offline tracking.

A handful of named baseline metrics outside `gated` are also enforced for
benches that predate the `gated` convention (see LEGACY_GATES).
"""
from __future__ import annotations

import glob
import json
import os
import sys

TOLERANCE = 0.10  # >10% regression on any gated metric fails

# bench name -> [(dotted json path, direction)] for baselines that carry
# their deterministic ratios outside a `gated` dict.  "higher" metrics may
# drop at most TOLERANCE below baseline; "lower" may rise at most that.
LEGACY_GATES = {
    "exec_paths": [
        ("paged_serving.kv_storage_ratio", "higher"),
        ("prefix_sharing.prefill_page_reduction", "higher"),
        ("prefix_sharing.pages_vs_single_ratio", "lower"),
    ],
}


def _dig(d, dotted):
    for part in dotted.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def gate_one(name: str, base: dict, fresh: dict):
    """All failures for one bench (empty list = pass)."""
    fails = []
    for key, ok in (base.get("checks") or {}).items():
        if ok is not True:
            continue  # never gate on a check the baseline itself failed
        got = (fresh.get("checks") or {}).get(key)
        if got is not True:
            fails.append(f"check '{key}': baseline true, fresh {got!r}")
    gates = [(f"gated.{k}", "higher") for k in (base.get("gated") or {})]
    gates += LEGACY_GATES.get(name, [])
    for path, direction in gates:
        want = _dig(base, path)
        got = _dig(fresh, path)
        if want is None:
            continue
        if got is None or not isinstance(got, (int, float)):
            fails.append(f"metric '{path}': missing from fresh results")
            continue
        if direction == "higher" and got < want * (1 - TOLERANCE):
            fails.append(f"metric '{path}': {got:.4g} < "
                         f"{want:.4g} - {TOLERANCE:.0%}")
        if direction == "lower" and got > want * (1 + TOLERANCE):
            fails.append(f"metric '{path}': {got:.4g} > "
                         f"{want:.4g} + {TOLERANCE:.0%}")
    # a gated ratio the fresh bench emits but the baseline doesn't know
    # about is a silent coverage hole: the new metric would never be
    # compared.  Fail by name until the baseline is regenerated.
    base_gated = set(base.get("gated") or {})
    for key in sorted(fresh.get("gated") or {}):
        if key not in base_gated:
            fails.append(
                f"gated metric 'gated.{key}' emitted by the fresh bench but "
                f"missing from the committed baseline BENCH_{name}.json — "
                f"regenerate and recommit the baseline so it is gated")
    return fails


def main():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    fresh_dir = os.environ.get("BENCH_OUTPUT_DIR", ".")
    baselines = sorted(glob.glob(os.path.join(repo, "BENCH_*.json")))
    if not baselines:
        print("perf gate: no committed BENCH_*.json baselines found")
        return 1
    failures = {}
    for path in baselines:
        fname = os.path.basename(path)
        name = fname[len("BENCH_"):-len(".json")]
        with open(path) as f:
            base = json.load(f)
        fresh_path = os.path.join(fresh_dir, fname)
        if os.path.abspath(fresh_path) == os.path.abspath(path):
            print(f"perf gate: BENCH_OUTPUT_DIR resolves onto the committed "
                  f"baseline {fname}; set it to a scratch directory")
            return 1
        if not os.path.exists(fresh_path):
            failures[name] = [f"fresh {fname} missing from {fresh_dir}"]
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        fails = gate_one(name, base, fresh)
        if fails:
            failures[name] = fails
        else:
            n_checks = len(base.get("checks") or {})
            n_gates = len(base.get("gated") or {}) + \
                len(LEGACY_GATES.get(name, []))
            print(f"perf gate: {fname} OK "
                  f"({n_checks} checks, {n_gates} gated metrics)")
    if failures:
        print("\nperf gate FAILED:")
        for name, fails in sorted(failures.items()):
            for f in fails:
                print(f"  [{name}] {f}")
        return 1
    print("perf gate passed for all baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
