"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits,
per (arch x shape x mesh): the three roofline terms in seconds, the
dominant bottleneck, MODEL_FLOPS / HLO_FLOPS usefulness ratio, and a
one-line "what would move the dominant term" note.
"""
from __future__ import annotations

import glob
import json
import os

NOTES = {
    ("compute",): "raise arithmetic intensity: larger per-device tiles, "
                  "fewer remat recomputes, bf16 throughout the MXU path",
    ("memory",): "cut HBM bytes: posit-coded weights/KV (2-4x), fuse "
                 "elementwise chains, wider microbatch to reuse weights",
    ("collective",): "cut wire bytes: bf16/posit-compressed collectives, "
                     "shard so gathers move smaller operands, overlap with "
                     "compute via latency hiding",
}


def load(dirpath="experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r):
    t = r["roofline"]
    terms = {"compute": t["compute_s"], "memory": t["memory_s"],
             "collective": t["collective_s"]}
    dom = t["dominant"]
    bound = max(terms.values())
    useful = t.get("useful_flops_ratio")
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh_tag"],
        "compute_s": terms["compute"], "memory_s": terms["memory"],
        "collective_s": terms["collective"], "dominant": dom,
        "bound_s": bound,
        "useful_flops_ratio": useful,
        "roofline_fraction": terms["compute"] / bound if bound else None,
        "note": NOTES[(dom,)],
    }


def main(dirpath="experiments/dryrun"):
    rows = [fmt_row(r) for r in load(dirpath)]
    if not rows:
        print("roofline,no dryrun artifacts found (run repro.launch.dryrun)")
        return []
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "roofline_fraction,useful_flops_ratio")
    for r in rows:
        uf = f"{r['useful_flops_ratio']:.3f}" if r["useful_flops_ratio"] else "-"
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['compute_s']:.4g},{r['memory_s']:.4g},"
              f"{r['collective_s']:.4g},{r['dominant']},"
              f"{r['roofline_fraction']:.3f},{uf}")
    return rows


if __name__ == "__main__":
    main()
