"""Fig. 3 reproduction: posit's tapered decimal accuracy vs the DNN data
distribution.

decimal_accuracy(x, fmt) = -log10(|x_quantized/x - 1|): higher is better.
The paper's claim: P(16,2) has *more* decimal accuracy than FP16 exactly
where DNN tensor mass lives (|x| in ~[1e-2, 1e1]) and a far wider dynamic
range (no overflow/underflow cliffs at 2^-24 / 65504).
"""
from __future__ import annotations

import numpy as np

from repro.core import posit_np as pnp
from repro.core.formats import P16_2, P8_2
from .workload import dnn_value_histogram


def decimal_accuracy(x, quantize):
    q = quantize(x)
    with np.errstate(over="ignore", invalid="ignore"):
        rel = np.abs(q / x - 1.0)
    rel = np.where(np.isfinite(rel), rel, 1e17)  # overflow == zero accuracy
    rel = np.clip(rel, 1e-17, 1e17)
    return -np.log10(rel)


def _fp16(x):
    return x.astype(np.float16).astype(np.float64)


def rows(n_bins: int = 24):
    edges = np.logspace(-8, 8, n_bins + 1)
    mids = np.sqrt(edges[1:] * edges[:-1])
    data = np.abs(dnn_value_histogram())
    hist, _ = np.histogram(data, bins=edges)
    hist = hist / hist.sum()

    out = []
    for mid, mass in zip(mids, hist):
        xs = mid * np.exp(np.random.default_rng(1).normal(0, 0.1, 256))
        da_p16 = decimal_accuracy(xs, lambda v: pnp.quantize_np(v, P16_2)).mean()
        da_p8 = decimal_accuracy(xs, lambda v: pnp.quantize_np(v, P8_2)).mean()
        da_f16 = decimal_accuracy(xs, _fp16).mean()
        out.append({"magnitude": mid, "data_mass": mass,
                    "posit16": da_p16, "posit8": da_p8, "fp16": da_f16})
    return out


def claims_check(table):
    # mass-weighted decimal accuracy: posit16 > fp16 on the DNN distribution
    wp = sum(r["posit16"] * r["data_mass"] for r in table)
    wf = sum(r["fp16"] * r["data_mass"] for r in table)
    # dynamic range: posit16 still represents 1e-8 and 1e8; fp16 does not
    lo = [r for r in table if r["magnitude"] < 1e-7][0]
    hi = [r for r in table if r["magnitude"] > 1e7][-1]
    return {
        "posit16_beats_fp16_on_dnn_mass": wp > wf,
        "posit16_wider_range_low": lo["posit16"] > 0.5 > max(lo["fp16"], 0),
        "posit16_wider_range_high": hi["posit16"] > 0.5 > max(hi["fp16"], 0),
        "tapered_peak_center": max(table, key=lambda r: r["posit16"])
                               ["magnitude"] < 1e2,
    }


def main():
    table = rows()
    print("magnitude,data_mass,posit16_da,posit8_da,fp16_da")
    for r in table:
        print(f"{r['magnitude']:.3g},{r['data_mass']:.4f},{r['posit16']:.2f},"
              f"{r['posit8']:.2f},{r['fp16']:.2f}")
    for k, v in claims_check(table).items():
        print(f"claim,{k},{'PASS' if v else 'FAIL'}")
    return table


if __name__ == "__main__":
    main()
