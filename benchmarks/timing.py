"""Shared wall-clock + machine-readable-output helpers for the benchmarks."""
from __future__ import annotations

import json
import os
import time

import jax


def time_ms(fn, *args, reps: int = 3) -> float:
    """Wall time of fn(*args) in ms, after one warm-up (compile) call."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e3


def write_bench_json(name: str, payload: dict) -> str:
    """Dump a benchmark's results as BENCH_<name>.json (latency + storage
    per plan — the machine-readable record CI archives next to the logs).
    BENCH_OUTPUT_DIR overrides the destination directory (default: CWD)."""
    out_dir = os.environ.get("BENCH_OUTPUT_DIR", ".")
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    print(f"wrote {path}")
    return path
