"""Shared wall-clock helper for the model-level benchmarks."""
from __future__ import annotations

import time

import jax


def time_ms(fn, *args, reps: int = 3) -> float:
    """Wall time of fn(*args) in ms, after one warm-up (compile) call."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e3
