"""Fig. 6 reproduction: 6-stage pipeline breakdown of PDPU vs dot size N.

Per-stage latency/area from the calibrated generator model, the worst-stage
clock, and the throughput improvement of pipelining vs the combinational
unit (paper: 4.4x / 4.6x, worst stage ~0.37 ns, S1 decode dominating area,
S2/S4 growing fastest with N).
"""
from __future__ import annotations

from repro.core import hwmodel
from repro.core.formats import P13_2, P16_2, PDPUConfig

STAGES = ("S1_decode", "S2_multiply", "S3_align", "S4_accumulate",
          "S5_normalize", "S6_encode")


def rows():
    out = []
    for N in (2, 4, 8, 16):
        cfg = PDPUConfig(P13_2, P16_2, N=N, w_m=14)
        r = hwmodel.report(cfg)
        rec = {"N": N, "comb_delay_ns": r.delay_ns,
               "worst_stage_ns": max(r.stage_delay_ns),
               "fmax_ghz": r.fmax_ghz,
               "throughput_gain": r.delay_ns / max(r.stage_delay_ns),
               "area_um2": r.area_um2}
        for s, d, a in zip(STAGES, r.stage_delay_ns, r.stage_area_um2):
            rec[f"{s}_ns"] = d
            rec[f"{s}_um2"] = a
        out.append(rec)
    return out


def claims_check(table):
    n4 = next(r for r in table if r["N"] == 4)
    n8 = next(r for r in table if r["N"] == 8)
    return {
        # worst stage ~0.37ns -> up to ~2.7 GHz (paper §IV-B)
        "worst_stage_near_0p37ns": abs(n4["worst_stage_ns"] - 0.37) < 0.06,
        "fmax_above_2_5ghz": n4["fmax_ghz"] > 2.5,
        "throughput_gain_over_4x": n4["throughput_gain"] > 4.0,
        # S1 decoders dominate area
        "s1_area_dominates": n4["S1_decode_um2"] == max(
            n4[f"{s}_um2"] for s in STAGES),
        # S2/S4 latency grows with N (tree depth)
        "s2_s4_grow_with_n": (n8["S2_multiply_ns"] >= n4["S2_multiply_ns"]
                              and n8["S4_accumulate_ns"] > n4["S4_accumulate_ns"]),
    }


def main():
    table = rows()
    cols = ["N", "comb_delay_ns", "worst_stage_ns", "fmax_ghz",
            "throughput_gain", "area_um2"] + \
        [f"{s}_ns" for s in STAGES] + [f"{s}_um2" for s in STAGES]
    print(",".join(cols))
    for r in table:
        print(",".join(f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    for k, v in claims_check(table).items():
        print(f"claim,{k},{'PASS' if v else 'FAIL'}")
    return table


if __name__ == "__main__":
    main()
