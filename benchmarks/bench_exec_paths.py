"""Model-level execution-plan comparison: fake_quant vs fused vs bit_exact.

The kernel benchmarks (bench_kernels.py, bench_fused_vs_discrete.py) compare
datapaths at GEMM granularity; this one measures the *model hot path* — the
same transformer forward under each QuantPolicy.execution plan — plus the
storage terms the plans trade on:

  latency          : wall time of the jit'd forward / decode step (CPU
                     interpret wall time is NOT TPU performance, but the
                     plan-to-plan ratio shows the dispatch overheads)
  weight bytes     : checkpoint-resident weight storage (float vs packed
                     posit codes — the HBM footprint serving reads per step)
  kv cache bytes   : decode-state storage per slot configuration

fake_quant and fused run on a smoke config; bit_exact is O(M*N*K) select
chains (VPU-bound by design), so it runs on a micro config — the point is
plan parity and relative cost, not absolute numbers.

Three further sections:

  activation-coded serving : float-activation fused vs both-operands fused
                     (QuantPolicy.with_serving_activations) — the
                     accuracy/bandwidth trade: logits RMSE against the
                     float-activation reference vs GEMM activation-operand
                     bytes per element (f32 vs posit code width).
  QAT train step   : jit'd value_and_grad of the LM loss under fake_quant
                     vs fused execution — the kernel-in-the-loop QAT cost,
                     plus the max relative grad deviation between the two
                     STE datapaths (they compute on identical quantized
                     operands, so this is reduction-order noise).
  paged serving    : the paged posit-KV runtime vs the dense cache on a
                     mixed-length request queue — greedy token parity per
                     family (transformer / mamba / hybrid) and the KV
                     storage ratio: dense f32 `batch x max_seq` allocation
                     vs P(16,1)-coded pages actually backing tokens in
                     flight (must be >= 2x smaller).
  prefix sharing   : a shared-prefix queue (N requests, same system
                     prompt) served with the refcounted page pool — fresh
                     page grants must drop >= 2x vs unshared serving, and
                     N same-prompt requests must stay under 1.5x a single
                     request's pages (the shared prefix is allocated
                     once); token parity with unshared serving rides
                     along.  Prefill device calls shrink too (batched
                     cross-slot chunks + skipped shared prefixes).
  sharded scaling  : the same shared-prefix queue over a kv_pages-sharded
                     page pool at mesh sizes 1/2/4 (each in a subprocess
                     with that many forced host devices) — per-device page
                     budgets and tok/s per size, gated on cross-topology
                     token parity and full per-device reclamation.

Results are also written as machine-readable BENCH_exec_paths.json
(latency + storage per plan; the CI artifact, with a committed baseline
pinning the schema).

    PYTHONPATH=src python benchmarks/bench_exec_paths.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.timing import time_ms, write_bench_json
    from benchmarks.act_serving import act_checks, bench_act_serving, \
        print_act_rows
except ImportError:  # bare-script run: benchmarks/ itself is sys.path[0]
    from timing import time_ms, write_bench_json
    from act_serving import act_checks, bench_act_serving, print_act_rows
from repro import configs
from repro.core.quant import QuantPolicy
from repro.core.formats import P13_2, P16_1, P16_2, P8_2
from repro.models import api


def bench_cfg(cfg, plans, B, S, rng, reps=3):
    rows = []
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    for plan in plans:
        pcfg = cfg.replace(quant=cfg.quant.with_execution(plan))
        params = api.init(jax.random.key(0), pcfg)
        if plan == "fused":
            params = api.pack_params(params, pcfg)
        wbytes = api.weight_bytes(params)
        fwd = jax.jit(lambda p, t: api.apply(p, {"tokens": t}, pcfg))
        ms = time_ms(fwd, params, tokens, reps=reps)
        cache = api.init_cache(pcfg, B, S)
        kv_bytes = int(sum(x.nbytes for x in jax.tree.leaves(cache)))
        rows.append((pcfg.name, plan, B, S, ms, wbytes, kv_bytes))
    return rows


def bench_train_qat(micro, B=2, S=16, reps=2):
    """jit'd value_and_grad of the LM loss: fake_quant STE vs the fused
    kernel-in-the-loop STE (custom_vjp over the packed Pallas forward)."""
    from repro.train import step as step_lib

    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, micro.vocab_size, (B, S)), jnp.int32)
    labs = jnp.asarray(rng.integers(0, micro.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": labs}
    rows, grads = [], {}
    for plan in ("fake_quant", "fused"):
        pcfg = micro.replace(quant=micro.quant.with_execution(plan))
        params = api.init(jax.random.key(0), pcfg)
        grad_fn = jax.jit(jax.value_and_grad(
            lambda p, b, c=pcfg: step_lib.loss_fn(p, b, c)[0]))
        ms = time_ms(grad_fn, params, batch, reps=reps)
        loss, g = grad_fn(params, batch)
        grads[plan] = g
        rows.append((pcfg.name, plan, B, S, ms, float(loss)))
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b)) /
                           jnp.maximum(jnp.max(jnp.abs(a)), 1e-12)),
        grads["fake_quant"], grads["fused"])
    return rows, max(jax.tree.leaves(diffs))


def bench_paged_serving(rng):
    """Paged posit-KV runtime vs dense cache on a mixed-length queue:
    greedy token parity per family + the decode-state storage ratio.

    The dense reference runs token-by-token prefill (buckets=(1,)), so the
    comparison crosses both the cache layout (pages vs rows) and the chunk
    decomposition — for the SSM/hybrid families that pins the chunked SSD
    recurrence, not just the attention path."""
    from repro.serve import Request, ServingEngine

    def serve(cfg, params, prompts, buckets=(16, 4, 1), **kw):
        eng = ServingEngine(cfg, params, batch_slots=4, max_seq=96,
                            prefill_buckets=buckets, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
        out = {r.rid: r.out_tokens for r in eng.run()}
        return out, eng

    lengths = [8, 13, 20, 6, 16, 9]  # the mixed-length queue
    parity = {}
    for arch in ("command_r_35b", "mamba2_1_3b", "jamba_1_5_large"):
        cfg = configs.get_tiny_serving(arch, QuantPolicy(weights=P16_2,
                                                         kv_cache=P16_1))
        params = api.init(jax.random.key(0), cfg)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in lengths]
        out_paged, _ = serve(cfg, params, prompts, page_size=16)
        out_dense, _ = serve(cfg, params, prompts, paged=False,
                             buckets=(1,))
        parity[cfg.family] = out_paged == out_dense

    # storage: dense f32 KV allocation vs P(16,1)-coded pages in flight
    cfg_f32 = configs.get_smoke("command_r_35b").replace(
        quant=QuantPolicy(weights=P16_2))          # kv_cache=None -> f32 KV
    cfg_paged = cfg_f32.replace(
        quant=QuantPolicy(weights=P16_2, kv_cache=P16_1, kv_page_size=16))
    params = api.init(jax.random.key(0), cfg_f32)
    prompts = [rng.integers(0, cfg_f32.vocab_size, n).astype(np.int32)
               for n in lengths]
    _, eng_dense = serve(cfg_f32, params, prompts, paged=False)
    _, eng_paged = serve(cfg_paged, params, prompts)
    dense_kv = eng_dense.kv_cache_summary()["kv_bytes"]
    paged_peak = eng_paged.kv_cache_summary()["kv_bytes_peak"]
    return {
        "queue_prompt_lengths": lengths,
        "token_parity_paged_vs_dense": parity,
        "dense_reference_prefill_buckets": [1],
        "dense_f32_kv_bytes": dense_kv,
        "paged_p16_1_peak_kv_bytes": paged_peak,
        "kv_storage_ratio": dense_kv / paged_peak,
        "page_size": 16,
        "kv_page_format": str(P16_1),
        "peak_pages_in_use": eng_paged.allocator.peak_in_use,
        "pages_capacity": eng_paged.allocator.capacity,
    }


def bench_prefix_sharing(rng, n_req=4):
    """Shared-prefix serving: N requests with the same prompt against the
    refcounted page pool — prefill pages and device calls vs unshared.

    The prompt dominates the token budget (the repeated-system-prompt
    shape), so sharing turns prefill from O(N x prompt) into O(prompt):
    the prefix pages allocate once and every follow-up request maps them
    by reference, COW-forking only the tail page it diverges on."""
    from repro.serve import Request, ServingEngine

    cfg = configs.get_tiny_serving("command_r_35b",
                                   QuantPolicy(weights=P16_2,
                                               kv_cache=P16_1))
    params = api.init(jax.random.key(0), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 46).astype(np.int32)

    def serve(n, sharing):
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=48,
                            page_size=4, prefill_buckets=(16, 4, 1),
                            prefix_sharing=sharing)
        for i in range(n):
            eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=2))
        out = {r.rid: r.out_tokens for r in eng.run()}
        assert len(out) == n and eng.pages_in_use == 0
        calls = sum(eng.stats["prefill_batch_sizes"].values())
        return out, eng.allocator.total_allocs, calls, eng

    _, single_pages, _, _ = serve(1, True)
    out_s, shared_pages, shared_calls, eng_s = serve(n_req, True)
    out_u, unshared_pages, unshared_calls, _ = serve(n_req, False)
    return {
        "n_requests": n_req,
        "prompt_tokens": int(len(prompt)),
        "page_size": 4,
        "token_parity_shared_vs_unshared": out_s == out_u,
        "single_request_pages": single_pages,
        "shared_pages_allocated": shared_pages,
        "unshared_pages_allocated": unshared_pages,
        "prefill_page_reduction": unshared_pages / shared_pages,
        "pages_vs_single_ratio": shared_pages / single_pages,
        "shared_prefill_device_calls": shared_calls,
        "unshared_prefill_device_calls": unshared_calls,
        "pages_shared_refs": eng_s.stats["pages_shared"],
        "cow_forks": eng_s.stats["cow_forks"],
    }


def bench_sharded_scaling(mesh_sizes=(1, 2, 4)):
    """Sharded paged-KV serving scaling: the same mixed shared-prefix
    queue served with the page pool split over 1/2/4 devices.

    Each mesh size runs in a subprocess with that many forced host
    devices (XLA_FLAGS must precede jax init, the test_distributed.py
    idiom).  Interpret-mode CPU wall time measures dispatch + collective
    overhead, not TPU performance — the committed baseline pins the
    schema and the cross-topology invariant: every mesh size emits
    token-identical streams and reports its per-device page budget and
    occupancy."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = textwrap.dedent("""
        import json, time
        import jax, numpy as np
        from repro import configs
        from repro.core.formats import P16_1, P16_2
        from repro.core.quant import QuantPolicy
        from repro.models import api
        from repro.serve import Request, ServingEngine

        n = {n}
        cfg = configs.get_tiny_serving(
            "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P16_1))
        params = api.init(jax.random.key(0), cfg)
        mesh = None
        if n > 1:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(n)
        rng = np.random.default_rng(0)
        system = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        prompts = [np.concatenate([system, rng.integers(
            0, cfg.vocab_size, 1 + (3 * i) % 7).astype(np.int32)])
            for i in range(6)]
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=32,
                            page_size=4, mesh=mesh)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        a = eng.allocator
        print("RESULT " + json.dumps({{
            "mesh_size": n,
            "kv_shards": eng.n_shards,
            "tokens": toks,
            "tokens_per_s": toks / dt,
            "pages_per_device": a.pages_per_shard - 1,
            "pool_pages": eng.layout.n_pages,
            "peak_pages_in_use": a.peak_in_use,
            "pages_in_use_after_drain": a.pages_in_use,
            "out": {{r.rid: list(r.out_tokens) for r in done}},
        }}))
    """)
    rows = []
    for n in mesh_sizes:
        env = {**os.environ,
               "XLA_FLAGS": f"--xla_force_host_platform_device_count={n}",
               "PYTHONPATH": os.path.join(repo, "src")}
        r = subprocess.run([sys.executable, "-c", code.format(n=n)],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, f"mesh={n}\n{r.stdout}\n{r.stderr}"
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        rows.append(json.loads(line[len("RESULT "):]))
    ref = rows[0].pop("out")
    parity = all(row.pop("out") == ref for row in rows[1:])
    return {
        "queue": "6 requests, 8-token shared system prefix, mixed tails",
        "rows": rows,
        "token_parity_across_mesh_sizes": parity,
        "pools_drained": all(r["pages_in_use_after_drain"] == 0
                             for r in rows),
    }


def bench_speculative_serving(rng, k=4, max_new=6):
    """Posit-native speculative decoding under the async front end vs the
    plain synchronous fused engine on the same queue.

    The serve policy runs fused (packed posit weights through the Pallas
    kernels — the expensive target); the draft is `with_draft()`, the same
    quantized function on float masters via cheap XLA dots, over the SAME
    posit-coded KV pages.  Verification re-attends every drafted position
    with the serve policy in ONE batched multi-query dispatch, so token
    streams are bitwise the plain engine's — speculation only changes how
    many *target* device programs the stream costs.  Wall tok/s and the
    front end's TTFT/ITL histograms are recorded (interpret-mode noise:
    never gated); the deterministic terms — accept rate and committed
    tokens per target program vs plain decode — carry the gate."""
    import asyncio
    import time

    from repro.serve import AsyncServingFrontend, Request, ServingEngine

    cfg = configs.get_tiny_serving(
        "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2,
                                     execution="fused"))
    params_f = api.init(jax.random.key(0), cfg)
    params = api.pack_params(params_f, cfg)
    lengths = [7, 11, 5]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    def run_plain():
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=32)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(),
                               max_new_tokens=max_new))
        t0 = time.perf_counter()
        done = {r.rid: r.out_tokens for r in eng.run()}
        return done, time.perf_counter() - t0, eng

    def run_spec():
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=32,
                            speculate_k=k, draft_params=params_f)
        fe = AsyncServingFrontend(eng)

        async def drain():
            ts = [fe.submit(p.copy(), max_new_tokens=max_new, rid=i)
                  for i, p in enumerate(prompts)]
            toks, _ = await asyncio.gather(
                asyncio.gather(*(t.wait() for t in ts)), fe.run())
            return {t.rid: list(got) for t, got in zip(ts, toks)}

        t0 = time.perf_counter()
        out = asyncio.run(drain())
        return out, time.perf_counter() - t0, fe, eng

    # warmup pass on throwaway engines: both paths trace/compile their
    # device programs (draft forward, batched verify, fused decode) so the
    # timed pass below compares steady-state serving, not jit time
    run_plain()
    run_spec()

    out_plain, dt_plain, plain = run_plain()
    out_spec, dt_spec, frontend, spec = run_spec()

    s = frontend.execution_summary()
    ps = plain.execution_summary()
    n_tok = sum(len(t) for t in out_plain.values())
    decode_tok = n_tok - len(prompts)  # first tokens come from prefill
    # committed decode tokens per TARGET-model device program: plain fused
    # decode batches B slots into one program; a verify program commits up
    # to B*k.  (The drafts are cheap XLA programs and excluded by design —
    # the target forward is what speculation amortizes.)
    eff_plain = decode_tok / ps["decode_device_programs"]
    eff_spec = (s["speculation_committed_tokens"] / s["speculation_rounds"]
                if s["speculation_rounds"] else 0.0)
    return {
        "queue_prompt_lengths": lengths,
        "max_new_tokens": max_new,
        "speculate_k": k,
        "draft_policy": "with_draft (fake_quant on float masters)",
        "token_parity_speculative_vs_plain": out_spec == out_plain,
        "accept_rate": s["speculation_accept_rate"],
        "speculation_rounds": s["speculation_rounds"],
        "committed_tokens": s["speculation_committed_tokens"],
        "plain_decode_device_programs": ps["decode_device_programs"],
        "spec_decode_device_programs": s["decode_device_programs"],
        "plain_tokens_per_target_program": eff_plain,
        "spec_tokens_per_target_program": eff_spec,
        "target_program_efficiency_ratio": (eff_spec / eff_plain
                                            if eff_plain else 0.0),
        "plain_tokens_per_s": n_tok / dt_plain,
        "spec_tokens_per_s": n_tok / dt_spec,
        "speculative_speedup": dt_plain / dt_spec,
        "ttft_ms": s["ttft_ms"],
        "itl_ms": s["itl_ms"],
        "frontend_preemptions": s["frontend_preemptions"],
    }


def main():
    rng = np.random.default_rng(0)
    rows = []

    # smoke-scale model: fake_quant (training path) vs fused (serving path)
    smoke = configs.get_smoke("command_r_35b").replace(
        quant=QuantPolicy(weights=P16_2, kv_cache=P8_2))
    rows += bench_cfg(smoke, ("fake_quant", "fused"), B=2, S=64, rng=rng)

    # micro model: all three plans incl. the bit-exact chunked-PDPU kernel
    micro = smoke.replace(
        name="command-r-35b-micro", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, head_dim=8, d_ff=32, vocab_size=64,
        quant=QuantPolicy(weights=P13_2, activations=P13_2, pdpu_n=4))
    rows += bench_cfg(micro, ("fake_quant", "fused", "bit_exact"),
                      B=1, S=8, rng=rng, reps=1)

    print("model,plan,batch,seq,forward_ms,weight_bytes,kv_cache_bytes")
    for name, plan, B, S, ms, wb, kb in rows:
        print(f"{name},{plan},{B},{S},{ms:.1f},{wb},{kb}")

    # serving accuracy/bandwidth trade: float vs posit-coded activations
    act_rows = bench_act_serving(smoke, B=2, S=32, rng=rng, act_fmt=P13_2,
                                 reps=3)
    print_act_rows(act_rows)

    # kernel-in-the-loop QAT: train-step cost + grad parity across plans
    qat_micro = micro.replace(quant=QuantPolicy(weights=P13_2,
                                                activations=P13_2))
    qat_rows, grad_dev = bench_train_qat(qat_micro)
    print("\nmodel,plan,batch,seq,train_step_ms,loss")
    for name, plan, B, S, ms, loss in qat_rows:
        print(f"{name},{plan},{B},{S},{ms:.1f},{loss:.4f}")
    print(f"max relative grad deviation fused vs fake_quant: {grad_dev:.3e}")

    # paged posit-KV serving: per-family parity + the storage win
    paged = bench_paged_serving(rng)
    print("\npaged serving (mixed-length queue "
          f"{paged['queue_prompt_lengths']}):")
    print(f"  token parity paged==dense: {paged['token_parity_paged_vs_dense']}")
    print(f"  dense f32 kv bytes: {paged['dense_f32_kv_bytes']}  "
          f"paged {paged['kv_page_format']} peak kv bytes: "
          f"{paged['paged_p16_1_peak_kv_bytes']}  "
          f"ratio: {paged['kv_storage_ratio']:.2f}x")

    # prefix sharing: N same-prompt requests against the refcounted pool
    share = bench_prefix_sharing(rng)
    print(f"\nprefix sharing ({share['n_requests']} requests x "
          f"{share['prompt_tokens']}-token shared prompt):")
    print(f"  fresh pages: single {share['single_request_pages']}, "
          f"shared {share['shared_pages_allocated']}, "
          f"unshared {share['unshared_pages_allocated']} "
          f"({share['prefill_page_reduction']:.2f}x reduction; "
          f"{share['pages_vs_single_ratio']:.2f}x a single request)")
    print(f"  prefill device calls: shared "
          f"{share['shared_prefill_device_calls']} vs unshared "
          f"{share['unshared_prefill_device_calls']}; "
          f"{share['pages_shared_refs']} page refs shared, "
          f"{share['cow_forks']} COW forks; token parity: "
          f"{share['token_parity_shared_vs_unshared']}")

    # sharded pool scaling: pages/device + tok/s vs kv_pages mesh size
    scaling = bench_sharded_scaling()
    print("\nsharded paged-KV scaling "
          f"({scaling['queue']}):")
    print("mesh,kv_shards,pages_per_device,pool_pages,peak_pages,tok_s")
    for r in scaling["rows"]:
        print(f"{r['mesh_size']},{r['kv_shards']},{r['pages_per_device']},"
              f"{r['pool_pages']},{r['peak_pages_in_use']},"
              f"{r['tokens_per_s']:.1f}")
    print(f"  token parity across mesh sizes: "
          f"{scaling['token_parity_across_mesh_sizes']}  pools drained: "
          f"{scaling['pools_drained']}")

    # speculative decoding + async front end: accept rate, target-program
    # amortization, wall tok/s, TTFT/ITL
    sp = bench_speculative_serving(rng)
    print(f"\nspeculative serving (k={sp['speculate_k']}, queue "
          f"{sp['queue_prompt_lengths']} x {sp['max_new_tokens']} new):")
    print(f"  token parity speculative==plain: "
          f"{sp['token_parity_speculative_vs_plain']}")
    print(f"  accept rate {sp['accept_rate']:.3f} over "
          f"{sp['speculation_rounds']} rounds "
          f"({sp['committed_tokens']} tokens committed)")
    print(f"  committed tokens per target program: "
          f"{sp['spec_tokens_per_target_program']:.2f} vs plain "
          f"{sp['plain_tokens_per_target_program']:.2f} "
          f"({sp['target_program_efficiency_ratio']:.2f}x)")
    print(f"  tok/s: speculative {sp['spec_tokens_per_s']:.1f} vs plain "
          f"{sp['plain_tokens_per_s']:.1f} "
          f"({sp['speculative_speedup']:.2f}x; interpret wall time)")
    ttft, itl = sp["ttft_ms"], sp["itl_ms"]
    print(f"  TTFT p50={ttft['p50_ms']:.1f}ms p95={ttft['p95_ms']:.1f}ms; "
          f"ITL p50={itl['p50_ms']:.1f}ms p95={itl['p95_ms']:.1f}ms")

    by_plan = {r[1]: r for r in rows[:2]}
    f32_w = by_plan["fake_quant"][5]
    packed_w = by_plan["fused"][5]
    checks = {
        "packed_weights_smaller": packed_w < f32_w,
        "all_plans_ran": len(rows) == 5,
        # activation-coded path: halved operand bandwidth, sane accuracy
        **act_checks(act_rows),
        # the two STE datapaths back-propagate the same quantized operands
        "qat_grads_match": grad_dev < 1e-2,
        # paged posit-KV decode: token parity on every family, and the
        # coded pages in flight beat the dense f32 allocation >= 2x
        "paged_token_parity": all(
            paged["token_parity_paged_vs_dense"].values()),
        "paged_kv_storage_2x": paged["kv_storage_ratio"] >= 2.0,
        # prefix sharing: shared-prefix queues prefill >= 2x fewer fresh
        # pages, N same-prompt requests stay < 1.5x a single request's
        # pages (the shared prefix allocates once), bit-identical tokens
        "prefix_sharing_parity": share["token_parity_shared_vs_unshared"],
        "prefix_prefill_pages_2x": share["prefill_page_reduction"] >= 2.0,
        "prefix_pages_near_single": share["pages_vs_single_ratio"] < 1.5,
        # sharded pool: every kv_pages mesh size emits identical tokens
        # and reclaims its per-device budgets completely
        "sharded_token_parity": scaling["token_parity_across_mesh_sizes"],
        "sharded_pools_drained": scaling["pools_drained"],
        # speculation: bitwise the plain streams, and each target-model
        # dispatch commits strictly more decode tokens than plain fused
        # decode — the tokens/sec term on dispatch-bound hardware.  Wall
        # tok/s is recorded above but never gated: interpret-mode Pallas
        # cost scales with attended positions (a k-token verify costs ~k
        # one-token decodes), so the dispatch amortization speculation
        # buys is exactly what interpretation does not charge for.
        "speculative_token_parity": sp["token_parity_speculative_vs_plain"],
        "speculative_beats_plain_per_target_program":
            sp["target_program_efficiency_ratio"] > 1.0,
    }
    print("checks:", checks)
    write_bench_json("exec_paths", {
        "plans": [dict(zip(("model", "plan", "batch", "seq", "forward_ms",
                            "weight_bytes", "kv_cache_bytes"), r))
                  for r in rows],
        "act_serving": [dict(zip(("model", "act_mode", "batch", "seq",
                                  "forward_ms", "act_bytes_per_elem",
                                  "logits_rmse_vs_float_act"), r))
                        for r in act_rows],
        "qat": {
            "rows": [dict(zip(("model", "plan", "batch", "seq",
                               "train_step_ms", "loss"), r))
                     for r in qat_rows],
            "max_rel_grad_deviation": grad_dev,
        },
        "paged_serving": paged,
        "prefix_sharing": share,
        "sharded_scaling": scaling,
        "speculative_serving": sp,
        # deterministic structural ratios (greedy, fixed queue, fixed
        # seeds): the perf gate compares each against the committed
        # baseline at 10% tolerance, direction "higher"
        "gated": {
            "speculation_accept_rate": sp["accept_rate"],
            "speculation_target_program_efficiency":
                sp["target_program_efficiency_ratio"],
        },
        "checks": checks,
    })
    assert all(checks.values()), checks


if __name__ == "__main__":
    main()
