"""Kernel micro-benchmarks (CPU interpret wall time is NOT TPU performance;
the derived column is the analytic TPU roofline time for the same call:
max(bytes/HBM_bw, flops/MXU) from the kernel's own tile arithmetic)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import P13_2, P16_2, P8_2, PDPUConfig
from repro.kernels import ops
from repro.launch.mesh import HW


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def rows(rng=None):
    rng = rng or np.random.default_rng(0)
    out = []

    # codec kernels
    for fmt, shape in [(P16_2, (1024, 1024)), (P8_2, (1024, 1024))]:
        codes = jnp.asarray(rng.integers(0, 1 << fmt.n, shape), jnp.int32)
        us = _time(lambda c: ops.decode(c, fmt), codes)
        n = np.prod(shape)
        tpu_us = max(n * (fmt.storage_bits // 8 + 4) / HW["hbm_bw"],
                     n * 20 / (HW["peak_flops_bf16"] / 2)) * 1e6
        out.append((f"decode_{fmt}_{shape[0]}x{shape[1]}", us, tpu_us))
        x = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
        us = _time(lambda v: ops.encode(v, fmt), x)
        out.append((f"encode_{fmt}_{shape[0]}x{shape[1]}", us, tpu_us))

    # fused matmul kernel (posit-in, posit-out)
    M = K = N = 512
    a = jnp.asarray(rng.integers(0, 1 << 16, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 16, (K, N)), jnp.int32)
    a = jnp.where(a == P16_2.nar_code, 0, a)
    b = jnp.where(b == P16_2.nar_code, 0, b)
    us = _time(lambda x, y: ops.fused_matmul(x, y, P16_2, P16_2, P16_2,
                                             bm=128, bn=128, bk=256), a, b)
    flops = 2 * M * K * N
    byts = (M * K + K * N) * 2 + M * N * 2
    tpu_us = max(flops / HW["peak_flops_bf16"], byts / HW["hbm_bw"]) * 1e6
    out.append((f"fused_matmul_{M}x{K}x{N}_p16", us, tpu_us))

    # bit-exact PDPU GEMM kernel (VPU-bound fidelity path)
    cfg = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
    a = jnp.asarray(rng.integers(0, 1 << 13, (64, 32)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 13, (32, 64)), jnp.int32)
    us = _time(lambda x, y: ops.pdpu_matmul(x, y, cfg, bm=32, bn=32), a, b)
    # ~200 int ops per MAC on the VPU (8-wide int32 lanes x 128)
    vpu_ops = 64 * 64 * 32 * 200
    tpu_us = vpu_ops / (HW["peak_flops_bf16"] / 16) * 1e6
    out.append(("pdpu_exact_gemm_64x32x64", us, tpu_us))
    return out


def main():
    print("kernel,us_per_call_cpu_interpret,us_per_call_tpu_roofline")
    for name, us, tpu in rows():
        print(f"{name},{us:.0f},{tpu:.2f}")


if __name__ == "__main__":
    main()
