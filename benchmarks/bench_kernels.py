"""Kernel micro-benchmarks (CPU interpret wall time is NOT TPU performance;
the derived column is the analytic TPU roofline time for the same call:
max(bytes/HBM_bw, flops/MXU) from the kernel's own tile arithmetic).

Three sections beyond the raw kernel table:

  decode tokens/sec : the paged-attention decode step as served — one
      3-D kernel launch per new token (the T=1 hot loop) vs ONE 4-D
      multi-query launch covering all T tokens of every active slot.
      The grids compute bitwise-identical outputs (checked here), so the
      structural win is launches/token: T -> 1.
  prefill fused vs three-program : one prefill chunk through the fused
      kernel (ops.prefill_attention_paged — attention + posit KV encode
      + page scatter in ONE device program) vs the decomposed path
      (flash_attention, kv encode, insert_chunk_batched: three).  Bit
      parity of attention output and written pages is asserted.
  autotune : whether the committed tile cache resolved params for the
      shapes this benchmark launches (kernels/autotune.hit_report).

Results are written as machine-readable BENCH_kernels.json.  `checks` are
hard booleans; `gated` carries the structural ratios the CI perf gate
(benchmarks/perf_gate.py) compares against the committed baseline —
wall-clock latencies are recorded but never gated (interpret-mode noise).

    PYTHONPATH=src python benchmarks/bench_kernels.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.timing import time_ms, write_bench_json
except ImportError:  # bare-script run: benchmarks/ itself is sys.path[0]
    from timing import time_ms, write_bench_json
from repro.core import posit
from repro.core.formats import P13_2, P16_1, P16_2, P8_2, PDPUConfig
from repro.kernels import autotune, ops
from repro.launch.mesh import HW
from repro.models import common, paged


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def rows(rng=None):
    rng = rng or np.random.default_rng(0)
    out = []

    # codec kernels
    for fmt, shape in [(P16_2, (1024, 1024)), (P8_2, (1024, 1024))]:
        codes = jnp.asarray(rng.integers(0, 1 << fmt.n, shape), jnp.int32)
        us = _time(lambda c: ops.decode(c, fmt), codes)
        n = np.prod(shape)
        tpu_us = max(n * (fmt.storage_bits // 8 + 4) / HW["hbm_bw"],
                     n * 20 / (HW["peak_flops_bf16"] / 2)) * 1e6
        out.append((f"decode_{fmt}_{shape[0]}x{shape[1]}", us, tpu_us))
        x = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
        us = _time(lambda v: ops.encode(v, fmt), x)
        out.append((f"encode_{fmt}_{shape[0]}x{shape[1]}", us, tpu_us))

    # fused matmul kernel (posit-in, posit-out)
    M = K = N = 512
    a = jnp.asarray(rng.integers(0, 1 << 16, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 16, (K, N)), jnp.int32)
    a = jnp.where(a == P16_2.nar_code, 0, a)
    b = jnp.where(b == P16_2.nar_code, 0, b)
    us = _time(lambda x, y: ops.fused_matmul(x, y, P16_2, P16_2, P16_2,
                                             bm=128, bn=128, bk=256), a, b)
    flops = 2 * M * K * N
    byts = (M * K + K * N) * 2 + M * N * 2
    tpu_us = max(flops / HW["peak_flops_bf16"], byts / HW["hbm_bw"]) * 1e6
    out.append((f"fused_matmul_{M}x{K}x{N}_p16", us, tpu_us))

    # bit-exact PDPU GEMM kernel (VPU-bound fidelity path)
    cfg = PDPUConfig(P13_2, P16_2, N=4, w_m=14)
    a = jnp.asarray(rng.integers(0, 1 << 13, (64, 32)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 13, (32, 64)), jnp.int32)
    us = _time(lambda x, y: ops.pdpu_matmul(x, y, cfg, bm=32, bn=32), a, b)
    # ~200 int ops per MAC on the VPU (8-wide int32 lanes x 128)
    vpu_ops = 64 * 64 * 32 * 200
    tpu_us = vpu_ops / (HW["peak_flops_bf16"] / 16) * 1e6
    out.append(("pdpu_exact_gemm_64x32x64", us, tpu_us))
    return out


def bench_decode_mq(rng):
    """Decode-step tokens/sec: T single-token 3-D launches vs one 4-D
    multi-query launch over the same pool — bitwise-identical outputs."""
    B, T, Hq, Hkv, Dh, ps, M = 4, 8, 4, 2, 8, 4, 6
    fmt = P16_1
    F = Hkv * Dh
    n_pages = 1 + B * M
    kp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                jnp.float32), fmt)
    vp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                jnp.float32), fmt)
    bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
    lengths = jnp.asarray(rng.integers(T, M * ps, B), jnp.int32)
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, T, Hq, Dh)), jnp.float32)

    def single_loop(q):
        outs = [ops.paged_attention(q[:, t], kp, vp, bt,
                                    lengths - (T - 1 - t), win, fmt_kv=fmt)
                for t in range(T)]
        return jnp.stack(outs, axis=1)

    def mq(q):
        return ops.paged_attention(q, kp, vp, bt, lengths, win, fmt_kv=fmt)

    single_ms = time_ms(single_loop, q)
    mq_ms = time_ms(mq, q)
    exact = bool(jnp.all(single_loop(q) == mq(q)))
    return {
        "slots": B, "new_tokens_per_slot": T,
        "single_token_ms_per_step": single_ms,
        "single_token_tokens_per_s": B * T / (single_ms / 1e3),
        "multi_query_ms_per_step": mq_ms,
        "multi_query_tokens_per_s": B * T / (mq_ms / 1e3),
        # structural: one MQ launch replaces T per-token launches
        "launches_per_token_ratio": float(T),
        "mq_matches_single_token": exact,
    }


def bench_fused_prefill(rng):
    """One prefill chunk: fused single-program kernel vs the decomposed
    three-program path (attention, KV encode, page insert) — bit parity
    of the attention output and every written non-trash page."""
    B, C, Hq, Hkv, Dh, ps, M = 2, 8, 4, 2, 8, 4, 6
    fmt = P16_1
    F = Hkv * Dh
    n_pages = 1 + B * M
    kp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                jnp.float32), fmt)
    vp = posit.pack(jnp.asarray(rng.normal(0, 1, (n_pages, ps, F)),
                                jnp.float32), fmt)
    bt = jnp.asarray(1 + np.arange(B * M).reshape(B, M), jnp.int32)
    starts = jnp.asarray([4, 9], jnp.int32)
    win = jnp.full((1,), 2 ** 30, jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, C, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, C, Hkv, Dh)), jnp.float32)

    def three_program(q, k, v):
        k_codes = posit.pack(k.reshape(B, C, -1), fmt)        # program 1
        v_codes = posit.pack(v.reshape(B, C, -1), fmt)
        hist_k = paged.gather_slots(kp, bt)
        hist_v = paged.gather_slots(vp, bt)
        k_new = paged.insert_chunk_batched(kp, bt, starts, k_codes)  # 2
        v_new = paged.insert_chunk_batched(vp, bt, starts, v_codes)
        S_h = hist_k.shape[1]
        hist_pos = jnp.broadcast_to(jnp.arange(S_h, dtype=jnp.int32)[None],
                                    (B, S_h))
        hist_pos = jnp.where(hist_pos < starts[:, None], hist_pos, -1)
        pos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        kd = posit.unpack(hist_k, fmt).reshape(B, S_h, Hkv, Dh)
        vd = posit.unpack(hist_v, fmt).reshape(B, S_h, Hkv, Dh)
        attn = common.flash_attention(                        # program 3
            q, jnp.concatenate([kd, k], axis=1),
            jnp.concatenate([vd, v], axis=1), pos,
            jnp.concatenate([hist_pos, pos], axis=1), causal=True,
            window=None)
        return attn, k_new, v_new

    def fused(q, k, v):
        return ops.prefill_attention_paged(q, k, v, kp, vp, bt, starts, win,
                                           fmt_kv=fmt)

    three_ms = time_ms(three_program, q, k, v)
    fused_ms = time_ms(fused, q, k, v)
    a0, k0, v0 = three_program(q, k, v)
    a1, k1, v1 = fused(q, k, v)
    exact = bool(jnp.all(a0 == a1) and jnp.all(k0[1:] == k1[1:])
                 and jnp.all(v0[1:] == v1[1:]))
    return {
        "slots": B, "chunk": C,
        "three_program_ms": three_ms,
        "fused_ms": fused_ms,
        # structural: 3 logical device programs collapse into 1
        "programs_per_chunk_ratio": 3.0,
        "fused_bit_identical": exact,
    }


def bench_serving_programs():
    """Serving-structural launch counts on a real engine run with a long
    prompt (> 3 flash chunks, so fused prefill streams history): device
    programs per prefill chunk and per decode step.  Both ratios are
    work-units-per-program — 1.0 when every chunk / step is a single
    fused device program (the gated target), < 1 on any fallback."""
    from repro import configs
    from repro.core.quant import QuantPolicy
    from repro.models import api
    from repro.serve.engine import Request, ServingEngine

    q = QuantPolicy(weights=P16_2, kv_cache=P8_2, execution="fused")
    cfg = configs.get_tiny_serving("command_r_35b", q)
    params = api.init(jax.random.key(0), cfg)
    orig_chunk = paged.FLASH_CHUNK
    paged.FLASH_CHUNK = 16  # page size 16 divides it: fused span gate holds
    try:
        rng = np.random.default_rng(5)
        prompt = [int(t) for t in
                  rng.integers(0, cfg.vocab_size, 3 * paged.FLASH_CHUNK + 5)]
        eng = ServingEngine(cfg, params, batch_slots=2, max_seq=64,
                            greedy=True, base_seed=7)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        eng.submit(Request(rid=1, prompt=prompt[:9], max_new_tokens=4))
        done = eng.run()
        summ = eng.execution_summary()
    finally:
        paged.FLASH_CHUNK = orig_chunk
    chunks = summ["prefill_chunks"]
    p_progs = summ["prefill_device_programs"]
    steps = summ["decode_steps"]
    d_progs = summ["decode_device_programs"]
    return {
        "prompt_tokens": len(prompt), "flash_chunk": 16,
        "completed": len(done),
        "prefill_chunks": chunks,
        "prefill_device_programs": p_progs,
        "decode_steps": steps,
        "decode_device_programs": d_progs,
        # structural: 1.0 = every prefill chunk / decode step is ONE program
        "prefill_chunks_per_device_program": chunks / p_progs,
        "decode_steps_per_device_program": steps / d_progs,
        "long_prefill_fully_fused": p_progs == chunks,
        "decode_single_program": d_progs == steps and summ["fused_decode"],
    }


def main():
    rng = np.random.default_rng(0)
    print("kernel,us_per_call_cpu_interpret,us_per_call_tpu_roofline")
    kernel_rows = rows(rng)
    for name, us, tpu in kernel_rows:
        print(f"{name},{us:.0f},{tpu:.2f}")

    decode = bench_decode_mq(rng)
    print(f"\ndecode: {decode['slots']} slots x "
          f"{decode['new_tokens_per_slot']} tokens — "
          f"single-token {decode['single_token_ms_per_step']:.1f} ms "
          f"({decode['single_token_tokens_per_s']:.0f} tok/s) vs "
          f"multi-query {decode['multi_query_ms_per_step']:.1f} ms "
          f"({decode['multi_query_tokens_per_s']:.0f} tok/s); "
          f"bitwise match: {decode['mq_matches_single_token']}")

    prefill = bench_fused_prefill(rng)
    print(f"prefill: three-program {prefill['three_program_ms']:.1f} ms vs "
          f"fused {prefill['fused_ms']:.1f} ms per chunk; "
          f"bit identical: {prefill['fused_bit_identical']}")

    serving = bench_serving_programs()
    print(f"serving: {serving['prompt_tokens']}-token prompt over "
          f"flash_chunk={serving['flash_chunk']} — "
          f"{serving['prefill_chunks']} prefill chunks / "
          f"{serving['prefill_device_programs']} programs, "
          f"{serving['decode_steps']} decode steps / "
          f"{serving['decode_device_programs']} programs")

    tuned = autotune.hit_report()
    n_entries = len(autotune.get_cache().entries)
    print(f"autotune: {n_entries} cache entries; hits/misses: {tuned}")

    checks = {
        "mq_matches_single_token": decode["mq_matches_single_token"],
        "fused_prefill_bit_identical": prefill["fused_bit_identical"],
        "long_prefill_fully_fused": serving["long_prefill_fully_fused"],
        "decode_single_program": serving["decode_single_program"],
        "autotune_cache_loaded": n_entries > 0,
    }
    payload = {
        "kernels": [{"name": n, "us_cpu_interpret": u, "us_tpu_roofline": t}
                    for n, u, t in kernel_rows],
        "decode": decode,
        "prefill": prefill,
        "serving": serving,
        "autotune": {"entries": n_entries, "report": tuned},
        # the CI perf gate compares these (>10% regression fails); they
        # are structural ratios, deterministic on any host
        "gated": {
            "decode_launches_per_token_ratio":
                decode["launches_per_token_ratio"],
            "prefill_programs_per_chunk_ratio":
                prefill["programs_per_chunk_ratio"],
            # 1.0 = every long-prompt prefill chunk is ONE fused program
            "prefill_chunks_per_device_program":
                serving["prefill_chunks_per_device_program"],
            # 1.0 = every decode step is ONE program (fused epilogue)
            "decode_steps_per_device_program":
                serving["decode_steps_per_device_program"],
        },
        "checks": checks,
    }
    write_bench_json("kernels", payload)
    failed = [k for k, ok in checks.items() if not ok]
    if failed:
        raise SystemExit(f"kernel benchmark checks failed: {failed}")
    print("all kernel benchmark checks passed:",
          ", ".join(sorted(checks)))


if __name__ == "__main__":
    main()
