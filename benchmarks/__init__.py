"""Benchmarks — one section per paper table/figure (see run.py)."""
