"""Shared activation-coded serving measurement (used by bench_exec_paths
and bench_moe_paths): float-activation fused vs both-operands fused on a
packed checkpoint — the accuracy/bandwidth serving trade.

Each row: [model, act_mode, B, S, forward_ms, act_bytes_per_elem,
logits_rmse_vs_float_act].  act_bytes_per_elem is *measured* from the
container dtype the activation operand actually travels in (the codec
kernel's output for the coded mode), not from the format label — a
regression that widens the operand back to f32 shows up here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.timing import time_ms
except ImportError:  # bare-script run: benchmarks/ itself is sys.path[0]
    from timing import time_ms
from repro.kernels import ops
from repro.models import api


def _act_container_bytes(act_fmt):
    """Width of the activation operand entering the GEMM, measured from the
    codec kernel's actual output container (float path ships f32)."""
    if act_fmt is None:
        return np.dtype(np.float32).itemsize
    probe = ops.encode(jnp.zeros((1, 1), jnp.float32), act_fmt)
    return probe.dtype.itemsize


def bench_act_serving(cfg, B, S, rng, act_fmt, reps=2):
    """Run the packed model fused with float vs posit-coded activations."""
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cfg_ref = cfg.replace(quant=cfg.quant.with_execution("fused"))
    cfg_act = cfg.replace(quant=cfg.quant.with_serving_activations(act_fmt))
    params = api.init(jax.random.key(0), cfg_ref)
    packed = api.pack_params(params, cfg_ref)
    rows, logits = [], {}
    for label, pcfg, fmt in (("fused_float_act", cfg_ref, None),
                             ("fused_act_coded", cfg_act, act_fmt)):
        fwd = jax.jit(lambda p, t, c=pcfg: api.apply(p, {"tokens": t}, c))
        ms = time_ms(fwd, packed, tokens, reps=reps)
        logits[label] = np.asarray(fwd(packed, tokens), np.float64)
        rows.append([pcfg.name, label, B, S, ms,
                     float(_act_container_bytes(fmt))])
    ref = logits["fused_float_act"]
    for row, label in zip(rows, logits):
        err = logits[label] - ref
        row.append(float(np.sqrt(np.mean(err ** 2))))
    return rows


def print_act_rows(rows):
    print("\nmodel,act_mode,batch,seq,forward_ms,"
          "act_bytes_per_elem,logits_rmse_vs_float_act")
    for name, label, B, S, ms, ab, rmse in rows:
        print(f"{name},{label},{B},{S},{ms:.1f},{ab},{rmse:.3e}")


def act_checks(rows):
    """Shared assertions: coded operands at most half the f32 width and a
    finite deviation from the float-activation reference."""
    float_row, coded_row = rows
    return {
        "act_bandwidth_halved": coded_row[5] * 2 <= float_row[5],
        "act_coded_accuracy_sane": bool(np.isfinite(coded_row[6])),
    }
