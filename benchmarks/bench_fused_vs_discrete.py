"""§III-B reproduction: fused vs discrete decoder/encoder counts, the
rounding count per dot product, and the TPU translation — HBM bytes moved
per GEMM for fused in-kernel decode vs discrete decode-to-HBM.
"""
from __future__ import annotations

import math


def codec_counts(N: int):
    """Decoder/encoder counts for a size-N dot product (paper §III-B)."""
    tree_adders = 2 ** int(math.floor(math.log2(N + 1)))
    return {
        "mul_add_tree": {"decoders": 2 * N + tree_adders,
                         "encoders": N + tree_adders,
                         "roundings_per_dot": N + N},   # per-mult + per-add
        "fma_cascade": {"decoders": 3 * N, "encoders": N,
                        "roundings_per_dot": N},
        "pdpu_fused": {"decoders": 2 * N + 1, "encoders": 1,
                       "roundings_per_dot": 1},
    }


def tpu_bytes_per_gemm(M: int, K: int, N: int, in_bits: int = 16,
                       out_bits: int = 16):
    """HBM bytes: fused kernel (posit codes in, posit codes out, decode in
    VMEM) vs discrete (decode kernel writes f32 tensors to HBM, matmul
    reads them, encode kernel rewrites output)."""
    in_b, out_b = in_bits // 8, out_bits // 8
    fused = M * K * in_b + K * N * in_b + M * N * out_b
    discrete = (
        (M * K + K * N) * in_b          # decode kernel reads codes
        + (M * K + K * N) * 4           # ... writes f32 to HBM
        + (M * K + K * N) * 4           # matmul reads f32
        + M * N * 4                     # matmul writes f32
        + M * N * 4                     # encode kernel reads f32
        + M * N * out_b)                # ... writes codes
    return {"fused_bytes": fused, "discrete_bytes": discrete,
            "ratio": discrete / fused}


def main():
    print("N,arch,decoders,encoders,roundings")
    for N in (2, 4, 8, 16):
        for arch, c in codec_counts(N).items():
            print(f"{N},{arch},{c['decoders']},{c['encoders']},"
                  f"{c['roundings_per_dot']}")
    print("gemm,M,K,N,fused_bytes,discrete_bytes,ratio")
    for (M, K, N) in [(4096, 4096, 4096), (8192, 8192, 1024), (256, 16384, 256)]:
        r = tpu_bytes_per_gemm(M, K, N)
        print(f"gemm,{M},{K},{N},{r['fused_bytes']},{r['discrete_bytes']},"
              f"{r['ratio']:.2f}")
    c4 = codec_counts(4)
    checks = {
        "pdpu_fewest_decoders": c4["pdpu_fused"]["decoders"]
            == min(v["decoders"] for v in c4.values()),
        "pdpu_single_encoder": c4["pdpu_fused"]["encoders"] == 1,
        "pdpu_single_rounding": c4["pdpu_fused"]["roundings_per_dot"] == 1,
        "tpu_fused_beats_discrete_3x": tpu_bytes_per_gemm(4096, 4096, 4096)["ratio"] > 3.0,
    }
    for k, v in checks.items():
        print(f"claim,{k},{'PASS' if v else 'FAIL'}")


if __name__ == "__main__":
    main()
