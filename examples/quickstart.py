"""Quickstart: the paper's PDPU in 40 lines.

Builds posit vectors, runs the bit-exact fused dot product at several
configurations, and shows the accuracy/hardware trade-off of the
configurable generator (paper Table I in miniature).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import discrete, hwmodel, posit_np as pnp
from repro.core.formats import P8_2, P13_2, P16_2, PDPUConfig

rng = np.random.default_rng(0)
K = 64
a = rng.normal(0, 1, (8, K))
b = rng.normal(0, 1, (8, K))
exact = (a * b).sum(-1)

print("dot-product of K=64 posit values, out = acc + Va.Vb chunks")
print(f"{'config':36} {'result[0]':>12} {'mean rel err':>13} "
      f"{'area um2':>9} {'GOPS/W':>7}")
for cfg in [
    PDPUConfig(P8_2, P8_2, N=4, w_m=10),
    PDPUConfig(P13_2, P16_2, N=4, w_m=14),   # the paper's headline config
    PDPUConfig(P16_2, P16_2, N=8, w_m=14),
    PDPUConfig(P13_2, P16_2, N=4, w_m=256),  # quire (exact) reference
]:
    y = discrete.dpu_pdpu_fused(a, b, cfg)
    rel = np.abs(y - exact) / np.abs(exact)
    r = hwmodel.report(cfg)
    print(f"{cfg.name:36} {y[0]:12.6f} {rel.mean():13.2e} "
          f"{r.area_um2:9.0f} {r.energy_eff:7.0f}")

# the TPU-native fused path: posit codes in, single rounding out
import jax.numpy as jnp
from repro.kernels import ops
am = pnp.encode_np(a, P16_2)
bm = pnp.encode_np(b.T, P16_2)
out = ops.fused_matmul(jnp.asarray(am, jnp.int32), jnp.asarray(bm, jnp.int32),
                       P16_2, P16_2, P16_2, bm=8, bn=8, bk=64)
y_kernel = pnp.decode_np(np.asarray(out), P16_2)
print("\nPallas fused posit matmul diag vs exact:",
      np.abs(np.diag(y_kernel) - exact).max())
