"""End-to-end driver: train a small LM with the paper's mixed-precision
posit quantization (P(13,2) operands, f32 wide accumulation — the PDPU
contract) and compare against an unquantized run.

    PYTHONPATH=src python examples/train_posit_lm.py --steps 200
"""
import argparse

import jax

from repro import configs
from repro.core.quant import policy_by_name
from repro.data import DataConfig, Pipeline
from repro.models.config import ShapeConfig
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer, TrainerConfig


def run(quant: str, steps: int, arch: str):
    cfg = configs.get_smoke(arch).replace(quant=policy_by_name(quant))
    shape = ShapeConfig("ex", seq_len=128, global_batch=8, kind="train")
    pipe = Pipeline(cfg, shape, DataConfig(seed=0))
    opt = adamw(cosine_schedule(3e-3, warmup=steps // 10, total=steps))
    tr = Trainer(cfg, shape, opt, pipe,
                 TrainerConfig(total_steps=steps, log_every=max(steps // 10, 1),
                               ckpt_every=steps, accum=2))
    tr.run(jax.random.key(0))
    return [h["loss"] for h in tr.history]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--arch", default="minitron_8b")
    args = ap.parse_args()
    base = run("none", args.steps, args.arch)
    mixed = run("paper_mixed", args.steps, args.arch)
    n = max(args.steps // 5, 1)
    print(f"\nfinal loss (mean of last {n}):")
    print(f"  float32      : {sum(base[-n:])/n:.4f}")
    print(f"  P(13,2) mixed: {sum(mixed[-n:])/n:.4f}")
    print("mixed-precision posit training tracks the float baseline "
          "(paper §III-B / PositNN [26]).")


if __name__ == "__main__":
    main()
