"""End-to-end driver: train a small LM with the paper's mixed-precision
posit quantization (P(13,2) operands, f32 wide accumulation — the PDPU
contract) and compare against an unquantized run.

`--execution` picks the QAT datapath (QuantPolicy.with_execution):

  fake_quant : STE fake-quantization on float dots (the classical recipe).
  fused      : kernel-in-the-loop QAT — every matmul forward runs the
               packed Pallas fused GEMM (encode -> in-kernel decode ->
               wide f32 MXU accumulate) and the loss/grads come from that
               datapath via the custom_vjp STE backward.  Training sees
               exactly what fused serving will execute.

    PYTHONPATH=src python examples/train_posit_lm.py --steps 200
    PYTHONPATH=src python examples/train_posit_lm.py --execution fused
"""
import argparse

import jax

from repro import configs
from repro.core.quant import TRAINABLE_PLANS, policy_by_name
from repro.data import DataConfig, Pipeline
from repro.models.config import ShapeConfig
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer, TrainerConfig


def run(quant: str, steps: int, arch: str, execution: str = "fake_quant"):
    policy = policy_by_name(quant)
    if policy.enabled:  # 'none' has no formats: nothing to execute fused
        policy = policy.with_execution(execution).require_trainable()
    cfg = configs.get_smoke(arch).replace(quant=policy)
    shape = ShapeConfig("ex", seq_len=128, global_batch=8, kind="train")
    pipe = Pipeline(cfg, shape, DataConfig(seed=0))
    opt = adamw(cosine_schedule(3e-3, warmup=steps // 10, total=steps))
    tr = Trainer(cfg, shape, opt, pipe,
                 TrainerConfig(total_steps=steps, log_every=max(steps // 10, 1),
                               ckpt_every=steps, accum=2))
    tr.run(jax.random.key(0))
    return [h["loss"] for h in tr.history]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--arch", default="minitron_8b")
    ap.add_argument("--execution", default="fake_quant",
                    choices=list(TRAINABLE_PLANS),
                    help="QAT datapath: fake_quant (STE on float dots) or "
                         "fused (packed Pallas kernel forward, STE backward)")
    args = ap.parse_args()
    base = run("none", args.steps, args.arch)
    mixed = run("paper_mixed", args.steps, args.arch, args.execution)
    n = max(args.steps // 5, 1)
    print(f"\nfinal loss (mean of last {n}):")
    print(f"  float32      : {sum(base[-n:])/n:.4f}")
    print(f"  P(13,2) mixed: {sum(mixed[-n:])/n:.4f} "
          f"(execution={args.execution})")
    print("mixed-precision posit training tracks the float baseline "
          "(paper §III-B / PositNN [26]).")


if __name__ == "__main__":
    main()
