"""Ablation: accuracy of the PDPU vs alignment width W_m and chunk size N
on the conv1-shaped workload — how a deployment picks the generator
configuration for a target DNN (paper §III-C "suitable alignment width").

    PYTHONPATH=src python examples/wm_sensitivity_study.py
"""
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.workload import conv1_workload
from repro.core import discrete, hwmodel
from repro.core.formats import P13_2, P16_2, PDPUConfig

a, b = conv1_workload(n_positions=32, seed=0)
exact = (a * b).sum(-1)

print(f"{'N':>3} {'W_m':>4} {'accuracy%':>10} {'hit@1%':>8} "
      f"{'area um2':>9} {'GOPS/mm2':>9}")
from benchmarks.bench_table1 import hit_rate_pct
for N in (4, 8):
    for w_m in (8, 10, 12, 14, 18, 24):
        cfg = PDPUConfig(P13_2, P16_2, N=N, w_m=w_m)
        y = discrete.dpu_pdpu_fused(a, b, cfg)
        r = hwmodel.report(cfg)
        print(f"{N:>3} {w_m:>4} {discrete.accuracy_pct(y, exact):>10.2f} "
              f"{hit_rate_pct(y, exact):>8.2f} {r.area_um2:>9.0f} "
              f"{r.area_eff:>9.0f}")
print("\nW_m=14 is the knee: quire-level accuracy at a fraction of the "
      "area (paper Table I).")
