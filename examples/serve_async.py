"""Async continuous-batching serving with SLO classes, preemption,
streaming, and posit-native speculative decoding.

What this walks through (and asserts, so CI can run it as a smoke):
  1. an `AsyncServingFrontend` drains a mixed-SLO queue: low-priority
     batch requests fill every slot, then a high-priority *interactive*
     request arrives mid-flight and PREEMPTS a batch slot — the victim's
     pages flow through the engine's refcount/held-page paths, its
     request requeues, and its client stream resumes exactly where it
     left off (the front end dedups the bit-identical replay by count);
  2. per-token streaming callbacks fire in generation order and the
     streamed view matches each request's final token list;
  3. speculative decoding rides underneath: a draft policy over the SAME
     posit-coded KV pages proposes k tokens per round and one batched
     multi-query paged-attention dispatch verifies them — acceptance is
     exact, so every token stream is bitwise identical to a plain
     synchronous engine run of the same requests (asserted);
  4. TTFT / inter-token-latency histograms and the speculation accept
     rate surface through `frontend.execution_summary()`.

SERVE_ASYNC_REQUESTS / SERVE_ASYNC_TOKENS shrink the demo for CI.

    PYTHONPATH=src python examples/serve_async.py
"""
import asyncio
import os

import jax
import numpy as np

from repro import configs
from repro.core.formats import P8_2, P16_2
from repro.core.quant import QuantPolicy
from repro.models import api
from repro.serve import (AsyncServingFrontend, Request, ServingEngine,
                         SLOClass)

N_REQ = int(os.environ.get("SERVE_ASYNC_REQUESTS", "4"))
MAX_NEW = int(os.environ.get("SERVE_ASYNC_TOKENS", "8"))
SPEC_K = 4

cfg = configs.get_tiny_serving(
    "command_r_35b", QuantPolicy(weights=P16_2, kv_cache=P8_2))
params = api.init(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, 6 + 3 * i).astype(np.int32)
           for i in range(N_REQ)]
interactive_prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)

# ---- reference: plain synchronous serving, no speculation, no async ----
ref_engine = ServingEngine(cfg, params, batch_slots=2, max_seq=64)
for i, p in enumerate(prompts):
    ref_engine.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW))
ref_engine.submit(Request(rid=100, prompt=interactive_prompt,
                          max_new_tokens=MAX_NEW))
reference = {r.rid: list(r.out_tokens) for r in ref_engine.run()}

# ---- async + speculative serving of the same traffic ----
engine = ServingEngine(cfg, params, batch_slots=2, max_seq=64,
                       speculate_k=SPEC_K)
frontend = AsyncServingFrontend(engine)
streams: dict = {}


def on_token(rid, idx, tok):
    out = streams.setdefault(rid, [])
    assert idx == len(out), f"stream {rid} skipped/replayed index {idx}"
    out.append(tok)


async def clients():
    tickets = [
        frontend.submit(p, max_new_tokens=MAX_NEW, slo="batch",
                        on_token=on_token, rid=i)
        for i, p in enumerate(prompts)]
    # wait until every slot is busy with batch traffic, then drop in the
    # interactive request — with no free slot it must preempt a batch one
    while (engine.slot_phase == 0).any() or not engine.queue:
        if all(t.state != "pending" for t in tickets):
            break
        await asyncio.sleep(0)
    t_int = frontend.submit(interactive_prompt, max_new_tokens=MAX_NEW,
                            slo="interactive", on_token=on_token, rid=100)
    results = {t.rid: await t.wait() for t in tickets}
    results[t_int.rid] = await t_int.wait()
    return results


async def main():
    results, _ = await asyncio.gather(clients(), frontend.run())
    return results


results = asyncio.run(main())
summary = frontend.execution_summary()

assert set(results) == set(reference)
for rid, toks in reference.items():
    assert results[rid] == toks, (
        f"rid {rid}: async+speculative stream diverged from the plain "
        f"engine: {results[rid]} vs {toks}")
    assert streams[rid] == toks, f"rid {rid}: streamed view diverged"
assert summary["speculative"] and summary["speculation_rounds"] > 0
assert not engine.queue and engine.pages_in_use == 0

print(f"[serve_async] drained {len(results)} requests "
      f"({sum(len(t) for t in results.values())} tokens) — every stream "
      f"bitwise equal to the plain synchronous engine")
note = (" (interactive request displaced a batch slot mid-decode; victim "
        "replayed bit-identically, stream dedup'd)"
        if summary["frontend_preemptions"] else
        " (queue drained before the interactive arrival needed a slot)")
print(f"[serve_async] preemptions: {summary['frontend_preemptions']}{note}")
print(f"[serve_async] speculation: k={summary['speculate_k']}, "
      f"{summary['speculation_rounds']} rounds, accept rate "
      f"{summary['speculation_accept_rate']:.2f}, "
      f"{summary['speculation_committed_tokens']} tokens committed "
      f"speculatively")
ttft, itl = summary["ttft_ms"], summary["itl_ms"]
print(f"[serve_async] TTFT p50={ttft['p50_ms']:.1f}ms "
      f"p95={ttft['p95_ms']:.1f}ms over {ttft['count']} requests; "
      f"ITL p50={itl['p50_ms']:.1f}ms p95={itl['p95_ms']:.1f}ms over "
      f"{itl['count']} intervals")
print(f"[serve_async] histogram buckets: ttft={ttft['buckets']} "
      f"itl={itl['buckets']}")
