"""Serving with posit-packed weights + a paged posit-KV cache.

End-to-end demonstration of the paged serving runtime:
  1. a float checkpoint's qdot weights are packed once to P(16,2) codes
     (int16 — half the bf16 bytes, quarter the f32 bytes),
  2. the packed tree is checkpointed with pack metadata in the manifest,
  3. `ServingEngine.from_checkpoint` restores the codes and serves them
     through the *fused* Pallas GEMM, with the KV cache held as
     **posit-coded pages**: prompts prefill in bucketed chunks — same-size
     chunks from multiple slots batched into one program — straight into
     block-table pages, requests sharing the demo's system prompt map the
     same physical prefix pages (refcounted, copy-on-write past the
     prefix), decode attends them through the Pallas paged-attention
     kernel (block-table gather + in-kernel posit decode), and retired
     requests hand their pages back to the free list,
  4. the same checkpoint is re-served *activation-coded*
     (`serve_fused_p16_a13`): both GEMM operands run at int16 code width.

SERVE_DEMO_REQUESTS / SERVE_DEMO_TOKENS shrink the demo (the CI smoke step
runs a few decode steps on CPU, interpret mode).

    PYTHONPATH=src python examples/serve_posit_lm.py
"""
import os
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.quant import policy_by_name
from repro.kernels import autotune
from repro.models import api
from repro.serve import Request, ServingEngine

N_REQ = int(os.environ.get("SERVE_DEMO_REQUESTS", "10"))
MAX_NEW = int(os.environ.get("SERVE_DEMO_TOKENS", "12"))

cfg = configs.get_smoke("command_r_35b").replace(
    quant=policy_by_name("serve_fused_p16"))
params = api.init(jax.random.key(0), cfg)

# one-shot pack pass: float masters -> posit code arrays (int16)
packed = api.pack_params(params, cfg)
f32_bytes = api.weight_bytes(params)
packed_bytes = api.weight_bytes(packed)
print(f"weights: {f32_bytes} B float -> {packed_bytes} B packed "
      f"({f32_bytes / packed_bytes:.2f}x smaller)")

with tempfile.TemporaryDirectory() as ckpt_dir:
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(0, packed, extra=api.pack_manifest(cfg))
    # shard the page pool when the runtime has >1 device (the CI
    # multi-device leg forces 8 host devices): each device owns a
    # contiguous global-page-id range with its own budget, block tables
    # keep global ids, decode merges per-device softmax partials exactly
    mesh = None
    if jax.device_count() > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(2)
    engine = ServingEngine.from_checkpoint(cfg, ckpt_dir,
                                           batch_slots=4, max_seq=96,
                                           page_size=16, mesh=mesh)
    kv = engine.kv_cache_summary()
    print(f"engine resident: {engine.weight_bytes()} B weights; paged KV "
          f"pool {kv['kv_bytes']} B ({engine.cache['k'].dtype} codes, "
          f"page_size={engine.layout.page_size}) + {kv['metadata_bytes']} B "
          f"block-table/position metadata")
    rng = np.random.default_rng(0)
    # repeated-system-prompt traffic: every request opens with the same
    # 32-token "system prompt" (two full pages — prefix sharing maps them
    # once) followed by a short per-request question
    system = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    prompts = [np.concatenate([system,
                               rng.integers(0, cfg.vocab_size, 4)
                               .astype(np.int32)])
               for _ in range(N_REQ)]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW))
    t0 = time.perf_counter()
    # step once to catch the pool mid-flight, then drain
    engine.step()
    mid = engine.kv_cache_summary()
    print(f"mid-flight: {engine.pages_in_use} pages in use / "
          f"{engine.pages_free} free "
          f"({mid['kv_bytes_in_use']} B of coded KV backing tokens); "
          f"{engine.pages_shared_mapped} shared page refs mapped beyond "
          f"their first block table")
    occ = engine.allocator.pages_in_use_by_shard
    budget = engine.allocator.pages_per_shard - 1
    print(f"per-device page occupancy ({engine.n_shards} shard(s), "
          f"budget {budget} pages each): "
          + " ".join(f"d{i}={u}/{budget}" for i, u in enumerate(occ)))
    done = engine.run()
    dt = time.perf_counter() - t0
    batches = engine.stats["prefill_batch_sizes"]
    n_chunks = sum(k * v for k, v in batches.items())
    print(f"prefix sharing: {engine.stats['shared_admissions']} of "
          f"{len(done)} requests admitted onto shared prefix pages "
          f"({engine.stats['pages_shared']} page refs shared, "
          f"{engine.stats['cow_forks']} COW forks); fresh pages allocated: "
          f"{engine.allocator.total_allocs}")
    print(f"batched prefill: {n_chunks} chunks in "
          f"{sum(batches.values())} device calls "
          f"(batch-size histogram {dict(sorted(batches.items()))})")
    summ = engine.execution_summary()
    # each chunk group ran as 1 program (fused) or 3 (decomposed
    # fallback), so the counter pair recovers the per-chunk coverage
    n_pc, n_pp = summ["prefill_chunks"], summ["prefill_device_programs"]
    n_fused = (3 * n_pc - n_pp) // 2
    print(f"fused prefill: {'on' if summ['fused_prefill'] else 'off'} — "
          f"{n_pp} attention-stage device programs for {n_pc} chunk "
          f"groups: {n_fused} fused (1 program) / {n_pc - n_fused} "
          f"fallback (3 programs)")
    n_ds, n_dp = summ["decode_steps"], summ["decode_device_programs"]
    print(f"fused decode: {'on' if summ['fused_decode'] else 'off'} — "
          f"{n_dp / max(n_ds, 1):.1f} device programs per decode step "
          f"({n_dp} programs / {n_ds} steps; 1 fused = model+head+sampler "
          f"in one dispatch, 2 decomposed)")
    tuned = autotune.hit_report()
    print(f"autotune cache: {len(autotune.get_cache().entries)} entries; "
          f"tuned-config hits/misses this run: {tuned or 'none'}")

    # coded-page storage ratio: what the dense f32 worst-case cache would
    # allocate vs the coded pages that peak traffic actually touched
    dense_f32 = 2 * cfg.n_layers * engine.B * engine.S \
        * cfg.n_kv_heads * cfg.head_dim * 4
    peak = engine.kv_cache_summary()["kv_bytes_peak"]
    print(f"decode-state storage: dense f32 would allocate {dense_f32} B; "
          f"peak coded pages in flight {peak} B "
          f"({dense_f32 / peak:.1f}x smaller)")

    # activation-coded serving: same packed checkpoint, activations now
    # travel as P(13,2) codes through the both-operands fused kernel
    cfg_act = cfg.replace(quant=policy_by_name("serve_fused_p16_a13"))
    engine_act = ServingEngine.from_checkpoint(cfg_act, ckpt_dir,
                                               batch_slots=4, max_seq=96)
    n_act = min(4, N_REQ)
    for i, p in enumerate(prompts[:n_act]):
        engine_act.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW))
    done_act = engine_act.run()

tok = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {tok} tokens in {dt:.2f}s "
      f"({tok/dt:.1f} tok/s on CPU, Pallas interpret mode)")
print(f"execution plan: {cfg.quant.execution} "
      f"(weights {cfg.quant.weights}, kv {cfg.quant.kv_cache}, "
      f"pages reclaimed: {engine.pages_free}/{engine.allocator.capacity} free)")
print(f"sample continuation: {done[0].out_tokens}")
print(f"activation-coded plan: {engine_act.execution_summary()}")
by_rid = {r.rid: r.out_tokens for r in done}
match = sum(by_rid[r.rid] == r.out_tokens for r in done_act) / len(done_act)
print(f"activation-coded vs float-activation continuations: "
      f"{match:.0%} identical over {len(done_act)} requests "
      f"(both operands int16 codes vs f32 activations)")
