"""Serving with posit-compressed weights + KV cache (continuous batching).

The KV cache is stored as P(8,2) codes (4x smaller than f32, 2x smaller
than bf16) and decoded exactly on read — the PDPU storage-format win
applied to the decode-bandwidth roofline.

    PYTHONPATH=src python examples/serve_posit_lm.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core.quant import policy_by_name
from repro.models import api
from repro.serve import Request, ServingEngine

cfg = configs.get_smoke("command_r_35b").replace(
    quant=policy_by_name("serve_p16_kv8"))
params = api.init(jax.random.key(0), cfg)
engine = ServingEngine(cfg, params, batch_slots=4, max_seq=96)
rng = np.random.default_rng(0)
for i in range(10):
    engine.submit(Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                          max_new_tokens=12))
t0 = time.perf_counter()
done = engine.run()
dt = time.perf_counter() - t0
tok = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {tok} tokens in {dt:.2f}s "
      f"({tok/dt:.1f} tok/s on CPU)")
print(f"kv cache dtype: {engine.cache['k'].dtype} (posit P(8,2) codes)")
print(f"sample continuation: {done[0].out_tokens}")
