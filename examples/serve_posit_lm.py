"""Serving with posit-packed weights + posit KV cache (continuous batching).

End-to-end demonstration of the execution-plan architecture:
  1. a float checkpoint's qdot weights are packed once to P(16,2) codes
     (int16 — half the bf16 bytes, quarter the f32 bytes),
  2. the packed tree is checkpointed with pack metadata in the manifest,
  3. `ServingEngine.from_checkpoint` restores the codes and serves them
     through the *fused* Pallas GEMM (in-kernel decode, wide f32 MXU
     accumulate — the PDPU datapath on the model hot path), with the KV
     cache stored as P(8,2) codes decoded exactly on read,
  4. the same checkpoint is re-served *activation-coded*
     (`serve_fused_p16_a13`): activations are encoded to P(13,2) too, so
     both GEMM operands run through the both-operands fused kernel at
     int16 width — the accuracy/bandwidth serving knob.

    PYTHONPATH=src python examples/serve_posit_lm.py
"""
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.quant import policy_by_name
from repro.models import api
from repro.serve import Request, ServingEngine

cfg = configs.get_smoke("command_r_35b").replace(
    quant=policy_by_name("serve_fused_p16"))
params = api.init(jax.random.key(0), cfg)

# one-shot pack pass: float masters -> posit code arrays (int16)
packed = api.pack_params(params, cfg)
f32_bytes = api.weight_bytes(params)
packed_bytes = api.weight_bytes(packed)
print(f"weights: {f32_bytes} B float -> {packed_bytes} B packed "
      f"({f32_bytes / packed_bytes:.2f}x smaller)")

with tempfile.TemporaryDirectory() as ckpt_dir:
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(0, packed, extra=api.pack_manifest(cfg))
    engine = ServingEngine.from_checkpoint(cfg, ckpt_dir,
                                           batch_slots=4, max_seq=96)
    print(f"engine resident: {engine.weight_bytes()} B weights, "
          f"{engine.kv_cache_bytes()} B kv cache (P(8,2) codes)")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(10)]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0

    # activation-coded serving: same packed checkpoint, activations now
    # travel as P(13,2) codes through the both-operands fused kernel
    cfg_act = cfg.replace(quant=policy_by_name("serve_fused_p16_a13"))
    engine_act = ServingEngine.from_checkpoint(cfg_act, ckpt_dir,
                                               batch_slots=4, max_seq=96)
    for i, p in enumerate(prompts[:4]):
        engine_act.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    done_act = engine_act.run()

tok = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {tok} tokens in {dt:.2f}s "
      f"({tok/dt:.1f} tok/s on CPU, Pallas interpret mode)")
print(f"execution plan: {cfg.quant.execution} "
      f"(weights {cfg.quant.weights}, kv {cfg.quant.kv_cache})")
print(f"kv cache dtype: {engine.cache['k'].dtype} (posit P(8,2) codes)")
print(f"sample continuation: {done[0].out_tokens}")
print(f"activation-coded plan: {engine_act.execution_summary()}")
match = sum(a.out_tokens == b.out_tokens
            for a, b in zip(done[:4], done_act)) / len(done_act)
print(f"activation-coded vs float-activation continuations: "
      f"{match:.0%} identical over {len(done_act)} requests "
      f"(both operands int16 codes vs f32 activations)")
