"""Post-training quantization study: model-level analogue of Table I.

Trains a small LM in float32, then evaluates held-out cross-entropy with
weights (and optionally activations) quantized to each storage format —
the deployment question PDPU answers: which posit format serves this model
with how much quality loss, at what hardware cost (generator model).

    PYTHONPATH=src python examples/ptq_study.py --steps 300
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import posit
from repro.core.formats import P8_2, P10_2, P13_2, P16_2
from repro.data import DataConfig, Pipeline
from repro.models import api
from repro.models.config import ShapeConfig
from repro.models.module import param_count
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer, TrainerConfig, step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="minitron_8b")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    shape = ShapeConfig("ptq", seq_len=128, global_batch=8, kind="train")
    pipe = Pipeline(cfg, shape, DataConfig(seed=0))
    opt = adamw(cosine_schedule(3e-3, warmup=args.steps // 10, total=args.steps))
    tr = Trainer(cfg, shape, opt, pipe,
                 TrainerConfig(total_steps=args.steps,
                               log_every=max(args.steps // 5, 1),
                               ckpt_every=args.steps, accum=1))
    state = tr.run(jax.random.key(0))
    params = state.params

    eval_pipe = Pipeline(cfg, shape, DataConfig(seed=777))
    batches = [jax.tree.map(jnp.asarray, eval_pipe.batch_at(i)) for i in range(4)]
    eval_step = jax.jit(lambda p, b: step_lib.loss_fn(p, b, cfg)[0])

    def ce_with(quantize):
        q = jax.tree.map(lambda p: quantize(p) if p.ndim >= 2 else p, params)
        return float(np.mean([float(eval_step(q, b)) for b in batches]))

    base = ce_with(lambda p: p)
    rows = [("float32 (reference)", base, 32)]
    rows.append(("bfloat16", ce_with(lambda p: p.astype(jnp.bfloat16)
                                     .astype(jnp.float32)), 16))
    rows.append(("float16", ce_with(lambda p: p.astype(jnp.float16)
                                    .astype(jnp.float32)), 16))
    for fmt in (P16_2, P13_2, P10_2, P8_2):
        rows.append((str(fmt), ce_with(lambda p, f=fmt: posit.quantize(p, f)),
                     fmt.n))

    n = param_count(api.param_specs(cfg))
    print(f"\nPTQ held-out CE ({cfg.name}, {n/1e3:.0f}K params, "
          f"{args.steps} train steps):")
    print(f"{'format':22} {'eval CE':>9} {'delta':>8} {'bits':>5} "
          f"{'weight MB/1B-params':>20}")
    for name, ce, bits in rows:
        print(f"{name:22} {ce:9.4f} {ce-base:+8.4f} {bits:5d} "
              f"{bits/8*1000:20.0f}")
    p16 = dict((r[0], r[1]) for r in rows)
    ok = (p16["P(16,2)"] - base) < 0.01 and (p16["P(13,2)"] - base) < 0.05
    print("\nposit-16 serves at float quality, posit-13 within noise — the "
          "paper's mixed-precision deployment claim." if ok else
          "\nWARNING: posit quality gap larger than expected on this run.")


if __name__ == "__main__":
    main()
